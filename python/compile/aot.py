"""AOT pipeline: lower the L2 graphs to HLO text artifacts.

Emits one ``.hlo.txt`` per (entry point, shape) pair plus a
``manifest.json`` describing every artifact, which the Rust runtime
(`rust/src/runtime/artifact.rs`) parses to discover and shape-check
executables at startup.

HLO **text** — not ``lowered.compile()`` / ``.serialize()`` — is the
interchange format: jax ≥ 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Artifact schedule: every (name, entry, shapes) the runtime needs.
# Shapes here MUST stay in sync with the examples' code parameters; the
# manifest makes any drift a loud startup error on the Rust side rather
# than a silent shape mismatch.
WORKER_SPECS = [
    # (r, d, b): shard rows, data dim, batch width.
    (16, 32, 1),    # quickstart: tiny shards, single request
    (64, 128, 4),   # integration tests
    (256, 128, 4),  # end-to-end regression example (m=1024, k1=k2=2)
    (256, 128, 8),  # batched serving example
    (128, 64, 1),   # power-iteration (pagerank) example
]
ENCODE_SPECS = [
    # (n, k, r, d): code params, block rows, data dim.
    (6, 3, 64, 32),
    (4, 2, 256, 128),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_worker(r, d, b):
    """Lower ``worker_task`` for a (r, d) shard and (d, b) request."""
    shard = jax.ShapeDtypeStruct((r, d), jax.numpy.float32)
    x = jax.ShapeDtypeStruct((d, b), jax.numpy.float32)
    return jax.jit(model.worker_task).lower(shard, x)


def lower_encode(n, k, r, d):
    """Lower ``encode_task`` for an (n, k) code over (k, r, d) blocks."""
    g = jax.ShapeDtypeStruct((n, k), jax.numpy.float32)
    blocks = jax.ShapeDtypeStruct((k, r, d), jax.numpy.float32)
    return jax.jit(model.encode_task).lower(g, blocks)


def emit(out_dir: str, verbose: bool = True) -> dict:
    """Write all artifacts + manifest; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    def write(name, text, meta):
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        entries.append(
            {"name": name, "file": fname, "sha256_16": digest, **meta}
        )
        if verbose:
            print(f"  wrote {fname} ({len(text)} chars)")

    for r, d, b in WORKER_SPECS:
        name = f"worker_matvec_r{r}_d{d}_b{b}"
        write(
            name,
            to_hlo_text(lower_worker(r, d, b)),
            {
                "entry": "worker_task",
                "inputs": [[r, d], [d, b]],
                "output": [r, b],
                "dtype": "f32",
            },
        )
    for n, k, r, d in ENCODE_SPECS:
        name = f"encode_n{n}_k{k}_r{r}_d{d}"
        write(
            name,
            to_hlo_text(lower_encode(n, k, r, d)),
            {
                "entry": "encode_task",
                "inputs": [[n, k], [k, r, d]],
                "output": [n, r, d],
                "dtype": "f32",
            },
        )

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  wrote manifest.json ({len(entries)} artifacts)")
    return manifest


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir", default="../artifacts", help="artifact output directory"
    )
    parser.add_argument("--quiet", action="store_true")
    args = parser.parse_args()
    emit(args.out_dir, verbose=not args.quiet)


if __name__ == "__main__":
    main()
