"""L1 Pallas kernel: the worker hot-spot ``Â_{i,j} @ X``.

The compute a worker performs in the hierarchical scheme (§II-A) is a
dense product of its coded shard ``(r, d)`` with the (batched) request
``(d, b)``. This is the only code on a worker's critical path, so it is
the kernel the paper's latency model prices at rate `µ1`.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the shard's
rows in ``block_r`` chunks and the batch in ``block_b`` chunks; each
program owns a ``(block_r, d) × (d, block_b)`` product — an MXU-shaped
GEMM whose operands fit VMEM. The reduction dimension `d` is kept whole
per program (shards are short and wide: `r = m/(k1·k2) ≫ d` is the
common shape), which avoids cross-program accumulation. All Pallas calls
use ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness — not interpret-mode wallclock — is what
CPU runs validate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, x_ref, o_ref):
    """One grid program: a (block_r, d) x (d, block_b) MXU-shaped GEMM."""
    o_ref[...] = jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim, preferred):
    """Largest divisor of ``dim`` that is <= ``preferred``.

    Keeps the grid exact (no masking needed) for any shard shape while
    still tiling big shards into VMEM-sized pieces.
    """
    for cand in range(min(preferred, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("block_r", "block_b"))
def shard_matmul(shard, x, *, block_r=256, block_b=128):
    """Compute ``shard @ x`` with a tiled Pallas kernel.

    Args:
      shard: ``(r, d)`` float32 coded shard.
      x: ``(d, b)`` float32 batched request.
      block_r: preferred row-tile size (clipped to a divisor of ``r``).
      block_b: preferred batch-tile size (clipped to a divisor of ``b``).

    Returns:
      ``(r, b)`` float32 product.
    """
    r, d = shard.shape
    d2, b = x.shape
    assert d == d2, f"contraction mismatch: {d} vs {d2}"
    br = _pick_block(r, block_r)
    bb = _pick_block(b, block_b)
    grid = (r // br, b // bb)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, b), jnp.float32),
        interpret=True,
    )(shard, x)


def vmem_footprint_bytes(r, d, b, block_r=256, block_b=128):
    """Estimated VMEM bytes a single grid program touches (f32).

    Used by DESIGN.md §Perf to check the tiling against the ~16 MiB VMEM
    budget of a TPU core: one shard tile + one request tile + one output
    tile, double-buffered (×2) for the HBM→VMEM pipeline.
    """
    br = _pick_block(r, block_r)
    bb = _pick_block(b, block_b)
    per_program = (br * d + d * bb + br * bb) * 4
    return 2 * per_program
