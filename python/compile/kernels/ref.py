"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the pytest suite checks the kernels against
(`assert_allclose`), and double as the shape/semantics documentation:

* ``shard_matmul_ref``   — the worker hot-spot `Â_{i,j} @ X`;
* ``encode_blocks_ref``  — MDS encode: generator × stacked blocks;
* ``lincomb_ref``        — one generator row applied to stacked blocks.
"""

import jax.numpy as jnp


def shard_matmul_ref(shard, x):
    """Worker task: ``shard @ x``.

    Args:
      shard: ``(r, d)`` coded shard `Â_{i,j}`.
      x: ``(d, b)`` batched request matrix.

    Returns:
      ``(r, b)`` product.
    """
    return jnp.dot(shard, x, preferred_element_type=jnp.float32)


def encode_blocks_ref(generator, blocks):
    """MDS encode: ``out[i] = sum_j generator[i, j] * blocks[j]``.

    Args:
      generator: ``(n, k)`` MDS generator matrix.
      blocks: ``(k, r, d)`` stacked data blocks.

    Returns:
      ``(n, r, d)`` stacked coded blocks.
    """
    return jnp.einsum("ij,jrd->ird", generator, blocks)


def lincomb_ref(coeffs, blocks):
    """One coded block: ``sum_j coeffs[j] * blocks[j]``.

    Args:
      coeffs: ``(k,)`` one generator row.
      blocks: ``(k, r, d)`` stacked data blocks.

    Returns:
      ``(r, d)`` coded block.
    """
    return jnp.einsum("j,jrd->rd", coeffs, blocks)
