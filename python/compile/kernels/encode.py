"""L1 Pallas kernel: MDS encoding (generator × stacked blocks).

Encoding happens once per dataset (§II-A: the coded shards are stored in
the racks ahead of time, Facebook-cluster style), so this kernel is on
the *setup* path, not the request path. It is still implemented as a
first-class Pallas kernel: large `A` matrices make encoding a real cost,
and the same kernel re-encodes after group membership changes.

Layout: the ``(k, r, d)`` block stack is contracted with the ``(n, k)``
generator. The grid tiles the output rows `r`; the tiny generator is
replicated to every program (it would live in SMEM on a real TPU) while
block tiles stream HBM→VMEM once each.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(g_ref, blocks_ref, o_ref):
    """One grid program: out tile = einsum('ij,jrd->ird', G, block tile)."""
    o_ref[...] = jnp.einsum(
        "ij,jrd->ird",
        g_ref[...],
        blocks_ref[...],
        preferred_element_type=jnp.float32,
    )


def _pick_block(dim, preferred):
    for cand in range(min(preferred, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("block_r",))
def encode_blocks(generator, blocks, *, block_r=128):
    """MDS-encode ``k`` stacked blocks into ``n`` coded blocks.

    Args:
      generator: ``(n, k)`` float32 generator matrix.
      blocks: ``(k, r, d)`` float32 stacked data blocks.
      block_r: preferred row-tile size (clipped to a divisor of ``r``).

    Returns:
      ``(n, r, d)`` float32 stacked coded blocks.
    """
    n, k = generator.shape
    k2, r, d = blocks.shape
    assert k == k2, f"generator k={k} vs blocks k={k2}"
    br = _pick_block(r, block_r)
    grid = (r // br,)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((k, br, d), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((n, br, d), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r, d), jnp.float32),
        interpret=True,
    )(generator, blocks)
