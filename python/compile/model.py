"""L2: the JAX compute graph of hierarchical coded computation.

Composes the L1 Pallas kernels into the functions the Rust coordinator
executes via PJRT:

* :func:`worker_task` — the request-path graph (one worker's product),
  lowered per shard shape by ``aot.py``;
* :func:`encode_task` — the setup-path graph (MDS encode of a block
  stack);
* :func:`hierarchical_pipeline` — the whole scheme end-to-end in JAX
  (encode → all worker products → two-level decode), used by the pytest
  suite as a differential oracle against the Rust implementation's
  semantics.

Python never runs on the request path: these functions exist to be
lowered once (``make artifacts``) and to power build-time tests.
"""

import jax
import jax.numpy as jnp

from compile.kernels import coded_matvec, encode


def worker_task(shard, x):
    """One worker's request-path compute: ``Â_{i,j} @ X``.

    Args:
      shard: ``(r, d)`` coded shard held by the worker.
      x: ``(d, b)`` batched request.

    Returns:
      1-tuple of the ``(r, b)`` product (tuple so HLO lowering with
      ``return_tuple=True`` matches the Rust loader's ``to_tuple1``).
    """
    return (coded_matvec.shard_matmul(shard, x),)


def encode_task(generator, blocks):
    """Setup-path compute: encode ``k`` blocks into ``n`` coded blocks.

    Args:
      generator: ``(n, k)`` generator.
      blocks: ``(k, r, d)`` data blocks.

    Returns:
      1-tuple of ``(n, r, d)`` coded blocks.
    """
    return (encode.encode_blocks(generator, blocks),)


def hierarchical_encode(a, g_outer, g_inner):
    """Encode ``A`` with the two-level scheme of §II-A.

    Args:
      a: ``(m, d)`` input matrix, ``m`` divisible by ``k1·k2``.
      g_outer: ``(n2, k2)`` outer generator.
      g_inner: ``(n1, k1)`` inner generator (homogeneous groups).

    Returns:
      ``(n2, n1, r, d)`` shard tensor, ``r = m/(k1·k2)``;
      ``shards[i, j]`` is `Â_{i,j}`.
    """
    n2, k2 = g_outer.shape
    n1, k1 = g_inner.shape
    m, d = a.shape
    assert m % (k1 * k2) == 0, f"m={m} not divisible by k1*k2={k1 * k2}"
    r = m // (k1 * k2)
    # Outer: A -> k2 blocks of (m/k2, d) -> n2 coded group matrices.
    outer_blocks = a.reshape(k2, m // k2, d)
    coded_groups = encode.encode_blocks(g_outer, outer_blocks)
    # Inner, per group: (m/k2, d) -> k1 blocks -> n1 coded shards.
    inner_blocks = coded_groups.reshape(n2, k1, r, d)
    shards = jax.vmap(lambda blocks: encode.encode_blocks(g_inner, blocks))(
        inner_blocks
    )
    return shards


def hierarchical_pipeline(a, x, g_outer, g_inner):
    """The full scheme in JAX: encode, compute all products, decode from
    the systematic workers (all-workers-finished reference path).

    Returns ``(y, shards, products)`` where ``y ≈ A @ x``.
    """
    n2, k2 = g_outer.shape
    n1, k1 = g_inner.shape
    shards = hierarchical_encode(a, g_outer, g_inner)
    products = jax.vmap(
        jax.vmap(lambda s: coded_matvec.shard_matmul(s, x))
    )(shards)
    # Decode via the systematic prefix (generators are [I; P]): group i's
    # result is the stack of its first k1 products; A@x stacks the first
    # k2 groups.
    m = a.shape[0]
    b = x.shape[1]
    y = products[:k2, :k1].reshape(m, b)
    return y, shards, products


def reference_product(a, x):
    """Oracle: plain ``A @ x``."""
    return jnp.dot(a, x, preferred_element_type=jnp.float32)
