"""L2 correctness: the hierarchical pipeline in JAX.

Validates the end-to-end encode→compute→decode semantics that the Rust
coordinator mirrors, including the Fig. 3 toy example's structure.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def systematic_generator(key, n, k):
    """[I_k; P] with Gaussian parity — mirrors the Rust generator's
    structure (not its exact values; tests only need *a* valid MDS)."""
    p = jax.random.normal(key, (n - k, k), dtype=jnp.float32) / np.sqrt(k)
    return jnp.concatenate([jnp.eye(k, dtype=jnp.float32), p])


@pytest.mark.parametrize(
    "n1,k1,n2,k2,m,d,b",
    [
        (3, 2, 3, 2, 8, 4, 1),    # the paper's Fig. 3 parameters
        (4, 2, 4, 2, 16, 8, 2),
        (5, 3, 4, 2, 12, 16, 1),
    ],
)
def test_pipeline_recovers_product(n1, k1, n2, k2, m, d, b):
    keys = jax.random.split(jax.random.PRNGKey(m + n1), 4)
    a = jax.random.normal(keys[0], (m, d), dtype=jnp.float32)
    x = jax.random.normal(keys[1], (d, b), dtype=jnp.float32)
    g_outer = systematic_generator(keys[2], n2, k2)
    g_inner = systematic_generator(keys[3], n1, k1)
    y, shards, products = model.hierarchical_pipeline(a, x, g_outer, g_inner)
    np.testing.assert_allclose(
        y, model.reference_product(a, x), rtol=1e-4, atol=1e-4
    )
    assert shards.shape == (n2, n1, m // (k1 * k2), d)
    assert products.shape == (n2, n1, m // (k1 * k2), b)


def test_fig3_parity_structure():
    """Fig. 3: with sum-parity generators, Â_{3,j} = Â_{1,j} + Â_{2,j}
    and Â_{i,3} = Â_{i,1} + Â_{i,2}."""
    m, d = 8, 4
    a = jax.random.normal(jax.random.PRNGKey(0), (m, d), dtype=jnp.float32)
    # (3,2) sum-parity generator — exactly the paper's toy code.
    g = jnp.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]], dtype=jnp.float32)
    shards = model.hierarchical_encode(a, g, g)
    np.testing.assert_allclose(
        shards[2], shards[0] + shards[1], rtol=1e-5, atol=1e-5
    )
    for i in range(3):
        np.testing.assert_allclose(
            shards[i, 2], shards[i, 0] + shards[i, 1], rtol=1e-5, atol=1e-5
        )


def test_worker_task_is_tuple_of_product():
    key = jax.random.PRNGKey(1)
    shard = jax.random.normal(key, (16, 32), dtype=jnp.float32)
    x = jax.random.normal(key, (32, 4), dtype=jnp.float32)
    out = model.worker_task(shard, x)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(
        out[0], ref.shard_matmul_ref(shard, x), rtol=1e-5, atol=1e-5
    )


def test_encode_task_matches_ref():
    key0, key1 = jax.random.split(jax.random.PRNGKey(2))
    g = jax.random.normal(key0, (4, 2), dtype=jnp.float32)
    blocks = jax.random.normal(key1, (2, 8, 4), dtype=jnp.float32)
    out = model.encode_task(g, blocks)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(
        out[0], ref.encode_blocks_ref(g, blocks), rtol=1e-5, atol=1e-5
    )


def test_encode_rejects_indivisible_rows():
    a = jnp.zeros((10, 4), dtype=jnp.float32)  # 10 % (2*2) != 0
    g = systematic_generator(jax.random.PRNGKey(3), 3, 2)
    with pytest.raises(AssertionError):
        model.hierarchical_encode(a, g, g)
