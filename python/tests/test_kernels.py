"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

The CORE correctness signal for the compile path: every kernel must be
bit-for-bit close to its reference over a sweep of shapes, block sizes
and value ranges, including shapes that don't divide the preferred tile
sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import coded_matvec, encode, ref


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


@pytest.mark.parametrize(
    "r,d,b",
    [
        (1, 1, 1),
        (8, 16, 1),
        (16, 32, 4),
        (64, 128, 8),
        (256, 128, 4),
        (100, 60, 3),   # non-power-of-two
        (7, 13, 5),     # primes: forces 1-sized fallback tiles
    ],
)
def test_shard_matmul_matches_ref(r, d, b):
    k0, k1 = jax.random.split(jax.random.PRNGKey(r * 1000 + d + b))
    shard = rand(k0, (r, d))
    x = rand(k1, (d, b))
    got = coded_matvec.shard_matmul(shard, x)
    want = ref.shard_matmul_ref(shard, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_r", [1, 8, 64, 256, 1024])
@pytest.mark.parametrize("block_b", [1, 128])
def test_shard_matmul_block_size_invariance(block_r, block_b):
    """Output must not depend on the tiling."""
    k0, k1 = jax.random.split(jax.random.PRNGKey(0))
    shard = rand(k0, (64, 32))
    x = rand(k1, (32, 4))
    got = coded_matvec.shard_matmul(shard, x, block_r=block_r, block_b=block_b)
    want = ref.shard_matmul_ref(shard, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_shard_matmul_large_values():
    """No overflow/accuracy collapse at realistic magnitudes."""
    k0, k1 = jax.random.split(jax.random.PRNGKey(7))
    shard = rand(k0, (32, 64), scale=1e3)
    x = rand(k1, (64, 2), scale=1e3)
    got = coded_matvec.shard_matmul(shard, x)
    want = ref.shard_matmul_ref(shard, x)
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize(
    "n,k,r,d",
    [
        (3, 2, 8, 4),
        (6, 3, 64, 32),
        (4, 2, 256, 128),
        (5, 5, 10, 10),   # rate-1 code
        (7, 3, 9, 11),    # odd shapes
    ],
)
def test_encode_blocks_matches_ref(n, k, r, d):
    k0, k1 = jax.random.split(jax.random.PRNGKey(n * 100 + k))
    g = rand(k0, (n, k))
    blocks = rand(k1, (k, r, d))
    got = encode.encode_blocks(g, blocks)
    want = ref.encode_blocks_ref(g, blocks)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_encode_systematic_prefix_identity():
    """With a systematic generator [I; P], coded[:k] == blocks."""
    n, k, r, d = 5, 3, 16, 8
    key = jax.random.PRNGKey(3)
    blocks = rand(key, (k, r, d))
    g = jnp.concatenate(
        [jnp.eye(k, dtype=jnp.float32),
         rand(jax.random.PRNGKey(4), (n - k, k))]
    )
    coded = encode.encode_blocks(g, blocks)
    np.testing.assert_allclose(coded[:k], blocks, rtol=1e-6, atol=1e-6)


def test_encode_linearity():
    """encode(a·B1 + b·B2) == a·encode(B1) + b·encode(B2)."""
    n, k, r, d = 4, 2, 8, 8
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(9), 3)
    g = rand(k0, (n, k))
    b1 = rand(k1, (k, r, d))
    b2 = rand(k2, (k, r, d))
    lhs = encode.encode_blocks(g, 2.0 * b1 - 3.0 * b2)
    rhs = 2.0 * encode.encode_blocks(g, b1) - 3.0 * encode.encode_blocks(g, b2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_vmem_footprint_estimate_reasonable():
    """Tiling must keep a single program's working set under TPU VMEM."""
    fp = coded_matvec.vmem_footprint_bytes(4096, 512, 128)
    assert fp < 16 * 1024 * 1024, f"footprint {fp} exceeds 16 MiB VMEM"
    assert fp > 0
