"""AOT pipeline integrity: HLO text artifacts + manifest.

Verifies every artifact lowers, parses as HLO text (structural checks),
and that the manifest is complete and consistent — the contract the Rust
runtime's artifact loader depends on.
"""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.emit(str(out), verbose=False)
    return str(out), manifest


def test_manifest_lists_all_specs(emitted):
    _, manifest = emitted
    assert manifest["version"] == 1
    names = {e["name"] for e in manifest["artifacts"]}
    assert len(names) == len(aot.WORKER_SPECS) + len(aot.ENCODE_SPECS)
    for r, d, b in aot.WORKER_SPECS:
        assert f"worker_matvec_r{r}_d{d}_b{b}" in names
    for n, k, r, d in aot.ENCODE_SPECS:
        assert f"encode_n{n}_k{k}_r{r}_d{d}" in names


def test_artifacts_exist_and_are_hlo_text(emitted):
    out, manifest = emitted
    for e in manifest["artifacts"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), f"missing {e['file']}"
        text = open(path).read()
        # Structural sanity of HLO text.
        assert "HloModule" in text, f"{e['file']}: no HloModule header"
        assert "ROOT" in text, f"{e['file']}: no ROOT instruction"
        assert "f32" in text, f"{e['file']}: expected f32 types"


def test_manifest_shapes_match_hlo_entry(emitted):
    out, manifest = emitted
    for e in manifest["artifacts"]:
        text = open(os.path.join(out, e["file"])).read()
        # Every input shape should appear as a parameter type in the HLO.
        for shape in e["inputs"]:
            token = "f32[" + ",".join(str(s) for s in shape) + "]"
            assert token in text, f"{e['file']}: {token} not found"


def test_manifest_file_written(emitted):
    out, manifest = emitted
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk == manifest


def test_worker_lowering_deterministic():
    """Same spec → same HLO text (stable artifact hashing)."""
    t1 = aot.to_hlo_text(aot.lower_worker(16, 32, 1))
    t2 = aot.to_hlo_text(aot.lower_worker(16, 32, 1))
    assert t1 == t2


def test_distinct_specs_distinct_hlo():
    t1 = aot.to_hlo_text(aot.lower_worker(16, 32, 1))
    t2 = aot.to_hlo_text(aot.lower_worker(16, 32, 2))
    assert t1 != t2
