"""Hypothesis sweeps: kernel == oracle over randomized shapes/values.

Property-based layer on top of the parametrized tests — hypothesis
explores the shape space (including degenerate 1-sized axes) and value
distributions far more densely than a hand-written grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import coded_matvec, encode, ref

# Interpret-mode pallas is slow; keep dims modest but irregular.
dims = st.integers(min_value=1, max_value=48)
small_dims = st.integers(min_value=1, max_value=12)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
scales = st.sampled_from([1e-3, 1.0, 1e3])


@settings(max_examples=25, deadline=None)
@given(r=dims, d=dims, b=small_dims, seed=seeds, scale=scales)
def test_shard_matmul_property(r, d, b, seed, scale):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    shard = scale * jax.random.normal(k0, (r, d), dtype=jnp.float32)
    x = scale * jax.random.normal(k1, (d, b), dtype=jnp.float32)
    got = coded_matvec.shard_matmul(shard, x)
    want = ref.shard_matmul_ref(shard, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


@settings(max_examples=20, deadline=None)
@given(n=small_dims, k=small_dims, r=dims, d=small_dims, seed=seeds)
def test_encode_blocks_property(n, k, r, d, seed):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.normal(k0, (n, k), dtype=jnp.float32)
    blocks = jax.random.normal(k1, (k, r, d), dtype=jnp.float32)
    got = encode.encode_blocks(g, blocks)
    want = ref.encode_blocks_ref(g, blocks)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(r=dims, d=dims, seed=seeds)
def test_matmul_zero_and_identity_laws(r, d, seed):
    """Â @ 0 == 0; with square Â, Â @ I == Â."""
    key = jax.random.PRNGKey(seed)
    shard = jax.random.normal(key, (r, d), dtype=jnp.float32)
    zero = jnp.zeros((d, 2), dtype=jnp.float32)
    np.testing.assert_array_equal(
        coded_matvec.shard_matmul(shard, zero), jnp.zeros((r, 2))
    )
    eye = jnp.eye(d, dtype=jnp.float32)
    np.testing.assert_allclose(
        coded_matvec.shard_matmul(shard, eye), shard, rtol=1e-5, atol=1e-5
    )
