//! Determinism suite: parallel decode must equal serial decode
//! **bit-for-bit** for every scheme at every thread count, and the
//! packed GEMM must agree with the naive oracle at awkward shapes.
//!
//! This is the contract that makes `decode_threads` safe to turn up in
//! production: the pool only changes wall-clock, never results.

use hiercode::coding::{build_scheme_with, compute_all_products, select_results, SchemeKind};
use hiercode::linalg::{lu::LuFactors, ops, Matrix};
use hiercode::parallel::DecodePool;
use hiercode::sim::engine::{replay_decode, sample_arrival_order};
use hiercode::sim::straggler::StragglerModel;
use hiercode::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 8];

fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
}

/// Batch decode (session replay) of a shuffled, parity-heavy arrival
/// order: identical bits and flops at decode_threads ∈ {1, 2, 8}.
#[test]
fn all_schemes_decode_bit_identically_at_any_thread_count() {
    let mut r = Rng::new(4242);
    for kind in SchemeKind::ALL {
        let serial = build_scheme_with(kind, 4, 2, 4, 2, 1).unwrap();
        // Large enough that the per-block RHS spans several solve
        // panels, so the pooled panel fan-out actually engages.
        let rows = serial.row_divisor() * 64;
        let a = random_matrix(&mut r, rows, 6);
        let x = random_matrix(&mut r, 6, 3);
        let shards = serial.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Shuffled full arrival order: the session consumes the prefix
        // it needs, which lands on parity shards for every scheme.
        let mut order: Vec<usize> = (0..serial.num_workers()).collect();
        r.shuffle(&mut order);
        let subset = select_results(&all, &order);
        let expect = ops::matmul(&a, &x);
        let reference = serial.decode(&subset, rows).unwrap();
        assert!(
            reference.result.max_abs_diff(&expect) < 1e-6,
            "{kind}: serial decode wrong"
        );
        for threads in THREADS {
            let scheme = build_scheme_with(kind, 4, 2, 4, 2, threads).unwrap();
            let out = scheme.decode(&subset, rows).unwrap();
            assert_eq!(
                reference.result.data(),
                out.result.data(),
                "{kind} at {threads} threads: bits diverge from serial"
            );
            assert_eq!(
                reference.flops, out.flops,
                "{kind} at {threads} threads: flop accounting diverges"
            );
        }
    }
}

/// The simulator's session replay — the same decoders the live
/// coordinator runs — is equally deterministic across thread counts.
#[test]
fn replay_decode_bit_identical_across_thread_counts() {
    let mut r = Rng::new(77);
    let a = random_matrix(&mut r, 32, 4);
    let x = random_matrix(&mut r, 4, 2);
    for kind in SchemeKind::ALL {
        let order = sample_arrival_order(16, &StragglerModel::exp(10.0), &mut r).unwrap();
        let reference = {
            let scheme = build_scheme_with(kind, 4, 2, 4, 2, 1).unwrap();
            replay_decode(scheme.as_ref(), &a, &x, &order).unwrap()
        };
        for threads in THREADS {
            let scheme = build_scheme_with(kind, 4, 2, 4, 2, threads).unwrap();
            let replay = replay_decode(scheme.as_ref(), &a, &x, &order).unwrap();
            assert_eq!(replay.pushed, reference.pushed, "{kind}");
            assert_eq!(
                replay.output.result.data(),
                reference.output.result.data(),
                "{kind} at {threads} threads"
            );
            assert_eq!(replay.output.flops, reference.output.flops, "{kind}");
        }
    }
}

/// Packed GEMM vs the naive oracle at the awkward shapes: 1×n, n×1,
/// and non-multiples of the microtile/panel sizes.
#[test]
fn packed_gemm_matches_naive_at_awkward_shapes() {
    let mut r = Rng::new(7);
    for (m, k, n) in [
        (1usize, 17usize, 9usize), // 1×n row vector out
        (9, 17, 1),                // n×1 column vector out
        (1, 1, 1),
        (2, 3, 2),
        (5, 257, 6),    // k one past the KC=256 panel
        (6, 511, 1030), // non-multiple of every block size
        (63, 64, 65),
        (4, 4, 4),
    ] {
        let a = random_matrix(&mut r, m, k);
        let b = random_matrix(&mut r, k, n);
        let naive = ops::matmul_naive(&a, &b);
        let packed = ops::matmul(&a, &b);
        assert!(
            naive.max_abs_diff(&packed) < 1e-10,
            "{m}x{k}x{n}: packed kernel diverges from oracle by {}",
            naive.max_abs_diff(&packed)
        );
        // And row-parallel execution is bit-identical to serial.
        for threads in THREADS {
            let pool = DecodePool::new(threads).unwrap();
            let par = ops::matmul_with(&a, &b, &pool);
            assert_eq!(packed.data(), par.data(), "{m}x{k}x{n} t={threads}");
        }
    }
}

/// The blocked multi-RHS solve agrees with per-column solves and is
/// thread-count invariant (column panels are independent).
#[test]
fn blocked_solve_matches_columns_and_threads() {
    let mut r = Rng::new(11);
    let n = 24;
    let mut a = random_matrix(&mut r, n, n);
    for i in 0..n {
        a[(i, i)] += n as f64;
    }
    let f = LuFactors::factorize(&a).unwrap();
    let b = random_matrix(&mut r, n, 300);
    let serial = f.solve_matrix(&b).unwrap();
    for j in [0, 127, 128, 299] {
        let bj: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
        let xj = f.solve_vec(&bj).unwrap();
        for i in 0..n {
            assert!((serial[(i, j)] - xj[i]).abs() < 1e-9, "col {j} row {i}");
        }
    }
    for threads in THREADS {
        let pool = DecodePool::new(threads).unwrap();
        let mut scratch = Vec::new();
        let par = f.solve_matrix_with(&b, &pool, &mut scratch).unwrap();
        assert_eq!(serial.data(), par.data(), "threads={threads}");
    }
}

/// Property: [`DecodePool::map`] returns results in input order,
/// bit-identical to the serial map, for random task counts × thread
/// counts 1/2/4/8 (seeded; reproduce with `HIERCODE_CHECK_SEED`).
/// Uneven per-task sizes make the work-stealing counter actually
/// reorder execution, so the in-order guarantee is doing real work.
#[test]
fn decode_pool_map_property_order_and_bits() {
    use hiercode::util::check::{check, Gen};
    check("DecodePool::map == serial map, in order", 60, |g: &mut Gen| {
        let tasks = g.usize_in(0..65);
        let inputs: Vec<Vec<f64>> = (0..tasks)
            .map(|_| g.vec_f64(g.usize_in(1..33), -1e3, 1e3))
            .collect();
        let work = |v: &[f64]| -> f64 {
            v.iter()
                .enumerate()
                .map(|(i, x)| x * (i as f64 + 1.0).sqrt())
                .sum()
        };
        let serial: Vec<f64> = inputs.iter().map(|v| work(v)).collect();
        for threads in [1usize, 2, 4, 8] {
            let pool = DecodePool::new(threads).expect("valid pool width");
            let items: Vec<(usize, &[f64])> =
                inputs.iter().map(Vec::as_slice).enumerate().collect();
            let out = pool.map(items, |(i, v)| (i, work(v)));
            assert_eq!(out.len(), serial.len(), "threads={threads}: lost tasks");
            for (i, (j, val)) in out.iter().enumerate() {
                assert_eq!(i, *j, "threads={threads}: result out of input order");
                assert_eq!(
                    val.to_bits(),
                    serial[i].to_bits(),
                    "threads={threads}: bits diverge from serial at task {i}"
                );
            }
        }
    });
}

/// End-to-end: a live cluster configured with decode_threads ∈ {1, 2, 8}
/// returns the same (correct) answers — the config field reaches the
/// master/submaster sessions and never perturbs results.
#[test]
fn cluster_decode_threads_end_to_end() {
    use hiercode::config::schema::ClusterConfig;
    use hiercode::coordinator::Cluster;
    let mut r = Rng::new(1234);
    let a = random_matrix(&mut r, 16, 4);
    let x = vec![0.5, -1.0, 2.0, 0.25];
    let expect = ops::matvec(&a, &x);
    for threads in THREADS {
        let mut config = ClusterConfig::demo(4, 2, 4, 2);
        config.runtime.decode_threads = threads;
        config.straggler.enabled = false;
        let cluster = Cluster::launch(&config, &a).unwrap();
        let y = cluster.submit(x.clone()).unwrap().wait().unwrap();
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-6, "threads={threads}");
        }
        cluster.shutdown();
    }
}
