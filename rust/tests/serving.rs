//! Integration tests for the multi-tenant job service: concurrency,
//! admission control (Busy backpressure + deadline shedding) and the
//! graceful-shutdown drain guarantee.

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::fault::FaultConfig;
use hiercode::coordinator::{ClusterCore, SubmitOptions};
use hiercode::linalg::{ops, Matrix};
use hiercode::util::rng::Rng;
use hiercode::Error;
use std::time::{Duration, Instant};

fn test_matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
}

/// The request vector client `c` sends on its `i`-th iteration —
/// deterministic, so independent runs can be compared request by
/// request.
fn request_vec(d: usize, client: usize, iter: usize) -> Vec<f64> {
    let mut r = Rng::new(0xC0FFEE ^ ((client as u64) << 16) ^ (iter as u64));
    (0..d).map(|_| r.uniform(-1.0, 1.0)).collect()
}

/// Faults that kill every parity worker and the parity group of a
/// (3,2)×(3,2) hierarchical deployment: the only shards that can ever
/// arrive are systematic, so both decode levels take the pure-reshuffle
/// fast path (0 flops) — which is arrival-order-invariant, making
/// results **bit-deterministic** across runs and thread interleavings.
fn systematic_only_faults() -> FaultConfig {
    FaultConfig::none()
        .with_dead_workers(&[(0, 2), (1, 2), (2, 2)])
        .with_dead_links(&[2])
}

fn stress_config() -> ClusterConfig {
    let mut config = ClusterConfig::demo(3, 2, 3, 2);
    config.straggler.enabled = true;
    config.straggler.scale = 0.0005;
    config.serving.queue_cap = 1024; // no Busy in the bit-match runs
    // One request per job: every request's worker GEMM has the same
    // shape in the single-client and concurrent runs, so the bitwise
    // comparison isolates concurrency (not batch-width coalescing).
    config.batching.max_batch = 1;
    config
}

const MODELS: [&str; 2] = ["alpha", "beta"];
const CLIENTS: usize = 8;
const ITERS: usize = 12;

/// Run the deterministic request set and return every result, keyed
/// `[client][iter]`. `concurrent` = all 8 clients on their own threads
/// (each a closed loop), else one thread submits everything in order.
fn run_request_set(concurrent: bool) -> Vec<Vec<Vec<f64>>> {
    let config = stress_config();
    let core = ClusterCore::launch_with_faults(&config, systematic_only_faults())
        .unwrap();
    let a0 = test_matrix(8, 4, 50);
    let a1 = test_matrix(16, 3, 51);
    core.register_model(MODELS[0], &a0).unwrap();
    core.register_model(MODELS[1], &a1).unwrap();
    let dims = [4usize, 3usize];
    let results: Vec<Vec<Vec<f64>>> = if concurrent {
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let client = core.handle();
            joins.push(std::thread::spawn(move || {
                (0..ITERS)
                    .map(|i| {
                        let model = MODELS[i % 2];
                        let x = request_vec(dims[i % 2], c, i);
                        client
                            .submit_to(model, x)
                            .expect("admission")
                            .wait()
                            .expect("result")
                    })
                    .collect::<Vec<Vec<f64>>>()
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    } else {
        let client = core.handle();
        (0..CLIENTS)
            .map(|c| {
                (0..ITERS)
                    .map(|i| {
                        let model = MODELS[i % 2];
                        let x = request_vec(dims[i % 2], c, i);
                        client
                            .submit_to(model, x)
                            .expect("admission")
                            .wait()
                            .expect("result")
                    })
                    .collect()
            })
            .collect()
    };
    let snap = core.metrics();
    // Exactly-once accounting: every submission was accepted and
    // completed; nothing bounced, shed, failed or leaked.
    let total = (CLIENTS * ITERS) as u64;
    assert_eq!(snap.requests, total);
    assert_eq!(snap.rejected, 0);
    assert_eq!(snap.shed, 0);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.queue_depth, 0);
    assert_eq!(
        snap.decode_flops, 0,
        "systematic-only faults must keep both decode levels on the \
         reshuffle fast path (the bit-determinism precondition)"
    );
    let by_name: std::collections::HashMap<_, _> = snap
        .models
        .iter()
        .map(|m| (m.name.as_str(), m))
        .collect();
    for name in MODELS {
        let m = by_name[name];
        assert_eq!(m.accepted, total / 2, "model {name}");
        assert_eq!(m.completed, total / 2, "model {name}");
        assert_eq!(m.queued, 0, "model {name}");
    }
    core.shutdown();
    // Correctness against the oracle (f32-narrowed shards: 1e-4).
    for c in 0..CLIENTS {
        for i in 0..ITERS {
            let (a, d) = if i % 2 == 0 { (&a0, 4) } else { (&a1, 3) };
            let expect = ops::matvec(a, &request_vec(d, c, i));
            let got = &results[c][i];
            assert_eq!(got.len(), expect.len());
            for (g, w) in got.iter().zip(expect.iter()) {
                assert!((g - w).abs() < 1e-4, "client {c} iter {i}");
            }
        }
    }
    results
}

/// Satellite: ≥8 threads against ≥2 models — results bit-match a
/// single-client run of the identical request set, and every job is
/// accounted exactly once.
#[test]
fn multi_client_stress_bit_matches_single_client_run() {
    let single = run_request_set(false);
    let concurrent = run_request_set(true);
    for c in 0..CLIENTS {
        for i in 0..ITERS {
            assert_eq!(
                single[c][i], concurrent[c][i],
                "client {c} iter {i}: concurrent result must bit-match the \
                 single-client run"
            );
        }
    }
}

/// Acceptance: under saturating load, submissions beyond the queue cap
/// return `Error::Busy` — and are accounted exactly once, while every
/// accepted request still completes.
#[test]
fn saturating_load_bounces_busy_and_accounts_exactly_once() {
    let mut config = ClusterConfig::demo(2, 1, 2, 1);
    config.serving.queue_cap = 2;
    // A wide batch window so the queue actually fills.
    config.batching.max_batch = 1024;
    config.batching.max_wait_ms = 150.0;
    let core = ClusterCore::launch(&config).unwrap();
    core.register_model("m", &test_matrix(4, 2, 60)).unwrap();
    // 6 threads × 8 attempts against a cap of 2.
    let mut joins = Vec::new();
    for t in 0..6 {
        let client = core.handle();
        joins.push(std::thread::spawn(move || {
            let mut accepted = Vec::new();
            let mut busy = 0u64;
            for i in 0..8 {
                match client.submit_to("m", vec![t as f64, i as f64]) {
                    Ok(h) => accepted.push(h),
                    Err(Error::Busy { model }) => {
                        assert_eq!(model, "m");
                        busy += 1;
                        // Closed-loop backoff so accepted work drains.
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
            let completed = accepted
                .into_iter()
                .map(|h| h.wait().expect("accepted request must complete"))
                .count() as u64;
            (completed, busy)
        }));
    }
    let (mut completed, mut busy) = (0u64, 0u64);
    for j in joins {
        let (c, b) = j.join().unwrap();
        completed += c;
        busy += b;
    }
    assert_eq!(completed + busy, 48, "every attempt accounted exactly once");
    assert!(busy > 0, "cap 2 under 6 greedy clients must bounce");
    let snap = core.metrics();
    assert_eq!(snap.requests, completed);
    assert_eq!(snap.rejected, busy);
    assert_eq!(snap.shed, 0);
    assert_eq!(snap.queue_depth, 0, "queue drains to empty");
    core.shutdown();
}

/// Deadline shedding: a request that outlives its admission deadline in
/// the batcher is shed with `DeadlineExceeded`, exactly once.
#[test]
fn expired_deadline_sheds_with_explicit_error() {
    let mut config = ClusterConfig::demo(2, 1, 2, 1);
    // The batch window (120ms) far exceeds the deadline (20ms): the
    // request expires while queued.
    config.batching.max_batch = 1024;
    config.batching.max_wait_ms = 120.0;
    config.serving.default_deadline_ms = 20.0;
    let core = ClusterCore::launch(&config).unwrap();
    core.register_model("m", &test_matrix(4, 2, 61)).unwrap();
    let client = core.handle();
    let shed = client.submit_to("m", vec![1.0, 2.0]).unwrap();
    // A per-request deadline override outlives the window and succeeds.
    let kept = client
        .submit_with(
            vec![3.0, 4.0],
            SubmitOptions::to_model("m").with_deadline(Duration::from_secs(30)),
        )
        .unwrap();
    assert!(matches!(shed.wait(), Err(Error::DeadlineExceeded)));
    assert!(kept.wait().is_ok());
    let snap = core.metrics();
    assert_eq!(snap.shed, 1, "shed exactly once");
    assert_eq!(snap.requests, 2);
    assert_eq!(snap.queue_depth, 0);
    let m = &snap.models[0];
    assert_eq!(m.shed, 1);
    assert_eq!(m.completed, 1);
    core.shutdown();
}

/// Satellite regression: graceful shutdown drains — every accepted
/// request resolves (reply or error); no `JobHandle` ever hangs.
#[test]
fn shutdown_drains_inflight_jobs_to_completion() {
    let mut config = ClusterConfig::demo(3, 2, 3, 2);
    config.straggler.enabled = true;
    config.straggler.scale = 0.002; // real in-flight work at shutdown
    let core = ClusterCore::launch(&config).unwrap();
    let a = test_matrix(8, 4, 62);
    core.register_model("m", &a).unwrap();
    let client = core.handle();
    let handles: Vec<_> = (0..16)
        .map(|i| client.submit_to("m", request_vec(4, 0, i)).unwrap())
        .collect();
    // Shut down immediately: queued + in-flight work must drain.
    core.shutdown();
    for (i, h) in handles.into_iter().enumerate() {
        let outcome = h
            .try_wait()
            .expect("after shutdown every handle must be resolved");
        let y = outcome.unwrap_or_else(|e| {
            panic!("drained request {i} should have completed, got: {e}")
        });
        let expect = ops::matvec(&a, &request_vec(4, 0, i));
        for (g, w) in y.iter().zip(expect.iter()) {
            assert!((g - w).abs() < 1e-4, "request {i}");
        }
    }
}

/// Satellite regression: a `PartialResult` arriving after the master's
/// `gc_done_jobs` evicted its `Done` tombstone must be counted as a
/// **late delivery** (`late_partials`), not silently dropped as an
/// unknown job. This ordering is load-bearing for partial-work mode,
/// where straggler sub-results keep streaming after a group decoded.
#[test]
fn late_partial_after_tombstone_gc_counts_as_late_delivery() {
    use hiercode::coding::{CodedScheme, HierarchicalCode};
    use hiercode::coordinator::chaos::LivenessConfig;
    use hiercode::coordinator::master;
    use hiercode::coordinator::messages::{JobBroadcast, MasterMsg, ModelId, PartialResult};
    use hiercode::coordinator::metrics::Metrics;
    use hiercode::coordinator::JobId;
    use hiercode::sync::WallClock;
    use std::sync::{mpsc, Arc};

    let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
    let (master_tx, master_rx) = mpsc::channel();
    let metrics = Arc::new(Metrics::new());
    let scheme: Arc<dyn CodedScheme> = code;
    let h = master::spawn(
        scheme,
        vec![],
        Arc::clone(&metrics),
        Duration::from_secs(5),
        LivenessConfig::disabled(),
        Arc::new(WallClock::new()),
        master_rx,
    )
    .expect("spawn master");
    // 8193 reply-less batches leave one Done tombstone each; the
    // 8193rd insert crosses the master's DONE_JOBS_BOUND (8192) and
    // the GC evicts every tombstone.
    for id in 0..8193u64 {
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(id),
                    model: ModelId(0),
                    out_rows: 2,
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![],
            })
            .unwrap();
    }
    // A straggler partial for an evicted tombstone: late delivery…
    master_tx
        .send(MasterMsg::Partial(PartialResult {
            id: JobId(0),
            shard: 0,
            data: Matrix::identity(1),
            decoded: true,
            decode_flops: 0,
            finished_at: Instant::now(),
        }))
        .unwrap();
    // …and one for a still-present tombstone: the same accounting.
    master_tx
        .send(MasterMsg::Batch {
            job: JobBroadcast {
                id: JobId(9000),
                model: ModelId(0),
                out_rows: 2,
                x: Arc::new(Matrix::identity(1)),
            },
            replies: vec![],
        })
        .unwrap();
    master_tx
        .send(MasterMsg::Partial(PartialResult {
            id: JobId(9000),
            shard: 0,
            data: Matrix::identity(1),
            decoded: true,
            decode_flops: 0,
            finished_at: Instant::now(),
        }))
        .unwrap();
    master_tx.send(MasterMsg::Drain).unwrap();
    h.join().unwrap();
    let s = metrics.snapshot();
    assert_eq!(
        s.late_partials, 2,
        "evicted-tombstone and live-tombstone partials are both late deliveries"
    );
    assert_eq!(s.completed, 0);
    assert_eq!(s.failed, 0);
}

/// The drain guarantee also holds when jobs can never complete (all
/// uplinks dead): the drain grace bounds the wait and every handle
/// resolves with an error instead of hanging.
#[test]
fn shutdown_never_hangs_even_when_jobs_cannot_complete() {
    let mut config = ClusterConfig::demo(2, 1, 2, 2);
    config.serving.drain_ms = 300.0;
    let faults = FaultConfig::none().with_dead_links(&[0, 1]);
    assert!(!faults.survivable_for(&config.code.topology));
    let core = ClusterCore::launch_with_faults(&config, faults).unwrap();
    core.register_model("m", &test_matrix(4, 2, 63)).unwrap();
    let client = core.handle();
    let handles: Vec<_> = (0..4)
        .map(|i| client.submit_to("m", vec![i as f64, 1.0]).unwrap())
        .collect();
    let t0 = Instant::now();
    core.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "shutdown must be bounded by the drain grace"
    );
    for h in handles {
        let outcome = h
            .try_wait()
            .expect("every handle must resolve across shutdown");
        assert!(outcome.is_err(), "undecodable jobs must fail, not hang");
    }
    // Late submissions are refused, not silently dropped.
    assert!(client.submit_to("m", vec![0.0, 0.0]).is_err());
}
