//! Integration: heterogeneous scenario-layer topologies end-to-end —
//! config `groups` → coding → live cluster → metrics — plus the
//! uniform-sugar bit-identity acceptance checks.

use hiercode::coding::{compute_all_products, select_results, CodedScheme};
use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::Cluster;
use hiercode::linalg::{ops, Matrix};
use hiercode::util::rng::Rng;

fn matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
}

/// A 3-group heterogeneous config with two distinct `(n1_g, k1_g)`
/// specs (row divisor lcm(2·2, 2·3) = 12).
const HET_CONFIG: &str = r#"{
    "code": {"scheme": "hierarchical", "k2": 2,
             "groups": [
               {"n1": 4, "k1": 2},
               {"n1": 5, "k1": 3, "mu1": 5.0},
               {"n1": 4, "k1": 2}
             ]},
    "straggler": {"model": "exponential", "mu1": 10.0, "mu2": 1.0,
                  "scale": 0.001},
    "runtime": {"use_pjrt": false, "decode_threads": 2},
    "seed": 11
}"#;

#[test]
fn heterogeneous_cluster_serves_correct_results_end_to_end() {
    let config = ClusterConfig::from_json_text(HET_CONFIG).unwrap();
    let a = matrix(24, 5, 1);
    let cluster = Cluster::launch(&config, &a).unwrap();
    assert_eq!(cluster.scheme().num_workers(), 13);
    let mut r = Rng::new(2);
    let xs: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..5).map(|_| r.uniform(-2.0, 2.0)).collect())
        .collect();
    let handles: Vec<_> = xs
        .iter()
        .map(|x| cluster.submit(x.clone()).unwrap())
        .collect();
    for (x, h) in xs.iter().zip(handles) {
        let y = h.wait().unwrap();
        let expect = ops::matvec(&a, x);
        for (i, (&got, &want)) in y.iter().zip(expect.iter()).enumerate() {
            assert!((got - want).abs() < 1e-3, "row {i}: {got} vs {want}");
        }
    }
    // Give stragglers a moment to drain so every product registers
    // (the per-message counters are bumped in pairs by the submaster;
    // snapshotting mid-drain could catch one of a pair).
    std::thread::sleep(std::time::Duration::from_millis(300));
    // Per-group observability: every arrival and group decode is
    // attributed to its group.
    let snap = cluster.metrics();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.per_group.len(), 3, "one breakdown per group");
    let product_sum: u64 = snap.per_group.iter().map(|g| g.products).sum();
    assert_eq!(product_sum, snap.worker_products);
    let decode_sum: u64 = snap.per_group.iter().map(|g| g.decodes).sum();
    assert_eq!(decode_sum, snap.group_decodes);
    assert!(
        snap.group_decodes >= snap.jobs * 2,
        "k2 = 2 group decodes per job minimum: {snap:?}"
    );
    for (g, gm) in snap.per_group.iter().enumerate() {
        if gm.decodes > 0 {
            assert!(
                gm.decode_mean >= 0.0,
                "group {g}: decode latency must be recorded"
            );
            // A group cannot decode with fewer products than its k1.
            let k1 = [2u64, 3, 2][g];
            assert!(
                gm.products >= k1,
                "group {g}: {} products < k1 = {k1}",
                gm.products
            );
        }
    }
    cluster.shutdown();
}

#[test]
fn heterogeneous_parallel_decode_bit_identical_to_serial() {
    // The same heterogeneous topology decoded through config-built
    // schemes at pool widths 1 vs 4/8: streaming-session (batch
    // replay) results and flop accounting must agree bit-for-bit.
    let mut config = ClusterConfig::from_json_text(HET_CONFIG).unwrap();
    config.runtime.decode_threads = 1;
    let serial = config.build_scheme().unwrap();
    let a = matrix(24, 4, 3);
    let x = matrix(4, 2, 4);
    let shards = serial.encode(&a).unwrap();
    let all = compute_all_products(&shards, &x);
    // Parity-heavy subset: last k1_g workers of groups 1 and 2
    // (flat offsets: group 0 = 0..4, group 1 = 4..9, group 2 = 9..13).
    let picks = [6usize, 7, 8, 11, 12];
    let o1 = serial.decode(&select_results(&all, &picks), 24).unwrap();
    assert!(o1.result.max_abs_diff(&ops::matmul(&a, &x)) < 1e-7);
    for threads in [4usize, 8] {
        config.runtime.decode_threads = threads;
        let parallel = config.build_scheme().unwrap();
        let o2 = parallel.decode(&select_results(&all, &picks), 24).unwrap();
        assert_eq!(
            o1.result.data(),
            o2.result.data(),
            "threads={threads}: parallel decode must be bit-identical"
        );
        assert_eq!(o1.flops, o2.flops, "threads={threads}");
    }
}

#[test]
fn uniform_config_topology_path_bit_identical_to_seed_construction() {
    // Acceptance: the uniform (n1,k1,n2,k2) sugar routed through the
    // expanded Topology must reproduce the direct homogeneous
    // construction bit-for-bit — same generators, same decode results,
    // same flops.
    let config = ClusterConfig::demo(4, 2, 3, 2);
    assert!(config.code.topology.is_uniform_code());
    let via_topology = config.build_scheme().unwrap();
    let direct = hiercode::coding::HierarchicalCode::homogeneous(4, 2, 3, 2).unwrap();
    assert_eq!(via_topology.name(), direct.name());
    let a = matrix(16, 5, 5);
    let x = matrix(5, 3, 6);
    let shards_t = via_topology.encode(&a).unwrap();
    let shards_d = direct.encode(&a).unwrap();
    assert_eq!(shards_t.len(), shards_d.len());
    for (st, sd) in shards_t.iter().zip(&shards_d) {
        assert_eq!(st.data(), sd.data(), "encode must be bit-identical");
    }
    let all = compute_all_products(&shards_d, &x);
    // Parity-heavy subset across groups 1 and 2.
    let picks = [6usize, 7, 10, 11];
    let ot = via_topology
        .decode(&select_results(&all, &picks), 16)
        .unwrap();
    let od = direct.decode(&select_results(&all, &picks), 16).unwrap();
    assert_eq!(ot.result.data(), od.result.data());
    assert_eq!(ot.flops, od.flops);
}

#[test]
fn heterogeneous_scheme_topology_roundtrips_through_cluster_launch() {
    // The scheme's topology is the config's topology, verbatim — the
    // coordinator spawns from the very same value the simulator
    // analyzes (zero drift).
    let config = ClusterConfig::from_json_text(HET_CONFIG).unwrap();
    let scheme = config.build_scheme().unwrap();
    assert_eq!(scheme.topology(), config.code.topology);
    // And the simulator consumes it directly.
    let est = hiercode::sim::montecarlo::expected_latency_topology(
        &config.code.topology,
        20_000,
        7,
        &hiercode::parallel::DecodePool::serial(),
    )
    .unwrap();
    assert!(est.mean.is_finite() && est.mean > 0.0);
    let ub = hiercode::sim::bounds::topology_upper(&config.code.topology).unwrap();
    assert!(
        est.mean <= ub + 3.0 * est.ci95,
        "E[T] {} must be below the topology upper bound {ub}",
        est.mean
    );
}
