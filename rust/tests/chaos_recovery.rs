//! Integration: dynamic faults against the live cluster — seeded churn
//! under load, crash/restart shard re-shipping, and the failure
//! detector's fast-fail guarantee when survivability breaks.

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::chaos::{self, FaultInjector};
use hiercode::coordinator::fault::FaultPlan;
use hiercode::coordinator::ClusterCore;
use hiercode::linalg::{ops, Matrix};
use hiercode::sync::WallClock;
use hiercode::util::rng::Rng;
use hiercode::Error;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
}

/// Demo grid with liveness on and tight detector timeouts, so a test
/// never waits seconds for a verdict.
fn chaos_config(n1: usize, k1: usize, n2: usize, k2: usize) -> ClusterConfig {
    let mut config = ClusterConfig::demo(n1, k1, n2, k2);
    config.chaos.liveness = true;
    config.chaos.heartbeat_ms = 5.0;
    config.chaos.suspect_ms = 40.0;
    config.chaos.dead_ms = 120.0;
    config.serving.default_deadline_ms = 30_000.0;
    config.serving.queue_cap = 64;
    config
}

/// Tentpole e2e: a seeded survivable churn schedule (one worker per
/// group crashing and restarting every round) runs against a serving
/// cluster while a closed-loop client submits — every job must
/// complete with correct results, and the chaos report must tally a
/// restart for every crash.
#[test]
fn churn_under_load_completes_all_jobs() {
    let config = chaos_config(3, 2, 3, 2);
    let core = ClusterCore::launch(&config).unwrap();
    let a = matrix(16, 4, 41);
    core.register_model("m", &a).unwrap();
    let plan = FaultPlan::survivable_churn(9, &config.code.topology, 800, 200);
    assert!(!plan.is_empty(), "the schedule must actually churn");
    let driver =
        chaos::spawn(core.injector(), plan, Arc::new(WallClock::new())).unwrap();
    let client = core.handle();
    // Closed loop past the end of the schedule, so the last restart's
    // re-shipped shards serve real jobs too.
    let t_end = Instant::now() + Duration::from_millis(1_000);
    let mut rng = Rng::new(7);
    let mut jobs = 0u64;
    while Instant::now() < t_end {
        let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let y = client
            .submit_to("m", x.clone())
            .unwrap()
            .wait_timeout(Duration::from_secs(20))
            .expect("every job under a survivable churn plan must complete");
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-3, "churn must not corrupt results");
        }
        jobs += 1;
    }
    assert!(jobs > 0);
    let report = driver.join().unwrap();
    assert!(report.crashes > 0, "the plan fired no crashes");
    assert_eq!(
        report.restarts, report.crashes,
        "every crash in a survivable plan is paired with a restart"
    );
    assert!(
        report.recovery_ms.iter().all(|ms| ms.is_finite()),
        "every respawn must succeed: {:?}",
        report.recovery_ms
    );
    core.shutdown();
}

/// Satellite: a restart re-ships the registered model's shards, and the
/// recovered worker's products are **bit-identical** to the fault-free
/// run. The (2,2)×(2,2) grid has a unique decode subset (every worker
/// and every group is needed), so any corruption or loss in the
/// re-shipped shard would change — or hang — the answer.
#[test]
fn reshipped_shards_bit_identical_after_restart() {
    let mut config = ClusterConfig::demo(2, 2, 2, 2);
    // No detector needed: the crash happens while the cluster is idle.
    config.chaos.liveness = false;
    let core = ClusterCore::launch(&config).unwrap();
    let a = matrix(8, 3, 42);
    core.register_model("m", &a).unwrap();
    let client = core.handle();
    let x = vec![0.5, -1.25, 2.0];
    let clean = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
    // Crash + restart one worker while idle; the restart must re-ship
    // the shard it dropped or the next job can never decode.
    let sup = core.supervisor();
    sup.worker_crash(0, 1);
    let ms = sup.worker_restart(0, 1);
    assert!(ms.is_finite(), "respawn failed");
    let recovered = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
    assert_eq!(
        clean, recovered,
        "re-shipped shards must reproduce bit-identical results"
    );
    core.shutdown();
}

/// Satellite: when faults push the cluster below k2 healthy groups,
/// jobs fail **fast** with `Error::Insufficient` — the detector sweeps
/// them out instead of letting them ride the 30s admission deadline.
#[test]
fn unsurvivable_severs_fail_fast_with_insufficient() {
    let config = chaos_config(3, 2, 3, 2);
    let core = ClusterCore::launch(&config).unwrap();
    let a = matrix(16, 4, 43);
    core.register_model("m", &a).unwrap();
    let client = core.handle();
    let x = vec![1.0, -1.0, 0.5, 2.0];
    // Sanity: the healthy cluster serves.
    assert!(client.submit_to("m", x.clone()).unwrap().wait().is_ok());
    // Two of three uplinks severed: 1 < k2 = 2 healthy groups remain.
    let inj = core.injector();
    inj.link_sever(0);
    inj.link_sever(1);
    // Let the detector age the quiet groups out (dead_ms = 120).
    std::thread::sleep(Duration::from_millis(250));
    let t0 = Instant::now();
    let err = client
        .submit_to("m", x)
        .unwrap()
        .wait_timeout(Duration::from_secs(10))
        .unwrap_err();
    assert!(
        matches!(err, Error::Insufficient { needed: 2, .. }),
        "expected Insufficient, got: {err}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "must fail fast, not ride the deadline: took {:?}",
        t0.elapsed()
    );
    core.shutdown();
}
