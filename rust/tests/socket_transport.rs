//! Loopback bit-identity: the socket transport must be a perfect
//! stand-in for the in-memory FIFO path.
//!
//! The same seeded job stream is served twice — once over
//! `MemoryTransport` (the oracle), once over a UDS `SocketHub` with one
//! `run_node` thread per group — and the outputs must agree to the bit
//! (`f64::to_bits`, not an epsilon), with the stream counters equal.
//! The wire codec, the node's seed replay and the hub's partial
//! mirroring all sit under this contract: any divergence is a
//! transport bug, because the no-redundancy demo grid leaves the
//! scheduler no freedom in which shards feed the decode.

use hiercode::config::schema::{ClusterConfig, TransportMode};
use hiercode::coordinator::ClusterCore;
use hiercode::linalg::Matrix;
use hiercode::transport::node::{run_node, NodeOptions};
use hiercode::transport::TransportAddr;
use hiercode::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const MODEL: &str = "loopback";
const ROWS: usize = 16;
const COLS: usize = 4;
const SEED: u64 = 2027;
const JOBS: usize = 4;

static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// A socket path no concurrent test (or stale run) is sitting on.
fn fresh_uds() -> String {
    let path = std::env::temp_dir().join(format!(
        "hiercode-lb-{}-{}.sock",
        std::process::id(),
        SOCK_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    format!("uds:{}", path.display())
}

/// No-redundancy grid: every shard is needed, so the memory and socket
/// runs must pick the same decode subset — any output difference is a
/// transport bug, not scheduler freedom.
fn demo_config() -> ClusterConfig {
    let mut config = ClusterConfig::demo(2, 2, 2, 2);
    config.seed = SEED;
    config.serving.queue_cap = 64;
    config
}

/// Serve `JOBS` seeded requests sequentially (submit-then-wait keeps
/// every batch at exactly one request, so the jobs counter is
/// deterministic across transports).
fn run_stream(core: &ClusterCore, rng: &mut Rng) -> Vec<Vec<f64>> {
    let client = core.handle();
    (0..JOBS)
        .map(|_| {
            let x: Vec<f64> = (0..COLS).map(|_| rng.uniform(-1.0, 1.0)).collect();
            client
                .submit_to(MODEL, x)
                .expect("submit")
                .wait_timeout(Duration::from_secs(15))
                .expect("job result")
        })
        .collect()
}

#[test]
fn socket_stream_is_bit_identical_to_memory() {
    // Reference run: in-memory FIFO transport.
    let config = demo_config();
    let core = ClusterCore::launch(&config).expect("memory launch");
    let mut rng = Rng::new(SEED);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| rng.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a).expect("register");
    let mem_out = run_stream(&core, &mut rng);
    let mem = core.metrics();
    core.shutdown();

    // Same seeded stream over a UDS hub, one node thread per group.
    let mut config = demo_config();
    config.transport.mode = TransportMode::Socket;
    config.transport.listen = fresh_uds();
    let addr = config.transport.listen.clone();
    let core = ClusterCore::launch(&config).expect("socket launch");
    let nodes: Vec<_> = (0..config.code.topology.n2())
        .map(|g| {
            let opts = NodeOptions {
                config: config.clone(),
                group: g,
                addr: TransportAddr::parse(&addr).expect("addr"),
                max_dial_ms: 10_000,
                dial_backoff_ms: 5,
                dial_backoff_max_ms: 50,
            };
            std::thread::spawn(move || run_node(opts))
        })
        .collect();
    assert!(core.wait_connected(10_000), "node threads never joined {addr}");

    let mut rng = Rng::new(SEED);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| rng.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a).expect("register");
    let sock_out = run_stream(&core, &mut rng);
    let sock = core.metrics();
    core.shutdown();
    for n in nodes {
        n.join().expect("node thread").expect("node exits clean");
    }

    // Bitwise equality, not an epsilon.
    assert_eq!(mem_out.len(), sock_out.len());
    for (job, (m, s)) in mem_out.iter().zip(&sock_out).enumerate() {
        assert_eq!(m.len(), s.len(), "job {job} length");
        for (col, (x, y)) in m.iter().zip(s).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "job {job} col {col}: {x} != {y}");
        }
    }

    // The stream counters agree exactly. (Worker products and decode
    // timings are node-local in socket mode and deliberately absent.)
    assert_eq!(mem.jobs, sock.jobs);
    assert_eq!(mem.completed, sock.completed);
    assert_eq!(mem.group_decodes, sock.group_decodes);
    assert_eq!(mem.decode_flops, sock.decode_flops);
    assert_eq!(sock.failed, 0);

    // The socket run really used the wire: traffic in both directions,
    // globally and on every group link — and a clean handshake.
    assert!(sock.transport_bytes_sent > 0);
    assert!(sock.transport_bytes_received > 0);
    assert!(sock.transport_frames_sent > 0);
    assert!(sock.transport_frames_received > 0);
    assert_eq!(sock.transport_handshake_failures, 0);
    assert_eq!(sock.per_group.len(), 2);
    for g in &sock.per_group {
        assert!(g.transport_bytes_sent > 0);
        assert!(g.transport_bytes_received > 0);
    }
    // The memory oracle reports no wire traffic at all.
    assert_eq!(mem.transport_bytes_sent, 0);
    assert_eq!(mem.transport_frames_received, 0);
}

#[test]
fn socket_launch_without_nodes_times_out_and_shuts_down_clean() {
    let mut config = demo_config();
    config.transport.mode = TransportMode::Socket;
    config.transport.listen = fresh_uds();
    let core = ClusterCore::launch(&config).expect("socket launch");
    assert!(!core.wait_connected(100), "no nodes were spawned");
    core.shutdown();
}
