//! Integration: the erasure-pattern LU cache under worker churn.
//!
//! A serving cluster with one systematic worker crashed per group pins
//! every group's surviving-shard set, so each group decode takes the
//! general (factorizing) path with a *constant* erasure pattern — the
//! steady traffic the cache exists for. The tests drive jobs through
//! that cluster and check the full contract end to end:
//!
//! * repeat patterns hit the cache, and every cached decode is
//!   **bit-identical** to the cold (cache-miss) decode, which runs the
//!   exact factorize-then-solve computation an uncached code performs
//!   (the unit suites in `coding::mds` / `coding::polynomial` pin the
//!   cached-vs-bare-code comparison directly);
//! * hit/miss/eviction counters stay consistent with the traffic and
//!   surface through `ClusterCore::metrics`;
//! * `worker_restart` re-ships shards and **invalidates** every cache
//!   (stale factors must not survive a topology repair), after which
//!   the same pattern re-factorizes once and serves hits again.

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::chaos::FaultInjector;
use hiercode::coordinator::ClusterCore;
use hiercode::linalg::{ops, Matrix};
use hiercode::util::rng::Rng;

fn matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
}

/// (3,2)×(2,2) grid with worker 0 of each group crashed: every group
/// decodes from the pinned parity-bearing set {1, 2} (general path,
/// one constant cache key per group), and the outer (2,2) decode is
/// the systematic fast path (no cache traffic). All decode subsets are
/// forced, so outputs are bit-reproducible across jobs and the
/// counter arithmetic below is exact, not probabilistic.
#[test]
fn repeat_patterns_hit_cache_and_restart_invalidates() {
    let mut config = ClusterConfig::demo(3, 2, 2, 2);
    // The crashes below happen while the cluster is idle; no detector.
    config.chaos.liveness = false;
    let core = ClusterCore::launch(&config).unwrap();
    let a = matrix(16, 3, 44);
    core.register_model("m", &a).unwrap();
    let sup = core.supervisor();
    sup.worker_crash(0, 0);
    sup.worker_crash(1, 0);

    let client = core.handle();
    let x = vec![0.5, -1.25, 2.0];
    let expect = ops::matvec(&a, &x);

    // Cold decode: one cache miss per group, and the factorize-path
    // result every later hit must reproduce bit for bit.
    let y0 = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
    for (got, want) in y0.iter().zip(expect.iter()) {
        assert!((got - want).abs() < 1e-6, "decode must match A·x");
    }
    // Steady traffic: the same erasure pattern 9 more times.
    for _ in 0..9 {
        let y = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
        assert_eq!(y, y0, "cache hits must be bit-identical to the cold decode");
    }
    let stats = sup.decode_cache_stats();
    assert_eq!(stats.misses, 2, "one factorization per group's pinned pattern");
    assert_eq!(stats.hits, 18, "9 repeat jobs × 2 group decodes");
    assert_eq!(stats.evictions, 0, "nothing invalidated yet");

    // The same numbers must surface through the cluster snapshot.
    let snap = core.metrics();
    assert_eq!(snap.decode_cache_hits, stats.hits);
    assert_eq!(snap.decode_cache_misses, stats.misses);
    assert_eq!(snap.decode_cache_evictions, stats.evictions);
    assert!(
        (snap.decode_cache_hit_rate - 0.9).abs() < 1e-12,
        "18 hits / 20 lookups, got {}",
        snap.decode_cache_hit_rate
    );

    // Restart re-ships worker (0,0)'s shards and must flush every
    // cache: the conservative invalidation boundary rules out stale
    // factors instead of arguing about them.
    let ms = sup.worker_restart(0, 0);
    assert!(ms.is_finite(), "respawn failed");
    let stats = sup.decode_cache_stats();
    assert_eq!(
        stats.evictions, 2,
        "both groups' cached factors dropped on restart"
    );

    // Re-pin the pattern and decode again: the invalidated caches
    // re-factorize once (bit-identical to the original cold decode),
    // then serve hits again.
    sup.worker_crash(0, 0);
    let y1 = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
    assert_eq!(y1, y0, "re-factorized decode must reproduce the original bits");
    let y2 = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
    assert_eq!(y2, y0);
    let stats = sup.decode_cache_stats();
    assert_eq!(stats.misses, 4, "each group re-factorizes once after the flush");
    assert_eq!(stats.hits, 20, "the second post-restart job hits both caches");

    // Registering a model also re-ships shards → same flush rule.
    let b = matrix(16, 3, 45);
    core.register_model("m2", &b).unwrap();
    let stats = sup.decode_cache_stats();
    assert_eq!(
        stats.evictions, 4,
        "register_model invalidates the repopulated caches"
    );
    core.shutdown();
}

/// A fully healthy grid keeps every group on the systematic fast path:
/// no factorizations, so the cache sees zero traffic and the snapshot
/// reports the no-data hit-rate sentinel (NaN → `"n/a"` in Display,
/// `null` in JSON). Guards against the cache inserting itself into the
/// zero-flop reshuffle path.
#[test]
fn systematic_fast_path_bypasses_cache() {
    let mut config = ClusterConfig::demo(2, 2, 2, 2);
    config.chaos.liveness = false;
    let core = ClusterCore::launch(&config).unwrap();
    let a = matrix(8, 3, 46);
    core.register_model("m", &a).unwrap();
    let client = core.handle();
    let x = vec![1.0, 2.0, -0.5];
    let expect = ops::matvec(&a, &x);
    for _ in 0..3 {
        let y = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-6);
        }
    }
    let stats = core.supervisor().decode_cache_stats();
    assert_eq!(stats.hits + stats.misses, 0, "fast path must not touch the cache");
    let snap = core.metrics();
    assert!(
        snap.decode_cache_hit_rate.is_nan(),
        "no lookups → the no-data sentinel, got {}",
        snap.decode_cache_hit_rate
    );
    core.shutdown();
}
