//! Streaming-decoder satellites: batch `decode` and the streaming
//! `Decoder` sessions must agree **bit-for-bit on result and flop
//! count** when fed the same arrivals — checked from *every* minimal
//! viable worker subset per scheme (exhaustive at small `(n, k)`,
//! sampled through the `util::check` proptest substitute at larger
//! sizes) — and the hierarchical session must do its inner decodes
//! incrementally, leaving strictly less work after the last arrival
//! than the batch path performs (the §IV / Table I claim).

use hiercode::coding::{
    build_scheme, compute_all_products, select_results, CodedScheme, HierarchicalCode,
    MdsCode, PolynomialCode, ProductCode, ReplicationCode, SchemeKind, WorkerResult,
};
use hiercode::linalg::{ops, Matrix};
use hiercode::util::check::check;
use hiercode::util::rng::Rng;

fn matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
}

/// Enumerate every `k`-subset of `[0, n)` in lexicographic order.
fn k_subsets(n: usize, k: usize) -> Vec<Vec<usize>> {
    fn rec(start: usize, n: usize, k: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == k {
            out.push(cur.clone());
            return;
        }
        for i in start..n {
            if n - i < k - cur.len() {
                break;
            }
            cur.push(i);
            rec(i + 1, n, k, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(0, n, k, &mut Vec::new(), &mut out);
    out
}

/// Push `subset_idx`'s results through a fresh session and assert the
/// output is bit-for-bit identical to the batch (replay) path, and
/// correct. On a *minimal* subset the session must become ready exactly
/// at the last arrival.
fn assert_stream_matches_batch(
    scheme: &dyn CodedScheme,
    all: &[WorkerResult],
    subset_idx: &[usize],
    rows: usize,
    expect: &Matrix,
    minimal: bool,
) {
    let subset = select_results(all, subset_idx);
    let batch = scheme
        .decode(&subset, rows)
        .unwrap_or_else(|e| panic!("{}: batch decode failed on {subset_idx:?}: {e}", scheme.name()));
    let mut session = scheme.decoder(rows, subset[0].data.cols());
    let mut ready_at = None;
    for (i, r) in subset.iter().enumerate() {
        let p = session
            .push(r.clone())
            .unwrap_or_else(|e| panic!("{}: push failed on {subset_idx:?}: {e}", scheme.name()));
        if p.is_ready() {
            ready_at = Some(i);
            break;
        }
    }
    if minimal {
        assert_eq!(
            ready_at,
            Some(subset.len() - 1),
            "{}: minimal subset {subset_idx:?} must become ready at its last arrival",
            scheme.name()
        );
    } else {
        assert!(ready_at.is_some(), "{}: {subset_idx:?}", scheme.name());
    }
    let out = session.finish().expect("finish after ready");
    assert_eq!(
        out.result.data(),
        batch.result.data(),
        "{}: stream/batch results differ on {subset_idx:?}",
        scheme.name()
    );
    assert_eq!(
        out.flops, batch.flops,
        "{}: stream/batch flops differ on {subset_idx:?}",
        scheme.name()
    );
    assert!(
        out.result.max_abs_diff(expect) < 1e-6,
        "{}: wrong product on {subset_idx:?} (err {})",
        scheme.name(),
        out.result.max_abs_diff(expect)
    );
}

#[test]
fn mds_every_minimal_subset_streams_exactly() {
    let code = MdsCode::new(5, 3).unwrap();
    let a = matrix(6, 4, 1);
    let x = matrix(4, 2, 2);
    let expect = ops::matmul(&a, &x);
    let all = compute_all_products(&code.encode(&a).unwrap(), &x);
    for subset in k_subsets(5, 3) {
        assert_stream_matches_batch(&code, &all, &subset, 6, &expect, true);
    }
}

#[test]
fn polynomial_every_minimal_subset_streams_exactly() {
    let code = PolynomialCode::new(5, 3).unwrap();
    let a = matrix(6, 4, 3);
    let x = matrix(4, 1, 4);
    let expect = ops::matmul(&a, &x);
    let all = compute_all_products(&code.encode(&a).unwrap(), &x);
    for subset in k_subsets(5, 3) {
        assert_stream_matches_batch(&code, &all, &subset, 6, &expect, true);
    }
}

#[test]
fn replication_every_minimal_subset_streams_exactly() {
    // (6,3): one replica per block — 2^3 minimal covers.
    let code = ReplicationCode::new(6, 3).unwrap();
    let a = matrix(6, 3, 5);
    let x = matrix(3, 1, 6);
    let expect = ops::matmul(&a, &x);
    let all = compute_all_products(&code.encode(&a).unwrap(), &x);
    for r0 in 0..2 {
        for r1 in 0..2 {
            for r2 in 0..2 {
                let subset = [r0, 2 + r1, 4 + r2];
                assert_stream_matches_batch(&code, &all, &subset, 6, &expect, true);
            }
        }
    }
}

#[test]
fn hierarchical_every_minimal_subset_streams_exactly() {
    // (3,2)×(3,2): choose any 2 of 3 groups, any 2 of 3 workers each —
    // 3 · 3 · 3 = 27 minimal viable subsets.
    let code = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
    let a = matrix(8, 3, 7);
    let x = matrix(3, 2, 8);
    let expect = ops::matmul(&a, &x);
    let all = compute_all_products(&code.encode(&a).unwrap(), &x);
    for groups in k_subsets(3, 2) {
        for wa in k_subsets(3, 2) {
            for wb in k_subsets(3, 2) {
                let mut subset: Vec<usize> =
                    wa.iter().map(|&j| groups[0] * 3 + j).collect();
                subset.extend(wb.iter().map(|&j| groups[1] * 3 + j));
                assert_stream_matches_batch(&code, &all, &subset, 8, &expect, true);
            }
        }
    }
}

#[test]
fn product_every_minimal_subset_streams_exactly() {
    // (3,2)×(3,2): every size-4 subset (the information minimum) that
    // peeling can decode, per `can_decode`.
    let code = ProductCode::new(3, 2, 3, 2).unwrap();
    let a = matrix(8, 3, 9);
    let x = matrix(3, 1, 10);
    let expect = ops::matmul(&a, &x);
    let all = compute_all_products(&code.encode(&a).unwrap(), &x);
    let mut viable = 0usize;
    for subset in k_subsets(9, 4) {
        if code.can_decode(&subset) {
            viable += 1;
            assert_stream_matches_batch(&code, &all, &subset, 8, &expect, true);
        }
    }
    // Every decodable 2×2 subgrid is among them (3·3 choices of rows ×
    // cols at least).
    assert!(viable >= 9, "found only {viable} viable minimal subsets");
}

#[test]
fn sampled_larger_subsets_stream_exactly() {
    // Sampled coverage at larger (n, k) and shuffled arrival orders,
    // via the proptest substitute.
    check("stream == batch on sampled subsets", 20, |g| {
        let (n, k) = g.code_params(12);
        let rows = k * g.usize_in(1..3);
        let mut r = Rng::new(g.usize_in(0..1 << 30) as u64);
        let a = matrix(rows, 3, r.next_u64());
        let x = matrix(3, 2, r.next_u64());
        let expect = ops::matmul(&a, &x);
        // MDS and polynomial: any k-subset, any order.
        for scheme_box in [
            Box::new(MdsCode::new(n, k).unwrap()) as Box<dyn CodedScheme>,
            Box::new(PolynomialCode::new(n, k).unwrap()) as Box<dyn CodedScheme>,
        ] {
            let all = compute_all_products(&scheme_box.encode(&a).unwrap(), &x);
            let mut subset = g.subset(n, k);
            r.shuffle(&mut subset);
            assert_stream_matches_batch(scheme_box.as_ref(), &all, &subset, rows, &expect, true);
        }
        // Hierarchical: k2 random groups, k1 random workers each, in a
        // shuffled interleaving.
        let n2 = g.usize_in(2..4);
        let k2 = g.usize_in(1..n2 + 1);
        let n1 = g.usize_in(2..4);
        let k1 = g.usize_in(1..n1 + 1);
        let code = HierarchicalCode::homogeneous(n1, k1, n2, k2).unwrap();
        let hrows = code.required_row_divisor();
        let ha = matrix(hrows, 3, r.next_u64());
        let hx = matrix(3, 1, r.next_u64());
        let hexpect = ops::matmul(&ha, &hx);
        let hall = compute_all_products(&code.encode(&ha).unwrap(), &hx);
        let groups = g.subset(n2, k2);
        let mut subset = Vec::new();
        for &grp in &groups {
            for j in g.subset(n1, k1) {
                subset.push(grp * n1 + j);
            }
        }
        r.shuffle(&mut subset);
        assert_stream_matches_batch(&code, &hall, &subset, hrows, &hexpect, true);
    });
}

/// Acceptance: in the Table I regime (`k1 = k2²`, k1 ≫ k2), the
/// hierarchical streaming session leaves strictly less work after the
/// last arrival than the batch decode performs, because the `k2` inner
/// decodes already ran incrementally inside `push` — post-k1-arrival
/// latency is the outer decode alone.
#[test]
fn hierarchical_streaming_cuts_post_arrival_latency_in_table1_regime() {
    // Scaled-down Table I shape: (n1,k1) = (128,64), (n2,k2) = (16,8),
    // k1 = k2² — the paper's §IV scaling point p = 2.
    let (n1, k1, n2, k2) = (128usize, 64usize, 16usize, 8usize);
    let scheme = build_scheme(SchemeKind::Hierarchical, n1, k1, n2, k2).unwrap();
    let rows = k1 * k2 * 2; // 1024
    let a = matrix(rows, 4, 20);
    let x = matrix(4, 1, 21);
    let expect = ops::matmul(&a, &x);
    let shards = scheme.encode(&a).unwrap();
    let all = compute_all_products(&shards, &x);
    // Parity-heavy arrivals: the last k1 workers of each group, group-
    // major — every inner decode is a real k1×k1 elimination.
    let picks: Vec<usize> = (0..n2)
        .flat_map(|grp| (k1..n1).map(move |j| grp * n1 + j))
        .collect();
    let subset = select_results(&all, &picks);

    // Run the streaming and batch paths three times and keep the best
    // timing of each — min-of-N makes the wall-clock comparison robust
    // to scheduler preemption on shared CI runners.
    let mut tail = f64::INFINITY;
    let mut full = f64::INFINITY;
    let mut inner_flops = 0u64;
    let mut finish_flops = 0u64;
    let mut batch_flops = 0u64;
    for round in 0..3 {
        let mut session = scheme.decoder(rows, 1);
        let mut ready_at = None;
        for (i, res) in subset.iter().enumerate() {
            if session.push(res.clone()).unwrap().is_ready() {
                ready_at = Some(i);
                break;
            }
        }
        // Ready at the k2-th group's k1-th arrival: k1·k2 pushes.
        assert_eq!(ready_at, Some(k1 * k2 - 1));
        inner_flops = session.flops_so_far();
        let t0 = std::time::Instant::now();
        let streamed = session.finish().unwrap();
        tail = tail.min(t0.elapsed().as_secs_f64());

        // Batch path: the same arrivals, all work after the fact.
        let t1 = std::time::Instant::now();
        let batch = scheme.decode(&subset, rows).unwrap();
        full = full.min(t1.elapsed().as_secs_f64());

        if round == 0 {
            assert_eq!(streamed.result.data(), batch.result.data());
            assert_eq!(streamed.flops, batch.flops);
            assert!(streamed.result.max_abs_diff(&expect) < 1e-5);
            finish_flops = streamed.flops - inner_flops;
            batch_flops = batch.flops;
        }
    }
    // Deterministic form of the claim: the work remaining after the
    // last arrival (`finish` = outer decode only) is a negligible
    // share of what the batch path performs post-collection — the
    // inner eliminations ran inside `push`.
    assert!(inner_flops > 0, "inner decodes must run during pushes");
    assert!(
        finish_flops * 10 < batch_flops,
        "post-arrival flops {finish_flops} must be ≪ batch decode flops {batch_flops}"
    );
    // And the wall-clock version (min of 3): post-k1-arrival latency is
    // strictly below the batch-decode path.
    assert!(
        tail < full,
        "streaming tail {tail:.6}s must beat batch decode {full:.6}s \
         (inner flops front-loaded: {inner_flops})"
    );
}
