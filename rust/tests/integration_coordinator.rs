//! Integration: the full coordinator against direct linear algebra,
//! across coding schemes, code parameters, batch policies, backends and
//! fault plans.

use hiercode::coding::SchemeKind;
use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::fault::FaultConfig;
use hiercode::coordinator::Cluster;
use hiercode::linalg::{ops, Matrix};
use hiercode::util::check::check;
use hiercode::util::rng::Rng;

fn matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
}

fn verify_requests(cluster: &Cluster, a: &Matrix, n_requests: usize, seed: u64, tol: f64) {
    let d = a.cols();
    let mut r = Rng::new(seed);
    let xs: Vec<Vec<f64>> = (0..n_requests)
        .map(|_| (0..d).map(|_| r.uniform(-2.0, 2.0)).collect())
        .collect();
    let handles: Vec<_> = xs
        .iter()
        .map(|x| cluster.submit(x.clone()).unwrap())
        .collect();
    for (x, h) in xs.iter().zip(handles) {
        let y = h.wait().unwrap();
        let expect = ops::matvec(a, x);
        for (i, (&got, &want)) in y.iter().zip(expect.iter()).enumerate() {
            assert!(
                (got - want).abs() < tol,
                "row {i}: {got} vs {want} (tol {tol})"
            );
        }
    }
}

#[test]
fn coded_equals_uncoded_across_code_params() {
    for (n1, k1, n2, k2) in [(3, 2, 3, 2), (4, 2, 4, 3), (5, 3, 4, 2), (2, 1, 2, 1)] {
        let config = ClusterConfig::demo(n1, k1, n2, k2);
        let m = k1 * k2 * 4;
        let a = matrix(m, 6, 10 + n1 as u64);
        let cluster = Cluster::launch(&config, &a).unwrap();
        verify_requests(&cluster, &a, 6, 99, 1e-3);
        let snap = cluster.metrics();
        assert_eq!(snap.failed, 0);
        cluster.shutdown();
    }
}

/// Acceptance: `Cluster::launch` serves a correct matvec end-to-end for
/// **every** scheme the registry knows, through the same streaming
/// decode sessions.
#[test]
fn every_scheme_serves_correct_matvec_end_to_end() {
    for kind in SchemeKind::ALL {
        // (4,2)×(4,2): 16 workers; flat schemes run (16, 4) — k | n, so
        // replication is valid too.
        let config = ClusterConfig::demo_scheme(kind, 4, 2, 4, 2);
        let m = 16;
        let a = matrix(m, 5, 60 + kind.name().len() as u64);
        let cluster = Cluster::launch(&config, &a)
            .unwrap_or_else(|e| panic!("{kind}: launch failed: {e}"));
        verify_requests(&cluster, &a, 4, 61, 1e-3);
        let snap = cluster.metrics();
        assert_eq!(snap.failed, 0, "{kind}: {snap:?}");
        assert!(snap.completed >= 1, "{kind}: {snap:?}");
        if kind == SchemeKind::Hierarchical {
            assert!(
                snap.group_decodes >= snap.jobs * 2,
                "{kind}: submasters must decode k2 groups per job"
            );
        } else {
            assert_eq!(snap.group_decodes, 0, "{kind}: relay groups never decode");
        }
        cluster.shutdown();
    }
}

/// Satellite: a timed-out (abandoned) request cancels its job via the
/// CancelSet path instead of leaking master-side state and decode work.
#[test]
fn timed_out_request_cancels_master_side_job() {
    let config = ClusterConfig::demo(3, 2, 3, 2);
    let a = matrix(8, 4, 62);
    // Two dead links make the job unservable: it would previously hang
    // in the master's job table forever.
    let faults = FaultConfig::none().with_dead_links(&[0, 1]);
    let cluster = Cluster::launch_with_faults(&config, &a, faults).unwrap();
    let res = cluster
        .submit(vec![1.0; 4])
        .unwrap()
        .wait_timeout(std::time::Duration::from_millis(300));
    assert!(res.is_err());
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while cluster.metrics().cancelled == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "timeout never cancelled the job: {:?}",
            cluster.metrics()
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let snap = cluster.metrics();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 0);
    cluster.shutdown();
}

#[test]
fn batching_policies_preserve_results() {
    for max_batch in [1usize, 3, 8] {
        let mut config = ClusterConfig::demo(4, 2, 3, 2);
        config.batching.max_batch = max_batch;
        config.batching.max_wait_ms = 1.0;
        let a = matrix(16, 5, 20);
        let cluster = Cluster::launch(&config, &a).unwrap();
        verify_requests(&cluster, &a, 10, 50, 1e-3);
        cluster.shutdown();
    }
}

#[test]
fn every_survivable_single_fault_plan_works() {
    let (n1, k1, n2, k2) = (3usize, 2usize, 3usize, 2usize);
    let a = matrix(8, 4, 30);
    // All single-link faults and all single-worker faults are
    // survivable at these parameters; each must produce exact results.
    let mut plans: Vec<FaultConfig> = (0..n2)
        .map(|g| FaultConfig::none().with_dead_links(&[g]))
        .collect();
    for g in 0..n2 {
        for w in 0..n1 {
            plans.push(FaultConfig::none().with_dead_workers(&[(g, w)]));
        }
    }
    for plan in plans {
        let config = ClusterConfig::demo(n1, k1, n2, k2);
        assert!(plan.survivable_for(&config.code.topology));
        let cluster = Cluster::launch_with_faults(&config, &a, plan.clone()).unwrap();
        verify_requests(&cluster, &a, 2, 70, 1e-3);
        cluster.shutdown();
    }
}

#[test]
fn property_random_fault_plans_match_survivability() {
    // For random fault plans: survivable ⇒ exact results; not
    // survivable ⇒ requests time out (never wrong data).
    check("fault plans respect survivability", 8, |g| {
        let (n1, k1, n2, k2) = (3usize, 2usize, 3usize, 2usize);
        let mut plan = FaultConfig::none();
        for grp in 0..n2 {
            if g.bool_with(0.2) {
                plan = plan.with_dead_links(&[grp]);
            }
            for w in 0..n1 {
                if g.bool_with(0.15) {
                    plan = plan.with_dead_workers(&[(grp, w)]);
                }
            }
        }
        let a = matrix(8, 4, 31);
        let config = ClusterConfig::demo(n1, k1, n2, k2);
        let survivable = plan.survivable_for(&config.code.topology);
        let cluster = Cluster::launch_with_faults(&config, &a, plan.clone()).unwrap();
        let x = vec![1.0, -1.0, 0.5, 2.0];
        let res = cluster
            .submit(x.clone())
            .unwrap()
            .wait_timeout(std::time::Duration::from_millis(
                if survivable { 20_000 } else { 400 },
            ));
        if survivable {
            let y = res.expect("survivable plan must complete");
            let expect = ops::matvec(&a, &x);
            for (got, want) in y.iter().zip(expect.iter()) {
                assert!((got - want).abs() < 1e-3);
            }
        } else {
            assert!(res.is_err(), "unsurvivable plan must not answer");
        }
        cluster.shutdown();
    });
}

#[test]
fn pjrt_backend_end_to_end_if_artifacts_built() {
    let dir = hiercode::runtime::artifact::default_artifact_dir();
    if !hiercode::runtime::artifact::artifacts_available(&dir) {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return;
    }
    // Shard shape 16x32 with batch 1 → worker_matvec_r16_d32_b1.
    let mut config = ClusterConfig::demo(3, 2, 3, 2);
    config.runtime.use_pjrt = true;
    config.batching.max_batch = 1;
    let a = matrix(64, 32, 40); // 64/(2*2) = 16 rows per shard
    let cluster = Cluster::launch(&config, &a).unwrap();
    verify_requests(&cluster, &a, 4, 80, 1e-3);
    cluster.shutdown();
}

#[test]
fn pjrt_batched_requests_if_artifacts_built() {
    let dir = hiercode::runtime::artifact::default_artifact_dir();
    if !hiercode::runtime::artifact::artifacts_available(&dir) {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return;
    }
    // Shard 256x128 with batch widths {4, 8} → padding exercised.
    let mut config = ClusterConfig::demo(2, 2, 2, 2);
    config.runtime.use_pjrt = true;
    config.batching.max_batch = 8;
    config.batching.max_wait_ms = 10.0;
    let a = matrix(1024, 128, 41);
    let cluster = Cluster::launch(&config, &a).unwrap();
    verify_requests(&cluster, &a, 6, 81, 1e-2);
    let snap = cluster.metrics();
    assert!(snap.jobs < 6, "requests must have been batched");
    cluster.shutdown();
}

#[test]
fn metrics_account_for_all_work() {
    let config = ClusterConfig::demo(3, 2, 3, 2);
    let a = matrix(8, 4, 50);
    let cluster = Cluster::launch(&config, &a).unwrap();
    verify_requests(&cluster, &a, 5, 90, 1e-3);
    // Give stragglers a moment to drain so late products register.
    std::thread::sleep(std::time::Duration::from_millis(300));
    let snap = cluster.metrics();
    assert_eq!(snap.requests, 5);
    assert_eq!(snap.completed, snap.jobs);
    assert!(snap.group_decodes >= snap.jobs * 2, "k2 = 2 decodes per job minimum");
    assert!(snap.worker_products <= snap.jobs * 9);
    cluster.shutdown();
}
