//! Integration: cross-scheme agreement and the paper's comparative
//! claims, exercised through the public API only.

use hiercode::coding::cost::{self, Scheme};
use hiercode::coding::{
    compute_all_products, select_results, CodedScheme, HierarchicalCode, MdsCode,
    PolynomialCode, ProductCode, ReplicationCode,
};
use hiercode::linalg::{ops, Matrix};
use hiercode::sim::{bounds, markov, montecarlo, SimParams};
use hiercode::util::check::check;
use hiercode::util::rng::Rng;

fn matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
}

/// All five schemes decode the same product from all-workers input.
#[test]
fn all_schemes_agree_on_the_product() {
    let (n1, k1, n2, k2) = (4usize, 2usize, 4usize, 2usize);
    let rows = k1 * k2 * 3;
    let a = matrix(rows, 7, 1);
    let x = matrix(7, 2, 2);
    let expect = ops::matmul(&a, &x);
    let schemes: Vec<Box<dyn CodedScheme>> = vec![
        Box::new(MdsCode::new(n1 * n2, k1 * k2).unwrap()),
        Box::new(HierarchicalCode::homogeneous(n1, k1, n2, k2).unwrap()),
        Box::new(ProductCode::new(n1, k1, n2, k2).unwrap()),
        Box::new(PolynomialCode::new(n1 * n2, k1 * k2).unwrap()),
        Box::new(ReplicationCode::new(n1 * n2, k1 * k2).unwrap()),
    ];
    for s in &schemes {
        let shards = s.encode(&a).unwrap();
        assert_eq!(shards.len(), s.num_workers(), "{}", s.name());
        let all = compute_all_products(&shards, &x);
        let out = s.decode(&all, rows).unwrap();
        assert!(
            out.result.max_abs_diff(&expect) < 1e-6,
            "{}: err {}",
            s.name(),
            out.result.max_abs_diff(&expect)
        );
    }
}

/// Hierarchical vs flat-MDS: same recovery threshold in workers, but
/// the hierarchical code tolerates only group-constrained patterns —
/// and pays far less decode (§IV).
#[test]
fn hierarchical_trades_patterns_for_decode_cost() {
    let (n1, k1, n2, k2) = (4usize, 2usize, 4usize, 2usize);
    let rows = 16;
    let a = matrix(rows, 4, 3);
    let x = matrix(4, 1, 4);
    let hier = HierarchicalCode::homogeneous(n1, k1, n2, k2).unwrap();
    let flat = MdsCode::new(n1 * n2, k1 * k2).unwrap();
    // Any k1·k2 = 4 workers from one group: flat decodes, hier can't.
    let one_group: Vec<usize> = (0..4).collect();
    assert!(flat.can_decode(&one_group));
    assert!(!hier.can_decode(&one_group));
    // Group-aligned pattern: both decode; hier flops < flat flops when
    // the subset is parity-heavy.
    let shards_h = hier.encode(&a).unwrap();
    let shards_f = flat.encode(&a).unwrap();
    let all_h = compute_all_products(&shards_h, &x);
    let all_f = compute_all_products(&shards_f, &x);
    // Drop first k1 workers of each of the first k2 groups (parity use).
    let picks: Vec<usize> = (0..n2)
        .flat_map(|g| (k1..n1).map(move |j| g * n1 + j))
        .collect();
    let oh = hier.decode(&select_results(&all_h, &picks), rows).unwrap();
    let of = flat.decode(&select_results(&all_f, &picks), rows).unwrap();
    let expect = ops::matmul(&a, &x);
    assert!(oh.result.max_abs_diff(&expect) < 1e-6);
    assert!(of.result.max_abs_diff(&expect) < 1e-6);
    assert!(
        oh.flops < of.flops,
        "hier decode ({}) must be cheaper than flat MDS ({})",
        oh.flops,
        of.flops
    );
}

/// The full §III sandwich at multiple parameter points.
#[test]
fn latency_bounds_sandwich() {
    for (k1, k2) in [(5, 3), (5, 10), (20, 5)] {
        let p = SimParams::fig6(k1, k2);
        let l = markov::lower_bound(&p).unwrap();
        let et = montecarlo::expected_latency(&p, 30_000, 5).unwrap();
        let u = bounds::lemma2_upper(&p).unwrap();
        assert!(
            l <= et.mean + 3.0 * et.ci95 && et.mean <= u + 3.0 * et.ci95,
            "k1={k1},k2={k2}: L={l} E[T]={} U={u}",
            et.mean
        );
    }
}

/// Measured decode flops scale like the Table I models predict:
/// fitting log(flops) vs log(k) for the polynomial code gives an
/// exponent near 2 (β=2 regime: solve dominated by 2k² per column).
#[test]
fn polynomial_decode_flops_scale_quadratically() {
    let mut pts = Vec::new();
    for k in [8usize, 16, 32] {
        let n = 2 * k;
        let code = PolynomialCode::new(n, k).unwrap();
        let rows = k * 4;
        let a = matrix(rows, 4, 6);
        let x = matrix(4, 1, 7);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let out = code.decode(&all[k / 2..], rows).unwrap();
        pts.push((k as f64, out.flops as f64));
    }
    // Slope of log-log fit between first and last point. With rhs
    // columns ∝ rows/k · b, flops = O(k³) factor + O(k²·(rows/k)) solve;
    // at rows = 4k the measured slope sits between 2 and 3.
    let slope = (pts[2].1 / pts[0].1).ln() / (pts[2].0 / pts[0].0).ln();
    assert!(
        (1.8..=3.2).contains(&slope),
        "polynomial decode exponent {slope} out of range: {pts:?}"
    );
}

/// Property: for random valid parameters, the §IV model never ranks
/// product below hierarchical, and replication is always free.
#[test]
fn property_cost_model_ordering() {
    check("cost model ordering", 200, |g| {
        let k1 = g.usize_in(1..500) as f64;
        let k2 = g.usize_in(1..100) as f64;
        let beta = g.f64_in(1.0, 3.0);
        let h = cost::decoding_cost(Scheme::Hierarchical, k1, k2, beta);
        let p = cost::decoding_cost(Scheme::Product, k1, k2, beta);
        let r = cost::decoding_cost(Scheme::Replication, k1, k2, beta);
        assert_eq!(r, 0.0);
        // product = hier + k2·k1^β − ... : product − hier =
        // k2·k1^β − k1^β = (k2 − 1)·k1^β ≥ 0.
        assert!(p >= h - 1e-9, "k1={k1} k2={k2} beta={beta}: p={p} h={h}");
    });
}
