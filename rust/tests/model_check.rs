//! Exhaustive interleaving tests for the serving stack's
//! synchronization core, driven by the in-repo loom-style explorer
//! (`hiercode::sync::model`). Run with:
//!
//! ```text
//! cargo test --features modelcheck --test model_check
//! ```
//!
//! Every test runs its body under **all** schedules of the
//! participating threads' synchronization operations; `explore` panics
//! with a reproducing decision trace on any assertion failure or
//! deadlock, and panics loudly (never truncates) if the schedule space
//! exceeds the stated bound.

#![cfg(feature = "modelcheck")]

use hiercode::coordinator::messages::{CompletionSlot, JobError};
use hiercode::sync::model::{explore, spawn};
use hiercode::sync::{AdmissionGate, Condvar, DrainState, Mutex};
use std::collections::{BTreeSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::sync::Mutex as StdMutex;
use std::time::Duration;

/// First-write-wins: two completers race to deliver different results;
/// exactly one `complete` reports the win, the waiter observes exactly
/// the winner's value, and across the exploration both racers win at
/// least once (so the schedule space really covers both orders).
#[test]
fn completion_slot_first_write_wins() {
    let winners: Arc<StdMutex<BTreeSet<u64>>> = Arc::new(StdMutex::new(BTreeSet::new()));
    let collect = Arc::clone(&winners);
    let schedules = explore("slot-first-write-wins", 200_000, move || {
        let slot = Arc::new(CompletionSlot::new());
        let wins = Arc::new(AtomicUsize::new(0));
        let (s1, w1) = (Arc::clone(&slot), Arc::clone(&wins));
        let t1 = spawn(move || {
            if s1.complete(Ok(vec![1.0])) {
                w1.fetch_add(1, Ordering::SeqCst);
            }
        });
        let (s2, w2) = (Arc::clone(&slot), Arc::clone(&wins));
        let t2 = spawn(move || {
            if s2.complete(Ok(vec![2.0])) {
                w2.fetch_add(1, Ordering::SeqCst);
            }
        });
        let got = slot.wait().expect("one racing Ok always lands");
        t1.join();
        t2.join();
        assert_eq!(wins.load(Ordering::SeqCst), 1, "exactly one write wins");
        assert!(got == [1.0] || got == [2.0], "winner value intact: {got:?}");
        collect.lock().expect("collector").insert(got[0] as u64);
    });
    let winners = winners.lock().expect("collector");
    assert!(
        winners.contains(&1) && winners.contains(&2),
        "both racers must win somewhere in {schedules} schedules: {winners:?}"
    );
}

/// No lost wakeups: the waiter blocks in `wait_timeout` (untimed under
/// exploration — see the facade docs), so the *only* thing that can
/// wake it is the completer's notify. A schedule where that wakeup is
/// lost deadlocks, and `explore` reports it with a decision trace.
#[test]
fn completion_slot_wakeups_are_never_lost() {
    let schedules = explore("slot-no-lost-wakeup", 200_000, || {
        let slot = Arc::new(CompletionSlot::new());
        let s1 = Arc::clone(&slot);
        let t = spawn(move || {
            s1.complete(Err(JobError::Shutdown));
        });
        let got = slot.wait_timeout(Duration::from_secs(60));
        assert_eq!(
            got,
            Some(Err(JobError::Shutdown)),
            "untimed wait ends only via the completer's notify"
        );
        t.join();
    });
    assert!(schedules > 1, "the race must have multiple schedules");
}

/// No double-shed: a deadline shed racing another terminal write can
/// be *counted* at most once, because only the winning `complete`
/// returns `true` — the coordinator keys its shed counters on exactly
/// that return value.
#[test]
fn deadline_shed_is_never_counted_twice() {
    explore("slot-no-double-shed", 200_000, || {
        let slot = Arc::new(CompletionSlot::new());
        let sheds = Arc::new(AtomicUsize::new(0));
        let (s1, c1) = (Arc::clone(&slot), Arc::clone(&sheds));
        let t1 = spawn(move || {
            if s1.complete(Err(JobError::Deadline)) {
                c1.fetch_add(1, Ordering::SeqCst);
            }
        });
        let (s2, c2) = (Arc::clone(&slot), Arc::clone(&sheds));
        let t2 = spawn(move || {
            if s2.complete(Err(JobError::Deadline)) {
                c2.fetch_add(1, Ordering::SeqCst);
            }
        });
        t1.join();
        t2.join();
        assert_eq!(
            sheds.load(Ordering::SeqCst),
            1,
            "a request is shed (and counted) at most once"
        );
        assert_eq!(slot.wait(), Err(JobError::Deadline));
    });
}

/// Admission cap under racing reserves: with `cap = 1`, two concurrent
/// `try_reserve` calls admit exactly one request in every schedule —
/// the bounded increment is a single atomic step, so there is no
/// check-then-act window to interleave into.
#[test]
fn admission_gate_cap_holds_under_racing_reserves() {
    explore("admission-cap-race", 200_000, || {
        let gate = Arc::new(AdmissionGate::new(1));
        let admitted = Arc::new(AtomicUsize::new(0));
        let (g1, a1) = (Arc::clone(&gate), Arc::clone(&admitted));
        let t1 = spawn(move || {
            if g1.try_reserve() {
                a1.fetch_add(1, Ordering::SeqCst);
            }
        });
        let (g2, a2) = (Arc::clone(&gate), Arc::clone(&admitted));
        let t2 = spawn(move || {
            if g2.try_reserve() {
                a2.fetch_add(1, Ordering::SeqCst);
            }
        });
        t1.join();
        t2.join();
        assert_eq!(
            admitted.load(Ordering::SeqCst),
            1,
            "cap 1 admits exactly one of two racers"
        );
        assert_eq!(gate.queued(), 1);
        gate.release();
        assert_eq!(gate.queued(), 0, "release reopens the slot");
    });
}

/// One event of the mini master protocol in [`drain_never_hangs`].
enum Ev {
    Dispatch,
    Settle,
    Drain,
}

/// Drain-never-hangs: a miniature master loop (event queue + condvar +
/// [`DrainState`]) must terminate under **every** interleaving of a
/// worker's dispatch/settle stream with the shutdown path's drain
/// request — including the reordering where the drain request arrives
/// before the dispatch. A schedule where the master waits forever is a
/// deadlock, which `explore` reports with its decision trace.
#[test]
fn drain_never_hangs() {
    let schedules = explore("drain-never-hangs", 500_000, || {
        let q: Arc<(Mutex<VecDeque<Ev>>, Condvar)> =
            Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
        let master = {
            let q = Arc::clone(&q);
            spawn(move || {
                let (m, cv) = &*q;
                let mut drain = DrainState::new();
                let mut g = m.lock();
                loop {
                    match g.pop_front() {
                        Some(Ev::Dispatch) => drain.job_dispatched(),
                        Some(Ev::Settle) => {
                            if drain.job_settled() {
                                break;
                            }
                        }
                        Some(Ev::Drain) => {
                            if drain.begin_drain() {
                                break;
                            }
                        }
                        None => g = cv.wait(g),
                    }
                }
            })
        };
        let worker = {
            let q = Arc::clone(&q);
            spawn(move || {
                let (m, cv) = &*q;
                m.lock().push_back(Ev::Dispatch);
                cv.notify_all();
                m.lock().push_back(Ev::Settle);
                cv.notify_all();
            })
        };
        // The shutdown path (this thread) races its drain request
        // against the worker's whole dispatch/settle stream.
        let (m, cv) = &*q;
        m.lock().push_back(Ev::Drain);
        cv.notify_all();
        worker.join();
        master.join();
    });
    assert!(schedules > 1, "the race must have multiple schedules");
}
