//! Integration: the control plane end to end — compiled scenario
//! artifacts, zero-drop hot reload, atomic rejection, and rollback.
//!
//! The contracts pinned here are the ones an operator leans on:
//!
//! * a compiled artifact decodes to exactly the config that was
//!   compiled, and re-compiles **bit-identically** (the artifact is a
//!   canonical form, safe to diff and checksum);
//! * every way an artifact can be wrong — corruption, truncation,
//!   version skew, splicing — is a *typed* rejection, never a panic
//!   and never a partially-applied config;
//! * a heavy rollout (changed per-group k1 plan) landing while jobs
//!   are in the pipeline completes every one of them bit-identically
//!   to an unswapped run;
//! * an incompatible artifact is rejected atomically: typed error,
//!   unchanged generation, cluster still serving;
//! * rollback restores generation N−1 without dropping a handle.

use hiercode::config::schema::{ClusterConfig, ModelSpec};
use hiercode::controlplane::{self, ArtifactError};
use hiercode::coordinator::ClusterCore;
use hiercode::linalg::{ops, Matrix};
use hiercode::util::rng::Rng;
use hiercode::Error;
use std::time::{Duration, Instant};

fn matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut r = Rng::new(seed);
    Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
}

/// Serving-friendly demo grid: single-request batches so batch
/// composition cannot race the swap, and a queue that holds a flood.
fn control_config() -> ClusterConfig {
    let mut config = ClusterConfig::demo(4, 2, 3, 2);
    config.serving.queue_cap = 128;
    config.serving.default_deadline_ms = 30_000.0;
    config.serving.drain_ms = 10_000.0;
    config.batching.max_batch = 1;
    config.batching.max_wait_ms = 0.5;
    config
}

/// Compile → decode → recompile must be a fixed point: the artifact is
/// a canonical serialization, so the second compile is byte-identical
/// and the decoded config matches the source exactly.
#[test]
fn artifact_round_trip_is_bit_identical() {
    let mut config = control_config();
    config.serving.models.push(ModelSpec {
        name: "résumé-ranker".into(), // exercises UTF-8 string framing
        rows: 24,
        cols: 4,
        seed: 9,
    });
    let bytes = controlplane::compile(&config).unwrap();
    let artifact = controlplane::decode(&bytes).unwrap();
    assert_eq!(artifact.config, config);
    assert_eq!(artifact.manifest.seed, config.seed);
    let recompiled = controlplane::compile(&artifact.config).unwrap();
    assert_eq!(bytes, recompiled, "artifact is not a canonical form");
    // The manifest digest is topology-derived: a different k1 plan
    // digests differently, the same config digests the same.
    let again = controlplane::decode(&recompiled).unwrap();
    assert_eq!(artifact.manifest.topology_digest, again.manifest.topology_digest);
}

/// Every malformed input is a typed rejection: corruption at any byte,
/// truncation at any length, version skew, wrong magic.
#[test]
fn malformed_artifacts_are_rejected_typed() {
    let bytes = controlplane::compile(&control_config()).unwrap();

    // Wrong magic.
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    assert_eq!(controlplane::decode(&bad).unwrap_err(), ArtifactError::BadMagic);

    // Version skew (artifact version lives at offset 4, LE u16).
    let mut bad = bytes.clone();
    bad[4] = 0xee;
    bad[5] = 0x7f;
    assert_eq!(
        controlplane::decode(&bad).unwrap_err(),
        ArtifactError::BadVersion {
            got: u16::from_le_bytes([0xee, 0x7f]),
            want: controlplane::artifact::ARTIFACT_VERSION,
        }
    );

    // Truncation at every prefix length short of the full artifact.
    for len in 0..bytes.len() {
        let err = controlplane::decode(&bytes[..len]).unwrap_err();
        assert!(
            matches!(
                err,
                ArtifactError::Truncated | ArtifactError::BadChecksum(_)
            ),
            "prefix of {len} bytes gave unexpected error {err:?}"
        );
    }

    // Single-byte corruption anywhere past the version fields must be
    // caught by a checksum or framing check, never accepted. (Bytes
    // 6..8 are the compiler version, which is provenance, not a
    // compatibility gate — skew there is deliberately loadable.)
    for pos in 8..bytes.len() {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x01;
        assert!(
            controlplane::decode(&bad).is_err(),
            "flipped bit at {pos} was accepted"
        );
    }

    // The typed error converts into the crate error with context.
    let err: Error = ArtifactError::Truncated.into();
    assert!(format!("{err}").contains("artifact"));
}

/// The tentpole contract: a heavy rollout (skewed k1 plan) lands while
/// a flood of jobs is in the pipeline. Every pre-swap job completes —
/// zero drops — and bit-identically to a run that never swapped.
#[test]
fn hot_swap_under_load_drops_nothing_and_preserves_bits() {
    let jobs = 8usize;
    let config = control_config();
    let a = matrix(24, 4, 77);
    let inputs: Vec<Vec<f64>> = {
        let mut r = Rng::new(78);
        (0..jobs)
            .map(|_| (0..4).map(|_| r.uniform(-1.0, 1.0)).collect())
            .collect()
    };

    // Oracle: same flood, no swap.
    let core = ClusterCore::launch(&config).unwrap();
    core.register_model("m", &a).unwrap();
    let client = core.handle();
    let handles: Vec<_> = inputs
        .iter()
        .map(|x| client.submit_to("m", x.clone()).unwrap())
        .collect();
    let reference: Vec<Vec<f64>> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    core.shutdown();

    // Swapped run: flood, wait for dispatch, then roll out mid-flight.
    let core = ClusterCore::launch(&config).unwrap();
    core.register_model("m", &a).unwrap();
    let client = core.handle();
    let handles: Vec<_> = inputs
        .iter()
        .map(|x| client.submit_to("m", x.clone()).unwrap())
        .collect();
    let deadline = Instant::now() + Duration::from_secs(10);
    while core.metrics().jobs < jobs as u64 {
        assert!(Instant::now() < deadline, "flood never dispatched");
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut cand = config.clone();
    let plan = [3usize, 2, 1];
    for (g, spec) in cand.code.topology.groups.iter_mut().enumerate() {
        spec.k1 = plan[g];
    }
    cand.code.k1 = plan[0];
    let bytes = controlplane::compile(&cand).unwrap();
    assert_eq!(core.load_artifact(&bytes).unwrap(), 2);

    for (h, want) in handles.into_iter().zip(&reference) {
        let got = h.wait().expect("pre-swap job dropped by the rollout");
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            assert_eq!(g.to_bits(), w.to_bits(), "pre-swap output perturbed");
        }
    }
    // Post-swap traffic decodes correctly under the new plan.
    let x = vec![0.25, -1.5, 0.75, 2.0];
    let y = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
    let want = ops::matvec(&a, &x);
    for (g, w) in y.iter().zip(want.iter()) {
        assert!((g - w).abs() < 1e-6, "post-swap decode wrong: {g} vs {w}");
    }
    let m = core.metrics();
    assert_eq!(m.rollouts, 1);
    assert_eq!(m.artifact_generation, 2);
    core.shutdown();
}

/// An artifact whose outer code changed is refused atomically: typed
/// error, generation unchanged, and the cluster keeps serving.
#[test]
fn incompatible_swap_is_rejected_atomically() {
    let config = control_config();
    let core = ClusterCore::launch(&config).unwrap();
    let a = matrix(24, 4, 80);
    core.register_model("m", &a).unwrap();

    let mut bad = config.clone();
    bad.code.k2 = 3;
    bad.code.topology.k2 = 3;
    let bytes = controlplane::compile(&bad).unwrap();
    let err = core.load_artifact(&bytes).unwrap_err();
    assert!(matches!(err, Error::Incompatible(_)), "got {err}");
    assert!(
        format!("{err}").contains("nothing applied"),
        "rejection must state atomicity: {err}"
    );
    assert_eq!(core.artifact_generation(), 1);
    assert_eq!(core.metrics().rollouts, 0);

    let client = core.handle();
    let x = vec![1.0; 4];
    let y = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
    let want = ops::matvec(&a, &x);
    for (g, w) in y.iter().zip(want.iter()) {
        assert!((g - w).abs() < 1e-6);
    }
    core.shutdown();
}

/// Rollback restores generation N−1 with jobs in flight: the handles
/// submitted before the rollback all complete, and the restored plan
/// serves bit-identically to the pre-rollout cluster.
#[test]
fn rollback_restores_previous_generation_without_drops() {
    let config = control_config();
    let core = ClusterCore::launch(&config).unwrap();
    let a = matrix(24, 4, 90);
    core.register_model("m", &a).unwrap();
    let client = core.handle();

    // Oracle output under generation 1.
    let x0 = vec![0.5, -0.25, 1.5, -1.0];
    let before = client.submit_to("m", x0.clone()).unwrap().wait().unwrap();

    // Roll out a skewed plan (generation 2).
    let mut cand = config.clone();
    let plan = [3usize, 2, 1];
    for (g, spec) in cand.code.topology.groups.iter_mut().enumerate() {
        spec.k1 = plan[g];
    }
    cand.code.k1 = plan[0];
    assert_eq!(
        core.load_artifact(&controlplane::compile(&cand).unwrap()).unwrap(),
        2
    );

    // Flood under generation 2, then roll back mid-flight.
    let inputs: Vec<Vec<f64>> = {
        let mut r = Rng::new(91);
        (0..6).map(|_| (0..4).map(|_| r.uniform(-1.0, 1.0)).collect()).collect()
    };
    let handles: Vec<_> = inputs
        .iter()
        .map(|x| client.submit_to("m", x.clone()).unwrap())
        .collect();
    assert_eq!(core.rollback().unwrap(), 1);
    for (h, x) in handles.into_iter().zip(&inputs) {
        let got = h.wait().expect("in-flight job dropped by the rollback");
        let want = ops::matvec(&a, x);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-6);
        }
    }

    // The restored generation decodes bit-identically to generation 1.
    let after = client.submit_to("m", x0).unwrap().wait().unwrap();
    assert_eq!(before.len(), after.len());
    for (b, a) in before.iter().zip(&after) {
        assert_eq!(b.to_bits(), a.to_bits(), "rollback did not restore the plan");
    }
    let m = core.metrics();
    assert_eq!(m.rollouts, 1);
    assert_eq!(m.rollbacks, 1);
    assert_eq!(m.artifact_generation, 1);
    // A second rollback has nothing to restore: typed, not silent.
    assert!(matches!(core.rollback(), Err(Error::Incompatible(_))));
    core.shutdown();
}
