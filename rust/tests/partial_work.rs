//! Integration tests for the partial-work (multi-round sub-task) mode:
//! `subtasks_per_worker = 1` bit-identity across every scheme, and
//! partial-accumulation recovery from a mix of complete workers and
//! straggler sub-results at decode pool widths 1/2/8.

use hiercode::coding::{
    build_scheme_topology, compute_all_products, select_results, CodedScheme, SchemeKind,
    WorkerResult,
};
use hiercode::config::schema::ClusterConfig;
use hiercode::linalg::{ops, Matrix};
use hiercode::parallel::DecodePool;
use hiercode::scenario::Topology;
use hiercode::sim::montecarlo::expected_latency_topology;
use hiercode::util::rng::Rng;

fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
}

/// Acceptance: an explicit `subtasks_per_worker = 1` is bit-identical
/// to the knob being absent — topology value, encode, decode output,
/// decode flops and sim E[T] — for all five schemes.
#[test]
fn r1_sugar_is_bit_identical_for_every_scheme() {
    for kind in SchemeKind::ALL {
        let base = format!(
            r#"{{"code": {{"scheme": "{0}", "n1": 4, "k1": 2, "n2": 4, "k2": 2}}}}"#,
            kind.name()
        );
        let with_r = format!(
            r#"{{"code": {{"scheme": "{0}", "n1": 4, "k1": 2, "n2": 4, "k2": 2,
                           "subtasks_per_worker": 1}}}}"#,
            kind.name()
        );
        let c0 = ClusterConfig::from_json_text(&base).unwrap();
        let c1 = ClusterConfig::from_json_text(&with_r).unwrap();
        assert_eq!(c0.code.topology, c1.code.topology, "{kind}");
        let s0 = c0.build_scheme().unwrap();
        let s1 = c1.build_scheme().unwrap();
        let mut rng = Rng::new(170);
        let rows = s0.row_divisor() * 2;
        let a = random_matrix(&mut rng, rows, 5);
        let x = random_matrix(&mut rng, 5, 2);
        let sh0 = s0.encode(&a).unwrap();
        let sh1 = s1.encode(&a).unwrap();
        for (m0, m1) in sh0.iter().zip(&sh1) {
            assert_eq!(m0.data(), m1.data(), "{kind}: encode must be bit-identical");
        }
        let all = compute_all_products(&sh0, &x);
        let order: Vec<usize> = (0..s0.num_workers()).collect();
        let o0 = s0.decode(&select_results(&all, &order), rows).unwrap();
        let o1 = s1.decode(&select_results(&all, &order), rows).unwrap();
        assert_eq!(o0.result.data(), o1.result.data(), "{kind}");
        assert_eq!(o0.flops, o1.flops, "{kind}");
        // Sim E[T] over the two configs' topologies is bit-identical
        // (the r = 1 uniform case still rides the seed's Rényi
        // fast-path sampler).
        let pool = DecodePool::serial();
        let (t0, t1) = (&c0.code.topology, &c1.code.topology);
        let e0 = expected_latency_topology(t0, 10_000, 9, &pool).unwrap();
        let e1 = expected_latency_topology(t1, 10_000, 9, &pool).unwrap();
        assert_eq!(e0.mean.to_bits(), e1.mean.to_bits(), "{kind}");
        assert_eq!(e0.ci95.to_bits(), e1.ci95.to_bits(), "{kind}");
    }
}

/// Acceptance: partial-accumulation recovery — each group reaches its
/// `k1·r` threshold from a mix of complete workers and straggler
/// partials, the composed two-level decode reconstructs `A·X`, and the
/// result is bit-identical at decode pool widths 1, 2 and 8.
#[test]
fn partial_accumulation_recovers_identically_at_threads_1_2_8() {
    // (5,3)×(3,2), r = 3: per group, k1·r = 9 sub-results.
    let mut topo = Topology::homogeneous(5, 3, 3, 2);
    for g in &mut topo.groups {
        g.subtasks = 3;
    }
    let r = 3usize;
    let mut rng = Rng::new(88);
    let rows = 36; // divisible by k2·k1·r = 18
    let a = random_matrix(&mut rng, rows, 4);
    let x = random_matrix(&mut rng, 4, 2);
    let expect = ops::matmul(&a, &x);
    let mut reference: Option<(Vec<f64>, u64)> = None;
    for threads in [1usize, 2, 8] {
        let scheme = build_scheme_topology(SchemeKind::Hierarchical, &topo, threads).unwrap();
        let shards = scheme.encode(&a).unwrap();
        // Sub-product of flat worker w's sub-task s.
        let sub = |w: usize, s: usize| -> Matrix {
            let parts = shards[w].split_rows(r).unwrap();
            ops::matmul(&parts[s], &x)
        };
        let mut master = scheme.master_decoder(rows, 2);
        // Groups 1 and 2 decode (group 0 straggles entirely).
        for g in [1usize, 2] {
            let mut session = scheme.group_decoder(g, rows, 2).unwrap();
            // Mix: worker 4 (parity) completes all 3 sub-tasks; workers
            // 0..=3 contribute 2+2+1+1 straggler sub-results → 9 total.
            let contributions: [(usize, usize); 5] = [(4, 3), (0, 2), (1, 2), (2, 1), (3, 1)];
            let mut ready = false;
            for (j, count) in contributions {
                for s in 0..count {
                    let res = WorkerResult {
                        shard: j * r + s,
                        data: sub(g * 5 + j, s),
                    };
                    ready = session.push(res).unwrap().is_ready();
                }
            }
            assert!(ready, "threads={threads} group={g}: k1·r sub-results");
            let part = session.finish().unwrap();
            assert_eq!(part.result.rows(), rows / 2);
            master
                .push(WorkerResult { shard: g, data: part.result })
                .unwrap();
        }
        assert!(master.progress().is_ready(), "threads={threads}");
        let out = master.finish().unwrap();
        assert!(
            out.result.max_abs_diff(&expect) < 1e-6,
            "threads={threads}: wrong product"
        );
        match &reference {
            None => reference = Some((out.result.data().to_vec(), out.flops)),
            Some((data, flops)) => {
                assert_eq!(
                    data.as_slice(),
                    out.result.data(),
                    "threads={threads}: partial decode must be bit-identical"
                );
                assert_eq!(*flops, out.flops, "threads={threads}");
            }
        }
    }
}

/// The full-cluster streaming session accepts whole worker results in
/// partial-work mode too (each expands to its r sub-results), staying
/// bit-identical to the batch fan-out path.
#[test]
fn full_session_and_batch_agree_with_subtasks() {
    let mut topo = Topology::homogeneous(4, 2, 3, 2);
    for g in &mut topo.groups {
        g.subtasks = 2;
    }
    let scheme = build_scheme_topology(SchemeKind::Hierarchical, &topo, 2).unwrap();
    let mut rng = Rng::new(91);
    let rows = scheme.row_divisor();
    let a = random_matrix(&mut rng, rows, 3);
    let x = random_matrix(&mut rng, 3, 1);
    let shards = scheme.encode(&a).unwrap();
    let all = compute_all_products(&shards, &x);
    // Parity-heavy order: workers {2,3} of each group first.
    let order: Vec<usize> = (0..3)
        .flat_map(|g| [g * 4 + 2, g * 4 + 3])
        .chain((0..3).flat_map(|g| [g * 4, g * 4 + 1]))
        .collect();
    let batch = scheme.decode(&select_results(&all, &order), rows).unwrap();
    let mut session = scheme.decoder(rows, 1);
    let mut pushed = 0;
    for w in &order {
        pushed += 1;
        let res = WorkerResult {
            shard: *w,
            data: all[*w].data.clone(),
        };
        if session.push(res).unwrap().is_ready() {
            break;
        }
    }
    // Ready at the k2-th group's k1-th worker: 4 workers (2 groups × 2).
    assert_eq!(pushed, 4);
    let streamed = session.finish().unwrap();
    assert_eq!(streamed.result.data(), batch.result.data());
    assert_eq!(streamed.flops, batch.flops);
    assert!(streamed.result.max_abs_diff(&ops::matmul(&a, &x)) < 1e-6);
}
