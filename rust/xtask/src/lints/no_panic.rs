//! `no_panic`: hot-path modules must not contain `.unwrap()`,
//! `.expect(…)` or panicking macros outside `#[cfg(test)]` code.
//!
//! The coordinator's thread tree, the decoders and the linalg kernels
//! sit on the request path: a panic there kills a worker/submaster/
//! master thread and strands every in-flight job behind it. Errors
//! must propagate as `crate::Result`, or the site must carry an
//! allowlist justification naming the invariant that makes it
//! unreachable.

use super::{Finding, SourceFile};

/// Module prefixes on the request hot path.
const HOT_PATHS: &[&str] = &[
    "src/coordinator/",
    "src/coding/",
    "src/linalg/",
    "src/parallel/",
    "src/transport/",
    "src/controlplane/",
];

/// Panicking macros (checked as `name!`).
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "unimplemented", "todo"];

fn applies(path: &str) -> bool {
    HOT_PATHS.iter().any(|h| path.starts_with(h))
}

/// Scan one file for panic-family calls outside test code.
pub fn lint(file: &SourceFile) -> Vec<Finding> {
    if !applies(&file.path) {
        return Vec::new();
    }
    let s = &file.scan;
    let mut out = Vec::new();
    for id in &s.idents {
        if s.in_test(id.line) {
            continue;
        }
        let method_call = matches!(s.prev_nonspace(id.start), Some(('.', _)))
            && matches!(s.next_nonspace(id.end), Some(('(', _)));
        if (id.text == "unwrap" || id.text == "expect") && method_call {
            out.push(Finding {
                lint: "no_panic",
                file: file.path.clone(),
                line: id.line,
                token: id.text.clone(),
                message: format!(
                    "`.{}()` on the hot path can panic a coordinator \
                     thread; propagate a crate::Result or allowlist the \
                     site with the invariant that makes it unreachable",
                    id.text
                ),
            });
        }
        if PANIC_MACROS.contains(&id.text.as_str())
            && matches!(s.next_nonspace(id.end), Some(('!', _)))
        {
            out.push(Finding {
                lint: "no_panic",
                file: file.path.clone(),
                line: id.line,
                token: id.text.clone(),
                message: format!(
                    "`{}!` on the hot path kills its thread and strands \
                     in-flight jobs; return an error instead",
                    id.text
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hot(src: &str) -> Vec<Finding> {
        lint(&SourceFile::new("src/coding/x.rs", src))
    }

    #[test]
    fn flags_unwrap_expect_and_panic_macros() {
        let f = hot("fn f(x: Option<u32>) -> u32 { x.unwrap() }");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "unwrap");
        assert_eq!(hot("fn f() { g().expect(\"nope\"); }")[0].token, "expect");
        assert_eq!(hot("fn f() { panic!(\"boom\"); }")[0].token, "panic");
        assert_eq!(hot("fn f() { unreachable!() }")[0].token, "unreachable");
    }

    #[test]
    fn ignores_tests_strings_comments_and_cold_modules() {
        assert!(hot("#[cfg(test)]\nmod t {\n fn f(x: Option<u32>) { x.unwrap(); }\n}").is_empty());
        assert!(hot("// x.unwrap()\nfn f() { let s = \"panic!\"; }").is_empty());
        let cold = lint(&SourceFile::new(
            "src/sim/x.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
        ));
        assert!(cold.is_empty(), "sim/ is not a no_panic scope");
    }

    #[test]
    fn transport_is_a_hot_path() {
        let f = lint(&SourceFile::new(
            "src/transport/wire.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
        ));
        assert_eq!(f.len(), 1, "a panicking frame codec can kill the hub");
    }

    #[test]
    fn controlplane_is_a_hot_path() {
        // The artifact codec and the admin server both face untrusted
        // bytes; a panic there would kill the rollout path or the
        // control socket's accept loop.
        let f = lint(&SourceFile::new(
            "src/controlplane/artifact.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
        ));
        assert_eq!(f.len(), 1, "a panicking artifact codec can kill a rollout");
    }

    #[test]
    fn unwrap_or_variants_do_not_match() {
        assert!(hot("fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }").is_empty());
        assert!(hot("fn f(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 0) }").is_empty());
        // `std::panic::catch_unwind` is a path, not a macro call.
        assert!(hot("fn f() { let _ = std::panic::catch_unwind(|| 1); }").is_empty());
    }
}
