//! `simd_safety`: `unsafe` stays inside the dispatch module, annotated.
//!
//! The crate's determinism and memory-safety story rests on keeping
//! the SIMD kernels behind one audited boundary
//! (`src/linalg/dispatch.rs`): feature detection runs only in its
//! `select()`, and every `unsafe` block there cites the invariant that
//! makes it sound. This lint enforces both halves mechanically:
//!
//! * an `unsafe` block anywhere else in the crate is a finding —
//!   new unsafe code must either live in the dispatch module or carry
//!   an allowlist entry arguing for a second audited boundary;
//! * an `unsafe` block *inside* the dispatch module without a
//!   `SAFETY:` comment in the few lines above it is a finding — the
//!   soundness argument must sit next to the code it covers;
//! * `is_x86_feature_detected!` / `is_aarch64_feature_detected!`
//!   outside the dispatch module is a finding — scattered detection
//!   reintroduces the per-call-site feature checks the one-shot
//!   [`Kernels`](../../../src/linalg/dispatch.rs) table exists to
//!   remove.
//!
//! Only `unsafe` *blocks* (`unsafe {`) are checked: an `unsafe fn`
//! declaration shifts the obligation to its callers, and those call
//! sites are themselves `unsafe` blocks this lint sees.

use super::{Finding, SourceFile};

/// The one module allowed to contain `unsafe` blocks and runtime
/// feature detection.
const DISPATCH: &str = "src/linalg/dispatch.rs";

/// How many raw source lines above an `unsafe` block may hold its
/// `SAFETY:` comment (the block's own line counts too).
const SAFETY_WINDOW: usize = 5;

/// Feature-detection macros that must not leave the dispatch module.
const DETECT_MACROS: &[&str] = &["is_x86_feature_detected", "is_aarch64_feature_detected"];

/// Scan one file for unsafe-boundary violations outside test code.
pub fn lint(file: &SourceFile) -> Vec<Finding> {
    let s = &file.scan;
    let raw_lines: Vec<&str> = file.raw.lines().collect();
    let mut out = Vec::new();
    for id in &s.idents {
        if s.in_test(id.line) {
            continue;
        }
        if id.text == "unsafe" && matches!(s.next_nonspace(id.end), Some(('{', _))) {
            if file.path != DISPATCH {
                out.push(Finding {
                    lint: "simd_safety",
                    file: file.path.clone(),
                    line: id.line,
                    token: "unsafe".to_string(),
                    message: format!(
                        "`unsafe` block outside the audited kernel boundary \
                         ({DISPATCH}); move the code behind the dispatch \
                         table or allowlist a justified second boundary"
                    ),
                });
            } else {
                let lo = id.line.saturating_sub(SAFETY_WINDOW);
                let annotated = raw_lines[lo..id.line.min(raw_lines.len())]
                    .iter()
                    .any(|l| l.contains("SAFETY"));
                if !annotated {
                    out.push(Finding {
                        lint: "simd_safety",
                        file: file.path.clone(),
                        line: id.line,
                        token: "missing_safety_comment".to_string(),
                        message: format!(
                            "`unsafe` block without a SAFETY: comment within \
                             the {SAFETY_WINDOW} lines above it — state the \
                             invariant that makes the block sound next to \
                             the code"
                        ),
                    });
                }
            }
        }
        if file.path != DISPATCH && DETECT_MACROS.contains(&id.text.as_str()) {
            out.push(Finding {
                lint: "simd_safety",
                file: file.path.clone(),
                line: id.line,
                token: id.text.clone(),
                message: format!(
                    "runtime feature detection outside {DISPATCH}: kernel \
                     selection happens once in dispatch::select(), never \
                     per call site"
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsafe_outside_dispatch_is_flagged() {
        let f = lint(&SourceFile::new(
            "src/linalg/ops.rs",
            "fn f(p: *const f64) -> f64 { unsafe { *p } }",
        ));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "unsafe");
    }

    #[test]
    fn annotated_unsafe_in_dispatch_is_clean() {
        let f = lint(&SourceFile::new(
            super::DISPATCH,
            "fn f(p: *const f64) -> f64 {\n\
             \x20   // SAFETY: p points into a live slice (caller contract).\n\
             \x20   unsafe { *p }\n\
             }",
        ));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn unannotated_unsafe_in_dispatch_is_flagged() {
        let f = lint(&SourceFile::new(
            super::DISPATCH,
            "fn f(p: *const f64) -> f64 { unsafe { *p } }",
        ));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "missing_safety_comment");
    }

    #[test]
    fn unsafe_fn_declarations_are_exempt() {
        // The obligation sits on callers; only blocks are checked.
        let f = lint(&SourceFile::new(
            "src/linalg/ops.rs",
            "unsafe fn g(p: *const f64) -> f64 { *p }",
        ));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn feature_detection_outside_dispatch_is_flagged() {
        let f = lint(&SourceFile::new(
            "src/coding/mds.rs",
            "fn f() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }",
        ));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "is_x86_feature_detected");
        let ok = lint(&SourceFile::new(
            super::DISPATCH,
            "fn f() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }",
        ));
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn test_code_and_safety_in_strings_do_not_count() {
        let f = lint(&SourceFile::new(
            "src/linalg/ops.rs",
            "#[cfg(test)]\nmod t {\n    fn f(p: *const f64) -> f64 { unsafe { *p } }\n}",
        ));
        assert!(f.is_empty(), "{f:?}");
    }
}
