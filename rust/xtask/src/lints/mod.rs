//! The invariant lints behind `cargo xtask analyze`.
//!
//! Each lint is a pure function over pre-scanned sources, so the unit
//! tests and the `--self-test` mode drive them with in-memory strings
//! — no filesystem, no fixtures. Per-file lints ([`no_panic`],
//! [`determinism`], [`simd_safety`]) take one file; whole-crate lints
//! ([`lock_discipline`], [`metrics_pairing`]) take the full set,
//! because their properties (cycles, inc/dec pairing) span files.

pub mod determinism;
pub mod lock_discipline;
pub mod metrics_pairing;
pub mod no_panic;
pub mod simd_safety;

use crate::lexer::Scan;

/// One scanned source file. `path` is relative to the crate root with
/// forward slashes (e.g. `src/coordinator/master.rs`) — the same form
/// the allowlist uses.
pub struct SourceFile {
    /// Crate-relative path.
    pub path: String,
    /// The token scan of its contents.
    pub scan: Scan,
    /// The unstripped source — [`simd_safety`] reads comments (SAFETY
    /// annotations), which the scan blanks out by design.
    pub raw: String,
}

impl SourceFile {
    /// Scan one source text under `path`.
    pub fn new(path: &str, source: &str) -> SourceFile {
        SourceFile {
            path: path.to_string(),
            scan: Scan::new(source),
            raw: source.to_string(),
        }
    }
}

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Lint name (`no_panic`, `determinism`, …) — allowlist key 1.
    pub lint: &'static str,
    /// Crate-relative file — allowlist key 2.
    pub file: String,
    /// 1-based line of the violating token.
    pub line: usize,
    /// Violation token (e.g. `unwrap`, `Instant`,
    /// `send_while_holding:models`) — allowlist key 3.
    pub token: String,
    /// Human-readable explanation.
    pub message: String,
}

/// Run every lint over the file set; findings come back sorted by
/// (file, line, lint) so the report and allowlist diffs are stable.
pub fn run_all(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        out.extend(no_panic::lint(f));
        out.extend(determinism::lint(f));
        out.extend(simd_safety::lint(f));
    }
    out.extend(lock_discipline::lint(files));
    out.extend(metrics_pairing::lint(files));
    out.sort_by(|a, b| {
        (&a.file, a.line, a.lint, &a.token).cmp(&(&b.file, b.line, b.lint, &b.token))
    });
    out
}

/// Seed one violation per lint and assert the pass fails; run each
/// lint's clean fixture and assert it stays quiet. Returns one
/// (lint, result) row per check — the `--self-test` mode and the unit
/// tests share this.
pub fn self_check() -> Vec<(&'static str, Result<(), String>)> {
    let mut rows = Vec::new();
    let fire = |name: &'static str, files: &[SourceFile], token: &str| -> Result<(), String> {
        let found = run_all(files);
        if found.iter().any(|f| f.lint == name && f.token.contains(token)) {
            Ok(())
        } else {
            Err(format!(
                "seeded `{token}` violation not caught (found: {:?})",
                found.iter().map(|f| (f.lint, &f.token)).collect::<Vec<_>>()
            ))
        }
    };
    let quiet = |name: &'static str, files: &[SourceFile]| -> Result<(), String> {
        let found: Vec<_> = run_all(files)
            .into_iter()
            .filter(|f| f.lint == name)
            .collect();
        if found.is_empty() {
            Ok(())
        } else {
            Err(format!("clean fixture flagged: {:?}", found[0]))
        }
    };

    let seeded = vec![SourceFile::new(
        "src/coordinator/seeded.rs",
        "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
    )];
    rows.push(("no_panic", fire("no_panic", &seeded, "unwrap")));
    let clean = vec![SourceFile::new(
        "src/coordinator/clean.rs",
        "fn f(x: Option<u32>) -> Option<u32> { x }\n\
         #[cfg(test)]\nmod tests { fn t(x: Option<u32>) { x.unwrap(); } }",
    )];
    rows.push(("no_panic", quiet("no_panic", &clean)));

    let seeded = vec![SourceFile::new(
        "src/sim/seeded.rs",
        "use std::time::Instant;\nfn now() -> Instant { Instant::now() }",
    )];
    rows.push(("determinism", fire("determinism", &seeded, "Instant")));
    let clean = vec![SourceFile::new(
        "src/sim/clean.rs",
        "fn tick(t: f64) -> f64 { t + 1.0 }",
    )];
    rows.push(("determinism", quiet("determinism", &clean)));

    let seeded = vec![SourceFile::new(
        "src/coordinator/seeded.rs",
        "fn f(&self) {\n    let g = self.state.lock();\n    self.tx.send(1);\n    drop(g);\n}",
    )];
    rows.push((
        "lock_discipline",
        fire("lock_discipline", &seeded, "send_while_holding:state"),
    ));
    let clean = vec![SourceFile::new(
        "src/coordinator/clean.rs",
        "fn f(&self) {\n    let g = self.state.lock();\n    drop(g);\n    self.tx.send(1);\n}",
    )];
    rows.push(("lock_discipline", quiet("lock_discipline", &clean)));

    let seeded = vec![SourceFile::new(
        "src/coordinator/seeded.rs",
        "fn f(m: &Metrics) { Metrics::inc(&m.queue_depth); }",
    )];
    rows.push((
        "metrics_pairing",
        fire("metrics_pairing", &seeded, "queue_depth"),
    ));
    let clean = vec![SourceFile::new(
        "src/coordinator/clean.rs",
        "fn f(m: &Metrics) { Metrics::inc(&m.queue_depth); }\n\
         fn g(m: &Metrics) { Metrics::dec(&m.queue_depth); }",
    )];
    rows.push(("metrics_pairing", quiet("metrics_pairing", &clean)));

    let seeded = vec![SourceFile::new(
        "src/linalg/ops.rs",
        "fn f(p: *const f64) -> f64 { unsafe { *p } }",
    )];
    rows.push(("simd_safety", fire("simd_safety", &seeded, "unsafe")));
    let clean = vec![SourceFile::new(
        "src/linalg/dispatch.rs",
        "fn f(p: *const f64) -> f64 {\n\
         \x20   // SAFETY: p points into a live slice (caller contract).\n\
         \x20   unsafe { *p }\n\
         }",
    )];
    rows.push(("simd_safety", quiet("simd_safety", &clean)));

    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_seeded_violation_fails_and_every_clean_fixture_passes() {
        for (lint, result) in self_check() {
            assert!(result.is_ok(), "{lint}: {}", result.unwrap_err());
        }
    }

    #[test]
    fn findings_are_sorted_and_stable() {
        let files = vec![SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(x: Option<u32>) { x.unwrap(); panic!(\"boom\"); }",
        )];
        let a = run_all(&files);
        let b = run_all(&files);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!((x.line, &x.token), (y.line, &y.token));
        }
        assert!(a.windows(2).all(|w| w[0].line <= w[1].line));
    }
}
