//! `determinism`: the simulator and the decode paths must be
//! replayable — same seed, same bytes.
//!
//! `sim/` results feed the paper's figures, the decode paths back the
//! `parallel decode == serial decode` bit-identity tests, and the
//! chaos driver backs the `hiercode chaos` same-seed determinism
//! verdict, so all three ban ambient nondeterminism: wall clocks
//! (`Instant`, `SystemTime`), OS-seeded randomness (`thread_rng`,
//! `RandomState`) and unordered `HashMap`/`HashSet` iteration. Sites
//! that only *report* time (e.g. decode timing metadata riding on an
//! otherwise deterministic result) carry allowlist justifications.

use super::{Finding, SourceFile};

/// Deterministic-by-contract module prefixes. The chaos driver clocks
/// itself through the injectable `Clock` trait, so even its waiting is
/// replayable — a raw `Instant` there would silently break the
/// same-seed verdict. `linalg/` backs the `simd == scalar` and
/// cached == uncached bit-identity contracts, so its kernels, LU
/// factorization and erasure-pattern cache get the same ban (the
/// cache's Vec-scan store exists precisely because `HashMap` iteration
/// order is not replayable). `controlplane/` backs the compile →
/// decode → recompile bit-identity contract (the `.hca` artifact is a
/// canonical form) and the rollout classifier's replayability, so the
/// codec and the admin framing get it too.
const SCOPES: &[&str] = &[
    "src/sim/",
    "src/coding/",
    "src/linalg/",
    "src/coordinator/chaos.rs",
    "src/transport/",
    "src/controlplane/",
];

/// Banned identifiers and why.
const BANNED: &[(&str, &str)] = &[
    ("Instant", "wall-clock reads are not replayable"),
    ("SystemTime", "wall-clock reads are not replayable"),
    ("HashMap", "iteration order varies across runs; use BTreeMap or index by Vec"),
    ("HashSet", "iteration order varies across runs; use BTreeSet or a sorted Vec"),
    ("RandomState", "OS-seeded hasher breaks replayability"),
    ("thread_rng", "OS-seeded RNG; thread the crate's seeded util::rng::Rng instead"),
];

/// Scan one file for nondeterminism sources outside test code.
pub fn lint(file: &SourceFile) -> Vec<Finding> {
    if !SCOPES.iter().any(|p| file.path.starts_with(p)) {
        return Vec::new();
    }
    let s = &file.scan;
    let mut out = Vec::new();
    for id in &s.idents {
        if s.in_test(id.line) {
            continue;
        }
        if let Some((_, why)) = BANNED.iter().find(|(t, _)| *t == id.text) {
            out.push(Finding {
                lint: "determinism",
                file: file.path.clone(),
                line: id.line,
                token: id.text.clone(),
                message: format!(
                    "`{}` in a deterministic path ({}): {why}",
                    id.text,
                    if file.path.starts_with("src/sim/") {
                        "simulator"
                    } else if file.path.starts_with("src/coordinator/") {
                        "chaos driver"
                    } else if file.path.starts_with("src/linalg/") {
                        "kernel/cache"
                    } else if file.path.starts_with("src/transport/") {
                        "transport"
                    } else if file.path.starts_with("src/controlplane/") {
                        "control plane"
                    } else {
                        "decode"
                    }
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_clocks_and_unordered_collections_in_scope() {
        let f = lint(&SourceFile::new(
            "src/sim/x.rs",
            "use std::time::Instant;\nuse std::collections::HashMap;\n",
        ));
        let tokens: Vec<&str> = f.iter().map(|x| x.token.as_str()).collect();
        assert_eq!(tokens, vec!["Instant", "HashMap"]);
    }

    #[test]
    fn linalg_is_in_scope() {
        let f = lint(&SourceFile::new(
            "src/linalg/lu.rs",
            "use std::collections::HashMap;\n",
        ));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("kernel/cache"));
    }

    #[test]
    fn chaos_driver_is_in_scope() {
        let f = lint(&SourceFile::new(
            "src/coordinator/chaos.rs",
            "use std::time::Instant;\n",
        ));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("chaos driver"));
    }

    #[test]
    fn transport_is_in_scope() {
        // The wire codec and the node's seed replay back the loopback
        // bit-identity contract: same frames in, same bytes out.
        let f = lint(&SourceFile::new(
            "src/transport/wire.rs",
            "use std::collections::HashMap;\n",
        ));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("transport"));
    }

    #[test]
    fn controlplane_is_in_scope() {
        // The artifact codec backs the compile → decode → recompile
        // bit-identity contract: a canonical form cannot depend on
        // unordered iteration or wall clocks.
        let f = lint(&SourceFile::new(
            "src/controlplane/artifact.rs",
            "use std::time::Instant;\n",
        ));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("control plane"));
    }

    #[test]
    fn out_of_scope_and_test_code_ignored() {
        assert!(lint(&SourceFile::new(
            "src/coordinator/x.rs",
            "use std::time::Instant;",
        ))
        .is_empty());
        assert!(lint(&SourceFile::new(
            "src/coding/x.rs",
            "#[cfg(test)]\nmod t {\n    use std::collections::HashMap;\n}",
        ))
        .is_empty());
    }
}
