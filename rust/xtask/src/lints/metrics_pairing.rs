//! `metrics_pairing`: every gauge `inc` needs a reachable `dec`.
//!
//! Counters only ever go up, but a *gauge* (`queue_depth`) measures
//! live population — an `inc` without a matching `dec` somewhere in
//! the crate means the gauge drifts upward forever and the
//! `debug_assert` in `Metrics::dec` (gauge-below-zero) can never catch
//! the real bug. The same pairing argument applies to admission
//! slots: a `try_reserve()` with no `release()` site leaks queue
//! capacity until the model rejects everything. A third pass checks
//! [`COUPLED`] counters (decode-cache hits/misses) co-occur per file,
//! so no overlay or emitter surfaces half a hit-rate.

use super::{Finding, SourceFile};
use crate::lexer::Scan;
use std::collections::BTreeMap;

/// Fields of `Metrics` that are gauges (everything else is a
/// monotonic counter and exempt from pairing).
const GAUGES: &[&str] = &["queue_depth"];

/// Counter names that must travel together *within a file*: a site
/// that surfaces decode-cache hits but not misses (or vice versa)
/// produces a hit-rate nobody can recompute — the overlay in
/// `ClusterCore::metrics`, the JSON emitter and the Display impl must
/// each carry both. (Evictions are deliberately unpaired: invalidation
/// can evict without any lookup traffic.)
const COUPLED: &[(&str, &str)] = &[
    ("decode_cache_hits", "decode_cache_misses"),
    // A transport site that counts only one direction produces a
    // traffic asymmetry nobody can distinguish from a real link
    // imbalance: senders and receivers must be surfaced together.
    ("transport_bytes_sent", "transport_bytes_received"),
    ("transport_frames_sent", "transport_frames_received"),
];

/// One `Metrics::inc/dec` call site, keyed by the gauge field name.
struct Site {
    file: String,
    line: usize,
}

/// The field named by the *first argument* of the call whose open
/// paren sits at `open`: the last identifier before the `,` or `)`
/// that ends the first argument (`&m.queue_depth` → `queue_depth`).
fn first_arg_field(s: &Scan, open: usize) -> Option<String> {
    let mut depth = 1usize;
    let mut p = open + 1;
    while p < s.chars.len() && depth > 0 {
        match s.chars[p] {
            '(' | '[' => depth += 1,
            ')' | ']' => depth -= 1,
            ',' if depth == 1 => break,
            _ => {}
        }
        p += 1;
    }
    s.idents
        .iter()
        .rev()
        .find(|i| i.start > open && i.end <= p)
        .map(|i| i.text.clone())
}

/// True when the identifier at index `k` is a `Metrics::<name>` path
/// call (`Metrics` `::` `<name>` `(`).
fn metrics_helper_call(s: &Scan, k: usize) -> bool {
    let id = &s.idents[k];
    let Some((':', c2)) = s.prev_nonspace(id.start) else {
        return false;
    };
    let Some((':', c1)) = s.prev_nonspace(c2) else {
        return false;
    };
    matches!(s.ident_ending_at(c1), Some(i) if i.text == "Metrics")
}

/// Report every gauge present in `with` but absent from `without`
/// (an `inc` with no `dec` anywhere, or the converse).
fn unpaired(
    out: &mut Vec<Finding>,
    with: &BTreeMap<String, Vec<Site>>,
    without: &BTreeMap<String, Vec<Site>>,
    have: &str,
    miss: &str,
) {
    for (field, sites) in with {
        if !without.contains_key(field) && !sites.is_empty() {
            out.push(Finding {
                lint: "metrics_pairing",
                file: sites[0].file.clone(),
                line: sites[0].line,
                token: field.clone(),
                message: format!(
                    "gauge `{field}` has a `Metrics::{have}` site but no \
                     `Metrics::{miss}` anywhere in the crate — the gauge \
                     drifts monotonically and stops measuring live \
                     population"
                ),
            });
        }
    }
}

/// Run the whole-crate pass: collect gauge `inc`/`dec` sites and
/// admission `try_reserve`/`release` sites, then demand each side of
/// every pair is non-empty.
pub fn lint(files: &[SourceFile]) -> Vec<Finding> {
    let mut incs: BTreeMap<String, Vec<Site>> = BTreeMap::new();
    let mut decs: BTreeMap<String, Vec<Site>> = BTreeMap::new();
    let mut reserves: Vec<Site> = Vec::new();
    let mut releases: Vec<Site> = Vec::new();
    for f in files {
        let s = &f.scan;
        for (k, id) in s.idents.iter().enumerate() {
            if s.in_test(id.line) {
                continue;
            }
            let site = || Site {
                file: f.path.clone(),
                line: id.line,
            };
            match id.text.as_str() {
                "inc" | "dec" if metrics_helper_call(s, k) => {
                    let Some(('(', open)) = s.next_nonspace(id.end) else {
                        continue;
                    };
                    let Some(field) = first_arg_field(s, open) else {
                        continue;
                    };
                    if GAUGES.contains(&field.as_str()) {
                        let map = if id.text == "inc" { &mut incs } else { &mut decs };
                        map.entry(field).or_default().push(site());
                    }
                }
                "try_reserve" | "release" => {
                    let dotted = matches!(s.prev_nonspace(id.start), Some(('.', _)));
                    let called = matches!(s.next_nonspace(id.end), Some(('(', _)));
                    if dotted && called {
                        if id.text == "try_reserve" {
                            reserves.push(site());
                        } else {
                            releases.push(site());
                        }
                    }
                }
                _ => {}
            }
        }
    }
    let mut out = Vec::new();
    unpaired(&mut out, &incs, &decs, "inc", "dec");
    unpaired(&mut out, &decs, &incs, "dec", "inc");
    for f in files {
        for (a, b) in COUPLED {
            let site_of = |name: &str| {
                f.scan
                    .idents
                    .iter()
                    .find(|i| i.text == *name && !f.scan.in_test(i.line))
            };
            let (sa, sb) = (site_of(a), site_of(b));
            let (present, absent, site) = match (sa, sb) {
                (Some(s), None) => (*a, *b, s),
                (None, Some(s)) => (*b, *a, s),
                _ => continue,
            };
            out.push(Finding {
                lint: "metrics_pairing",
                file: f.path.clone(),
                line: site.line,
                token: present.to_string(),
                message: format!(
                    "`{present}` referenced without its paired counter \
                     `{absent}` in this file — every site that surfaces \
                     one side of the decode-cache hit/miss pair must \
                     surface the other, or the hit-rate it implies \
                     cannot be recomputed"
                ),
            });
        }
    }
    if !reserves.is_empty() && releases.is_empty() {
        out.push(Finding {
            lint: "metrics_pairing",
            file: reserves[0].file.clone(),
            line: reserves[0].line,
            token: "try_reserve".to_string(),
            message: "admission `try_reserve()` has no `release()` site \
                      anywhere in the crate — queue slots leak until the \
                      model rejects every submission"
                .to_string(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_only_gauge_is_flagged_and_paired_gauge_is_not() {
        let f = lint(&[SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(m: &Metrics) { Metrics::inc(&m.queue_depth); }",
        )]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "queue_depth");
        let ok = lint(&[
            SourceFile::new(
                "src/coordinator/a.rs",
                "fn f(m: &Metrics) { Metrics::inc(&m.queue_depth); }",
            ),
            SourceFile::new(
                "src/coordinator/b.rs",
                "fn g(m: &Metrics) { Metrics::dec(&m.queue_depth); }",
            ),
        ]);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn counters_and_add_are_exempt() {
        let ok = lint(&[SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(m: &Metrics) { Metrics::inc(&m.requests); \
             Metrics::add(&m.decode_flops, out.flops); }",
        )]);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn reserve_without_release_is_flagged() {
        let f = lint(&[SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(e: &Entry) -> bool { e.admission.try_reserve() }",
        )]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "try_reserve");
        let ok = lint(&[SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(e: &Entry) -> bool { e.admission.try_reserve() }\n\
             fn g(e: &Entry) { e.admission.release(); }",
        )]);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn half_of_a_coupled_counter_pair_is_flagged() {
        let f = lint(&[SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(s: &mut Snap, c: Stats) { s.decode_cache_hits = c.hits; }",
        )]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "decode_cache_hits");
        let ok = lint(&[SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(s: &mut Snap, c: Stats) {\n\
             \x20   s.decode_cache_hits = c.hits;\n\
             \x20   s.decode_cache_misses = c.misses;\n\
             }",
        )]);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn transport_pairs_are_coupled() {
        let f = lint(&[SourceFile::new(
            "src/transport/a.rs",
            "fn f(m: &Metrics) { Metrics::add(&m.transport_bytes_sent, n); }",
        )]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "transport_bytes_sent");
        let f = lint(&[SourceFile::new(
            "src/transport/a.rs",
            "fn f(s: &mut Snap) { s.transport_frames_received = 1; }",
        )]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "transport_frames_received");
        let ok = lint(&[SourceFile::new(
            "src/transport/a.rs",
            "fn f(m: &Metrics) {\n\
             \x20   Metrics::add(&m.transport_bytes_sent, n);\n\
             \x20   Metrics::add(&m.transport_bytes_received, n);\n\
             }",
        )]);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn non_metrics_inc_calls_do_not_match() {
        let ok = lint(&[SourceFile::new(
            "src/coordinator/a.rs",
            "fn f(c: &Counter) { c.inc(); other::inc(&c.queue_depth); }",
        )]);
        assert!(ok.is_empty(), "{ok:?}");
    }
}
