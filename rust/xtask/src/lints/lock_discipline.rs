//! `lock_discipline`: extract the lock-acquisition graph and flag
//! (a) cycles in the acquired-while-holding order and (b) channel
//! sends / condvar waits performed while a lock guard is live.
//!
//! Guard tracking is lexical: a durable guard is a `let`-bound
//! acquisition (`let g = x.lock();`) that lives until its block closes
//! or an explicit `drop(g)`; chained temporaries
//! (`x.lock().take()…`) die at the end of their statement. Locks are
//! named by their receiver identifier (`self.models.read()` →
//! `models`), so same-named fields on *different* objects (per-group
//! histograms) can produce self-edges — those carry allowlist
//! justifications rather than being silently skipped, because a
//! self-edge is also exactly what a real double-lock looks like.

use super::{Finding, SourceFile};
use crate::lexer::Scan;
use std::collections::{BTreeMap, BTreeSet};

/// Guard-producing call names (empty-argument method calls only, so
/// `io::Read::read(&mut buf)` never matches).
const ACQUIRERS: &[&str] = &["lock", "read", "write"];

/// One acquired-while-holding edge in the global lock graph.
struct Edge {
    from: String,
    to: String,
    file: String,
    line: usize,
}

struct Guard {
    name: String,
    lock: String,
    depth: usize,
}

/// Name the receiver of the method call whose `.` sits at `dot`.
fn receiver_name(s: &Scan, dot: usize) -> String {
    match s.prev_nonspace(dot) {
        Some((']', mut p)) => {
            // Indexed receiver `slots[i].lock()`: name the base.
            let mut brackets = 1;
            while p > 0 && brackets > 0 {
                p -= 1;
                match s.chars[p] {
                    ']' => brackets += 1,
                    '[' => brackets -= 1,
                    _ => {}
                }
            }
            match s.prev_nonspace(p) {
                Some((c, q)) if c.is_alphanumeric() || c == '_' => s
                    .ident_ending_at(q + 1)
                    .map(|i| i.text.clone())
                    .unwrap_or_else(|| "unknown".to_string()),
                _ => "unknown".to_string(),
            }
        }
        Some((c, p)) if c.is_alphanumeric() || c == '_' => s
            .ident_ending_at(p + 1)
            .map(|i| i.text.clone())
            .unwrap_or_else(|| "unknown".to_string()),
        _ => "unknown".to_string(),
    }
}

/// If the statement containing the call at `dot` is a `let` binding,
/// return the bound name.
fn let_binding_name(s: &Scan, dot: usize) -> Option<String> {
    // Statement start: last `;`, `{` or `}` before the call.
    let mut p = dot;
    while p > 0 {
        p -= 1;
        if matches!(s.chars[p], ';' | '{' | '}') {
            break;
        }
    }
    let mut in_stmt = s
        .idents
        .iter()
        .filter(|i| i.start > p && i.end <= dot)
        .map(|i| i.text.as_str());
    if in_stmt.next() != Some("let") {
        return None;
    }
    match in_stmt.next() {
        Some("mut") => in_stmt.next().map(str::to_string),
        Some(name) => Some(name.to_string()),
        None => None,
    }
}

fn analyze_file(file: &SourceFile, findings: &mut Vec<Finding>, edges: &mut Vec<Edge>) {
    let s = &file.scan;
    let mut guards: Vec<Guard> = Vec::new();
    for id in &s.idents {
        if s.in_test(id.line) {
            guards.clear();
            continue;
        }
        let depth_here = s.depth_at(id.start);
        guards.retain(|g| g.depth <= depth_here);
        let dotted = matches!(s.prev_nonspace(id.start), Some(('.', _)));
        match id.text.as_str() {
            t if ACQUIRERS.contains(&t) => {
                let Some(('.', dot)) = s.prev_nonspace(id.start) else {
                    continue;
                };
                let Some(('(', op)) = s.next_nonspace(id.end) else {
                    continue;
                };
                let Some((')', cp)) = s.next_nonspace(op + 1) else {
                    continue;
                };
                let lock = receiver_name(s, dot);
                for g in &guards {
                    edges.push(Edge {
                        from: g.lock.clone(),
                        to: lock.clone(),
                        file: file.path.clone(),
                        line: id.line,
                    });
                }
                // Durable guard: `let g = x.lock();` — the statement
                // ends right at the call and the result is named.
                if matches!(s.next_nonspace(cp + 1), Some((';', _))) {
                    if let Some(name) = let_binding_name(s, dot) {
                        if name != "_" {
                            guards.push(Guard {
                                name,
                                lock,
                                depth: depth_here,
                            });
                        }
                    }
                }
            }
            "send" if dotted => {
                for g in &guards {
                    findings.push(Finding {
                        lint: "lock_discipline",
                        file: file.path.clone(),
                        line: id.line,
                        token: format!("send_while_holding:{}", g.lock),
                        message: format!(
                            "channel send while holding lock `{}` (guard \
                             `{}`): a blocking send here can deadlock \
                             against the receiver; drop the guard first, \
                             or allowlist why this send cannot block",
                            g.lock, g.name
                        ),
                    });
                }
            }
            "wait" | "wait_timeout" if dotted => {
                if guards.len() >= 2 {
                    findings.push(Finding {
                        lint: "lock_discipline",
                        file: file.path.clone(),
                        line: id.line,
                        token: format!("wait_while_holding:{}", guards[0].lock),
                        message: format!(
                            "condvar wait with a second lock held (`{}`): \
                             the wait releases only its own mutex, so the \
                             other lock blocks every would-be notifier",
                            guards[0].lock
                        ),
                    });
                }
            }
            "drop" if !dotted => {
                // `drop(g)`: release the named guard early.
                if let Some(('(', op)) = s.next_nonspace(id.end) {
                    if let Some((_, p)) = s.next_nonspace(op + 1) {
                        if let Some(arg) = s.ident_starting_at(p) {
                            if matches!(s.next_nonspace(arg.end), Some((')', _))) {
                                let name = arg.text.clone();
                                guards.retain(|g| g.name != name);
                            }
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

/// Is `to` reachable from `from` in the edge graph?
fn reaches(adj: &BTreeMap<&str, BTreeSet<&str>>, from: &str, to: &str) -> bool {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut stack = vec![from];
    while let Some(n) = stack.pop() {
        let Some(next) = adj.get(n) else { continue };
        for &m in next {
            if m == to {
                return true;
            }
            if seen.insert(m) {
                stack.push(m);
            }
        }
    }
    false
}

/// Run the whole-crate pass: per-file guard tracking plus the global
/// cycle check over the acquisition graph.
pub fn lint(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut edges = Vec::new();
    for f in files {
        analyze_file(f, &mut findings, &mut edges);
    }
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in &edges {
        adj.entry(&e.from).or_default().insert(&e.to);
    }
    let mut reported: BTreeSet<String> = BTreeSet::new();
    for e in &edges {
        let cyclic = e.from == e.to || reaches(&adj, &e.to, &e.from);
        if !cyclic {
            continue;
        }
        let token = format!("cycle:{}->{}", e.from, e.to);
        if reported.insert(token.clone()) {
            findings.push(Finding {
                lint: "lock_discipline",
                file: e.file.clone(),
                line: e.line,
                token,
                message: format!(
                    "acquiring `{}` while holding `{}` closes a cycle in \
                     the lock order — two threads taking the locks in \
                     opposite orders deadlock; fix the order or allowlist \
                     why the locks are distinct objects",
                    e.to, e.from
                ),
            });
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> Vec<Finding> {
        lint(&[SourceFile::new(path, src)])
    }

    #[test]
    fn send_while_holding_a_guard_is_flagged_until_dropped() {
        let f = one(
            "src/coordinator/x.rs",
            "fn f(&self) {\n    let g = self.models.read();\n    self.tx.send(1);\n}",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].token, "send_while_holding:models");
        assert_eq!(f[0].line, 3);
        let ok = one(
            "src/coordinator/x.rs",
            "fn f(&self) {\n    let g = self.models.read();\n    drop(g);\n    self.tx.send(1);\n}",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn guard_dies_when_its_block_closes() {
        let ok = one(
            "src/coordinator/x.rs",
            "fn f(&self) {\n    {\n        let g = self.models.read();\n    }\n    self.tx.send(1);\n}",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn chained_temporaries_are_not_durable_guards() {
        let ok = one(
            "src/parallel/x.rs",
            "fn f(&self) {\n    let item = self.slots.lock().take();\n    self.tx.send(item);\n}",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn abba_order_across_files_is_a_cycle() {
        let f = lint(&[
            SourceFile::new(
                "src/a.rs",
                "fn f(&self) {\n    let a = self.alpha.lock();\n    let b = self.beta.lock();\n}",
            ),
            SourceFile::new(
                "src/b.rs",
                "fn g(&self) {\n    let b = self.beta.lock();\n    let a = self.alpha.lock();\n}",
            ),
        ]);
        assert!(
            f.iter().any(|x| x.token.starts_with("cycle:")),
            "ABBA must be reported: {f:?}"
        );
        let ok = lint(&[SourceFile::new(
            "src/a.rs",
            "fn f(&self) {\n    let a = self.alpha.lock();\n    let b = self.beta.lock();\n}",
        )]);
        assert!(ok.is_empty(), "consistent order is fine: {ok:?}");
    }

    #[test]
    fn self_edge_is_reported_as_a_cycle() {
        let f = one(
            "src/coordinator/x.rs",
            "fn f(&self) {\n    let a = self.latency.lock();\n    let b = self.latency.lock();\n}",
        );
        assert!(
            f.iter().any(|x| x.token == "cycle:latency->latency"),
            "{f:?}"
        );
    }

    #[test]
    fn indexed_receivers_resolve_to_their_base() {
        let f = one(
            "src/coordinator/x.rs",
            "fn f(&self) {\n    let g = self.slots[i].lock();\n    self.tx.send(1);\n}",
        );
        assert_eq!(f[0].token, "send_while_holding:slots");
    }

    #[test]
    fn wait_with_a_second_lock_held_is_flagged() {
        let f = one(
            "src/coordinator/x.rs",
            "fn f(&self) {\n    let a = self.state.lock();\n    let b = self.aux.lock();\n    let b = self.cv.wait(b);\n}",
        );
        assert!(
            f.iter().any(|x| x.token.starts_with("wait_while_holding:")),
            "{f:?}"
        );
    }
}
