//! `cargo xtask analyze` — the repo's invariant lints.
//!
//! Runs the five passes in [`lints`] over `src/` of the root crate and
//! reports every finding that does not carry an `analyze.allow` entry.
//! The allowlist is exact-match on `(lint, file, token)` and every
//! entry must both justify itself and still be *used* — a fixed
//! violation whose entry lingers is an error, so the list can only
//! shrink when the code improves.
//!
//! Exit codes: 0 clean, 1 findings (or stale allowlist entries, or a
//! failed self-test), 2 usage / IO errors.

mod lexer;
mod lints;

use lints::SourceFile;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// One parsed `analyze.allow` entry:
/// `lint | file | token | justification`.
struct AllowEntry {
    lint: String,
    file: String,
    token: String,
    source_line: usize,
}

fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut entries = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split('|').map(str::trim).collect();
        let &[lint, file, token, justification] = fields.as_slice() else {
            return Err(format!(
                "analyze.allow:{}: expected 4 `|`-separated fields \
                 (lint | file | token | justification), got {}",
                idx + 1,
                fields.len()
            ));
        };
        if justification.is_empty() {
            return Err(format!(
                "analyze.allow:{}: empty justification — every entry \
                 must explain why the site is safe",
                idx + 1
            ));
        }
        entries.push(AllowEntry {
            lint: lint.to_string(),
            file: file.to_string(),
            token: token.to_string(),
            source_line: idx + 1,
        });
    }
    Ok(entries)
}

/// Collect `root/src/**/*.rs`, sorted, as crate-relative forward-slash
/// paths.
fn collect_sources(root: &Path) -> Result<Vec<SourceFile>, String> {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut stack = vec![root.join("src")];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
        for entry in entries {
            let path = entry.map_err(|e| e.to_string())?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                paths.push(path);
            }
        }
    }
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for path in paths {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        files.push(SourceFile::new(&rel, &text));
    }
    Ok(files)
}

/// `--self-test`: seed one violation per lint, assert each pass fires,
/// and assert each clean fixture stays quiet.
fn self_test() -> i32 {
    let rows = lints::self_check();
    let mut failed = 0;
    for (lint, result) in &rows {
        match result {
            Ok(()) => println!("self-test {lint}: ok"),
            Err(msg) => {
                println!("self-test {lint}: FAILED — {msg}");
                failed += 1;
            }
        }
    }
    println!(
        "self-test: {}/{} checks passed",
        rows.len() - failed,
        rows.len()
    );
    i32::from(failed > 0)
}

fn analyze(root: &Path) -> Result<i32, String> {
    let files = collect_sources(root)?;
    let allow_path = root.join("analyze.allow");
    let allow_text = match std::fs::read_to_string(&allow_path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => return Err(format!("cannot read {}: {e}", allow_path.display())),
    };
    let entries = parse_allowlist(&allow_text)?;

    let findings = lints::run_all(&files);
    let mut used: BTreeSet<usize> = BTreeSet::new();
    let mut errors = 0usize;
    let mut allowed = 0usize;
    for f in &findings {
        let hit = entries
            .iter()
            .position(|e| e.lint == f.lint && e.file == f.file && e.token == f.token);
        match hit {
            Some(i) => {
                used.insert(i);
                allowed += 1;
            }
            None => {
                println!("error[{}]: {}:{}: {}", f.lint, f.file, f.line, f.message);
                errors += 1;
            }
        }
    }
    for (i, e) in entries.iter().enumerate() {
        if !used.contains(&i) {
            println!(
                "error[allowlist]: analyze.allow:{}: unused entry \
                 ({} | {} | {}) — the violation is gone; delete the entry",
                e.source_line, e.lint, e.file, e.token
            );
            errors += 1;
        }
    }
    println!(
        "analyze: {} files, {} findings ({} allowlisted), {} errors",
        files.len(),
        findings.len(),
        allowed,
        errors
    );
    Ok(i32::from(errors > 0))
}

fn run() -> Result<i32, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let mut want_self_test = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "analyze" if cmd.is_none() => cmd = Some("analyze"),
            "--self-test" => want_self_test = true,
            "--root" => {
                i += 1;
                let dir = args
                    .get(i)
                    .ok_or_else(|| "--root needs a directory".to_string())?;
                root = PathBuf::from(dir);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
        i += 1;
    }
    match cmd {
        Some("analyze") if want_self_test => Ok(self_test()),
        Some("analyze") => analyze(&root),
        _ => Err("usage: cargo xtask analyze [--self-test] [--root <dir>]".to_string()),
    }
}

fn main() {
    match run() {
        Ok(code) => std::process::exit(code),
        Err(msg) => {
            eprintln!("xtask: {msg}");
            std::process::exit(2);
        }
    }
}
