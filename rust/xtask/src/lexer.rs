//! Token-level scanner shared by every lint.
//!
//! Not a Rust parser: the build is fully offline (no `syn`), so the
//! lints work on a *stripped* view of each source file — comments and
//! string/char-literal contents blanked to spaces, line structure
//! preserved — plus the identifier stream over that view. That is
//! enough to resolve method names, receivers, brace depth and
//! `#[cfg(test)]` regions without false matches inside strings or
//! doc comments.

/// One identifier in the stripped source.
#[derive(Debug, Clone)]
pub struct Ident {
    /// The identifier text.
    pub text: String,
    /// Char index of the first char.
    pub start: usize,
    /// Char index one past the last char.
    pub end: usize,
    /// 1-based source line.
    pub line: usize,
}

/// A stripped file plus the lookup tables every lint needs.
pub struct Scan {
    /// The stripped source (char-indexed below).
    pub chars: Vec<char>,
    /// 1-based line number of each char.
    pub line_of: Vec<usize>,
    /// Brace depth *after* consuming each char.
    pub depth_after: Vec<usize>,
    /// All identifiers, in source order.
    pub idents: Vec<Ident>,
    /// Per 1-based line: is it inside a `#[cfg(test)]` item?
    test_line: Vec<bool>,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Blank comments and string/char-literal contents to spaces,
/// preserving every newline (so line numbers survive) and the literal
/// delimiters themselves.
pub fn strip(src: &str) -> Vec<char> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0;
    while i < n {
        let c = b[i];
        let prev_ident = i > 0 && is_ident_char(b[i - 1]);
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            // Line comment (incl. doc comments).
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
        } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
            // Block comment, nesting allowed.
            let mut depth = 0usize;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
        } else if !prev_ident && (c == 'r' || c == 'b') && raw_string_at(&b, i).is_some() {
            // Raw (byte) string: r"..." / r#"..."# / br#"..."#.
            let (body_start, hashes) = raw_string_at(&b, i).unwrap();
            for &d in &b[i..body_start] {
                out.push(d);
            }
            i = body_start;
            // Consume until `"` followed by `hashes` #s.
            while i < n {
                let closes = b[i] == '"'
                    && b[i + 1..].len() >= hashes
                    && b[i + 1..i + 1 + hashes].iter().all(|&h| h == '#');
                if closes {
                    out.push('"');
                    i += 1;
                    for _ in 0..hashes {
                        out.push('#');
                        i += 1;
                    }
                    break;
                }
                out.push(blank(b[i]));
                i += 1;
            }
        } else if c == '"' {
            // Normal string literal.
            out.push('"');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
        } else if c == '\'' {
            // Lifetime or char literal. A lifetime is `'` + ident with
            // no closing quote right after ('a, 'static); a char
            // literal always closes ('x', '\n').
            let is_lifetime = i + 2 < n
                && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                && b[i + 2] != '\'';
            if is_lifetime {
                out.push('\'');
                i += 1;
            } else {
                out.push('\'');
                i += 1;
                let mut consumed = 0;
                while i < n && consumed < 12 {
                    if b[i] == '\\' && i + 1 < n {
                        out.push(' ');
                        out.push(' ');
                        i += 2;
                        consumed += 2;
                    } else if b[i] == '\'' {
                        out.push('\'');
                        i += 1;
                        break;
                    } else {
                        out.push(blank(b[i]));
                        i += 1;
                        consumed += 1;
                    }
                }
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    out
}

/// If `b[i..]` starts a raw (byte) string, return (index of the first
/// body char, number of `#`s).
fn raw_string_at(b: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < b.len() && b[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < b.len() && b[j] == '"' {
        Some((j + 1, hashes))
    } else {
        None
    }
}

impl Scan {
    /// Strip and index one source file.
    pub fn new(source: &str) -> Scan {
        let chars = strip(source);
        let n = chars.len();
        let mut line_of = Vec::with_capacity(n);
        let mut depth_after = Vec::with_capacity(n);
        let mut line = 1usize;
        let mut depth = 0usize;
        for &c in &chars {
            line_of.push(line);
            if c == '\n' {
                line += 1;
            }
            match c {
                '{' => depth += 1,
                '}' => depth = depth.saturating_sub(1),
                _ => {}
            }
            depth_after.push(depth);
        }
        let mut idents = Vec::new();
        let mut i = 0;
        while i < n {
            if is_ident_char(chars[i]) && !chars[i].is_ascii_digit() {
                let start = i;
                while i < n && is_ident_char(chars[i]) {
                    i += 1;
                }
                idents.push(Ident {
                    text: chars[start..i].iter().collect(),
                    start,
                    end: i,
                    line: line_of[start],
                });
            } else {
                i += 1;
            }
        }
        let mut scan = Scan {
            chars,
            line_of,
            depth_after,
            idents,
            test_line: vec![false; line + 1],
        };
        scan.mark_test_regions();
        scan
    }

    /// Is 1-based `line` inside a `#[cfg(test)]` item?
    pub fn in_test(&self, line: usize) -> bool {
        self.test_line.get(line).copied().unwrap_or(false)
    }

    /// First non-whitespace char at or after `pos`.
    pub fn next_nonspace(&self, mut pos: usize) -> Option<(char, usize)> {
        while pos < self.chars.len() {
            let c = self.chars[pos];
            if !c.is_whitespace() {
                return Some((c, pos));
            }
            pos += 1;
        }
        None
    }

    /// First non-whitespace char strictly before `pos`.
    pub fn prev_nonspace(&self, pos: usize) -> Option<(char, usize)> {
        let mut p = pos;
        while p > 0 {
            p -= 1;
            let c = self.chars[p];
            if !c.is_whitespace() {
                return Some((c, p));
            }
        }
        None
    }

    /// The identifier whose span ends exactly at `end`.
    pub fn ident_ending_at(&self, end: usize) -> Option<&Ident> {
        self.idents
            .binary_search_by(|id| id.end.cmp(&end))
            .ok()
            .map(|i| &self.idents[i])
    }

    /// The identifier whose span starts exactly at `start`.
    pub fn ident_starting_at(&self, start: usize) -> Option<&Ident> {
        self.idents
            .binary_search_by(|id| id.start.cmp(&start))
            .ok()
            .map(|i| &self.idents[i])
    }

    /// Brace depth just before `pos`.
    pub fn depth_at(&self, pos: usize) -> usize {
        if pos == 0 {
            0
        } else {
            self.depth_after[pos - 1]
        }
    }

    /// Mark every line covered by a `#[cfg(test)]` braced item.
    fn mark_test_regions(&mut self) {
        let mut regions: Vec<(usize, usize)> = Vec::new();
        for (k, id) in self.idents.iter().enumerate() {
            if id.text != "cfg" {
                continue;
            }
            // Pattern: `#[cfg(test)]` — `cfg` preceded by `[`, then
            // `(test)` and `]`. `#[cfg(not(test))]` fails the `test`
            // ident check and is left alone.
            let Some(('[', _)) = self.prev_nonspace(id.start) else {
                continue;
            };
            let Some(('(', op)) = self.next_nonspace(id.end) else {
                continue;
            };
            let Some(inner) = self.idents.get(k + 1) else {
                continue;
            };
            if inner.text != "test" || inner.start < op {
                continue;
            }
            let Some((')', cp)) = self.next_nonspace(inner.end) else {
                continue;
            };
            let Some((']', close)) = self.next_nonspace(cp + 1) else {
                continue;
            };
            // The attribute's item: first `{` before any `;` opens the
            // region (a `;` first means a single-statement item).
            let mut p = close + 1;
            let mut open = None;
            while p < self.chars.len() {
                match self.chars[p] {
                    '{' => {
                        open = Some(p);
                        break;
                    }
                    ';' => break,
                    _ => p += 1,
                }
            }
            let Some(open) = open else { continue };
            let target = self.depth_at(open);
            let mut q = open;
            while q < self.chars.len() {
                if self.depth_after[q] == target && self.chars[q] == '}' {
                    break;
                }
                q += 1;
            }
            let end = q.min(self.chars.len() - 1);
            regions.push((self.line_of[open], self.line_of[end]));
        }
        for (a, b) in regions {
            for l in a..=b {
                if l < self.test_line.len() {
                    self.test_line[l] = true;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let src = "let x = \"panic!\"; // panic!\n/* panic! */ let y = 'p';\n";
        let stripped: String = strip(src).iter().collect();
        assert!(!stripped.contains("panic"));
        assert_eq!(stripped.matches('\n').count(), 2);
    }

    #[test]
    fn raw_strings_and_lifetimes_survive() {
        let src = "fn f<'a>(s: &'a str) -> &'a str { let _r = r#\"unwrap()\"#; s }";
        let scan = Scan::new(src);
        let texts: Vec<&str> = scan.idents.iter().map(|i| i.text.as_str()).collect();
        assert!(texts.contains(&"a"), "lifetime ident kept: {texts:?}");
        assert!(!texts.contains(&"unwrap"), "raw string stripped: {texts:?}");
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn live2() {}\n";
        let scan = Scan::new(src);
        assert!(!scan.in_test(1));
        assert!(scan.in_test(3));
        assert!(scan.in_test(4));
        assert!(scan.in_test(5));
        assert!(!scan.in_test(6));
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nmod live {\n    fn f() {}\n}\n";
        let scan = Scan::new(src);
        assert!(!scan.in_test(3));
    }

    #[test]
    fn depth_and_receivers_resolve() {
        let src = "fn f() { let g = self.state.lock(); }";
        let scan = Scan::new(src);
        let lock = scan.idents.iter().find(|i| i.text == "lock").unwrap();
        let ('.', dot) = scan.prev_nonspace(lock.start).unwrap() else {
            panic!("expected dot receiver")
        };
        let (c, p) = scan.prev_nonspace(dot).unwrap();
        assert!(is_ident_char(c));
        assert_eq!(scan.ident_ending_at(p + 1).unwrap().text, "state");
        assert_eq!(scan.depth_at(lock.start), 1);
    }
}
