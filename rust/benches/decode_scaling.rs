//! Bench E6: the §IV decode-cost scaling sweep (k1 = k2^p) with
//! measured flops from the real decoders, plus decode wall-clock at
//! growing sizes to expose the β exponent empirically.

use hiercode::coding::{
    compute_all_products, CodedScheme, HierarchicalCode, PolynomialCode, ProductCode,
};
use hiercode::figures::decode_scaling;
use hiercode::linalg::Matrix;
use hiercode::util::bench::Suite;
use hiercode::util::rng::Rng;

fn setup(code: &dyn CodedScheme, rows: usize, seed: u64) -> (Vec<hiercode::coding::WorkerResult>, usize) {
    let mut r = Rng::new(seed);
    let a = Matrix::from_fn(rows, 8, |_, _| r.uniform(-1.0, 1.0));
    let x = Matrix::from_fn(8, 1, |_, _| r.uniform(-1.0, 1.0));
    let shards = code.encode(&a).expect("encode");
    let all = compute_all_products(&shards, &x);
    (all, rows)
}

fn main() {
    let mut suite = Suite::new("decode_scaling").with_iters(10, 2);

    if suite.selected("scaling_series") {
        let rows = decode_scaling::run(42).expect("scaling");
        assert!(!rows.is_empty());
    }

    // Decode wall-clock: hierarchical vs product vs polynomial at the
    // same (n, k), parity-forcing erasures (first k1 workers dropped).
    for (n1, k1, n2, k2) in [(8usize, 4usize, 4usize, 2usize), (16, 8, 4, 2), (32, 16, 4, 2)] {
        let rows = k1 * k2 * 4;
        let drop = k1;
        let hier = HierarchicalCode::homogeneous(n1, k1, n2, k2).unwrap();
        let (all_h, _) = setup(&hier, rows, 1);
        suite.bench(&format!("decode_hier_{n1}x{k1}_{n2}x{k2}"), || {
            let subset: Vec<_> = all_h[drop..].to_vec();
            hier.decode(&subset, rows).unwrap().flops
        });
        let prod = ProductCode::new(n1, k1, n2, k2).unwrap();
        let (all_p, _) = setup(&prod, rows, 1);
        suite.bench(&format!("decode_product_{n1}x{k1}_{n2}x{k2}"), || {
            let subset: Vec<_> = all_p[drop..].to_vec();
            prod.decode(&subset, rows).unwrap().flops
        });
        let poly = PolynomialCode::new(n1 * n2, k1 * k2).unwrap();
        let (all_y, _) = setup(&poly, rows, 1);
        suite.bench(&format!("decode_poly_n{}_k{}", n1 * n2, k1 * k2), || {
            let subset: Vec<_> = all_y[drop..].to_vec();
            poly.decode(&subset, rows).unwrap().flops
        });
    }
    suite.finish();
}
