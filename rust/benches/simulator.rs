//! Simulator performance: Monte-Carlo sampling rate, Markov-chain
//! solver, event-driven engine — the machinery behind Fig. 6 — plus the
//! straggler-model ablation (paper's Exp vs shifted-Exp vs Weibull).

use hiercode::sim::straggler::StragglerModel;
use hiercode::sim::{engine, markov, montecarlo, SimParams};
use hiercode::util::bench::Suite;
use hiercode::util::rng::Rng;

fn main() {
    let mut suite = Suite::new("simulator").with_iters(15, 3);
    let p = SimParams::fig6(5, 5);
    let big = SimParams::fig6(300, 5);

    suite.bench("mc_sample_k1=5", || {
        let mut rng = Rng::new(1);
        let mut acc = 0.0;
        for _ in 0..1_000 {
            acc += montecarlo::sample_hierarchical(&p, &mut rng);
        }
        acc
    });
    suite.bench("mc_sample_k1=300", || {
        let mut rng = Rng::new(1);
        let mut acc = 0.0;
        for _ in 0..100 {
            acc += montecarlo::sample_hierarchical(&big, &mut rng);
        }
        acc
    });
    suite.bench("markov_chain_solve_3000_states", || {
        markov::lower_bound(&big).unwrap()
    });
    suite.bench("event_engine_job_k1=5", || {
        engine::expected_latency_event_driven(&p, 200, 1).unwrap().mean
    });

    // Ablation: E[T] under different straggler models (equal means).
    if suite.selected("straggler_ablation") {
        println!("# straggler ablation: E[T] at (10,5)x(10,5), equal-mean models");
        println!("model,E[T]");
        let models = [
            ("exponential", StragglerModel::exp(10.0)),
            (
                "shifted_exp",
                StragglerModel::ShiftedExponential { shift: 0.05, mu: 20.0 },
            ),
            (
                "weibull_heavy",
                StragglerModel::Weibull { shape: 0.5, scale: 0.05 },
            ),
            ("deterministic", StragglerModel::Deterministic { value: 0.1 }),
        ];
        let link = StragglerModel::exp(1.0);
        for (name, wm) in models {
            let est = montecarlo::estimate(20_000, 11, |rng| {
                montecarlo::sample_hierarchical_with(&p, &wm, &link, rng)
            });
            println!("{name},{:.6}", est.mean);
        }
    }
    suite.finish();
}
