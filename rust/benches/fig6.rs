//! Bench E1/E2: regenerate Fig. 6a and Fig. 6b (series printed as CSV)
//! and time the three estimators behind them.

use hiercode::figures::fig6;
use hiercode::sim::{markov, montecarlo, SimParams};
use hiercode::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("fig6").with_iters(5, 1);

    // Regenerate the actual figure series (the deliverable).
    if suite.selected("fig6a_series") {
        let rows = fig6::run(5, 20_000, 42).expect("fig6a");
        assert_eq!(rows.len(), 10);
    }
    if suite.selected("fig6b_series") {
        let rows = fig6::run(300, 5_000, 42).expect("fig6b");
        assert_eq!(rows.len(), 10);
    }

    // Time each estimator at representative points.
    let small = SimParams::fig6(5, 5);
    let large = SimParams::fig6(300, 5);
    suite.bench("mc_e[t]_k1=5_10k_trials", || {
        montecarlo::expected_latency(&small, 10_000, 1).unwrap().mean
    });
    suite.bench("mc_e[t]_k1=300_1k_trials", || {
        montecarlo::expected_latency(&large, 1_000, 1).unwrap().mean
    });
    suite.bench("markov_lower_bound_k1=5", || {
        markov::lower_bound(&small).unwrap()
    });
    suite.bench("markov_lower_bound_k1=300", || {
        markov::lower_bound(&large).unwrap()
    });
    suite.finish();
}
