//! Coordinator hot-path benchmark: end-to-end request latency and
//! throughput of the in-process cluster with straggler injection OFF
//! (isolating coordination overhead: channels, batching, decode) —
//! the §Perf target is coordination overhead ≪ compute.
//!
//! PJRT rows appear when `make artifacts` has been run.

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::Cluster;
use hiercode::linalg::Matrix;
use hiercode::util::bench::Suite;
use hiercode::util::rng::Rng;

fn bench_cluster(suite: &mut Suite, label: &str, config: &ClusterConfig, a: &Matrix) {
    let d = a.cols();
    let cluster = Cluster::launch(config, a).expect("launch");
    let mut rng = Rng::new(1);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
    suite.bench(&format!("{label}_single_request"), || {
        cluster.submit(x.clone()).unwrap().wait().unwrap()
    });
    suite.bench(&format!("{label}_32_concurrent"), || {
        let handles: Vec<_> = (0..32)
            .map(|_| cluster.submit(x.clone()).unwrap())
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
    });
    eprintln!("{label} metrics after bench:\n{}", cluster.metrics());
    cluster.shutdown();
}

fn main() {
    let mut suite = Suite::new("coordinator").with_iters(10, 2);
    let (m, d) = (1024usize, 128usize);
    let mut rng = Rng::new(3);
    let a = Matrix::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0));

    // Native backend, no straggle: pure coordination + GEMM cost.
    let mut native = ClusterConfig::demo(4, 2, 4, 2);
    native.straggler.enabled = false;
    native.batching.max_wait_ms = 0.5;
    bench_cluster(&mut suite, "native", &native, &a);

    // With straggler injection (the paper's Exp(10)/Exp(1) at 2ms/unit).
    let mut straggle = native.clone();
    straggle.straggler.enabled = true;
    straggle.straggler.scale = 0.002;
    bench_cluster(&mut suite, "native_straggle", &straggle, &a);

    // PJRT backend if artifacts exist.
    let dir = hiercode::runtime::artifact::default_artifact_dir();
    if hiercode::runtime::artifact::artifacts_available(&dir) {
        let mut pjrt = native.clone();
        pjrt.runtime.use_pjrt = true;
        bench_cluster(&mut suite, "pjrt", &pjrt, &a);
    } else {
        eprintln!("(skipping pjrt rows: run `make artifacts`)");
    }
    suite.finish();
}
