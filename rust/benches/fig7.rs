//! Bench E3: regenerate Fig. 7 (E[T_exec] vs α for the four schemes)
//! and time its components.

use hiercode::figures::fig7;
use hiercode::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("fig7").with_iters(5, 1);

    if suite.selected("fig7_series") {
        let rows = fig7::run(20_000, 42).expect("fig7");
        // The paper's qualitative claims, re-checked at bench scale.
        assert_eq!(rows.first().unwrap().winner, "polynomial");
        assert_eq!(rows.last().unwrap().winner, "replication");
        assert!(rows.iter().any(|r| r.winner == "hierarchical"));
        assert!(rows.iter().all(|r| r.exec[1] < r.exec[2]),
            "hierarchical must strictly beat product for all alpha");
    }

    let p = fig7::Fig7Params::default();
    suite.bench("fig7_components_5k_trials", || {
        fig7::components(&p, 5_000, 1).unwrap()
    });
    suite.finish();
}
