//! Bench E4: regenerate Table I (computing time + decoding cost per
//! scheme) and time the generation.

use hiercode::figures::table1;
use hiercode::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("table1").with_iters(3, 1);

    if suite.selected("table1_rows") {
        let rows = table1::run(20_000, 42).expect("table1");
        assert_eq!(rows.len(), 4);
    }

    suite.bench("table1_generate_5k_trials", || {
        table1::generate(800, 400, 40, 20, 10.0, 1.0, 2.0, 5_000, 1).unwrap()
    });
    suite.finish();
}
