//! Coding-substrate throughput: encode and decode micro-benchmarks for
//! every scheme (the L3 hot-path building blocks the §Perf pass tunes).

use hiercode::coding::{
    compute_all_products, CodedScheme, HierarchicalCode, MdsCode, PolynomialCode, ProductCode,
    ReplicationCode,
};
use hiercode::linalg::{lu::LuFactors, ops, Matrix};
use hiercode::parallel::DecodePool;
use hiercode::util::bench::Suite;
use hiercode::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let mut suite = Suite::new("coding").with_iters(20, 3);
    let mut r = Rng::new(7);

    // linalg primitives: the packed microkernel against both oracles,
    // square and at the k=64 decode hot shape.
    let a256 = Matrix::from_fn(256, 256, |_, _| r.uniform(-1.0, 1.0));
    let b256 = Matrix::from_fn(256, 256, |_, _| r.uniform(-1.0, 1.0));
    suite.bench("gemm_256x256x256_packed", || ops::matmul(&a256, &b256));
    suite.bench("gemm_256x256x256_ikj", || ops::matmul_ikj(&a256, &b256));
    suite.bench("gemm_256x256x256_naive", || ops::matmul_naive(&a256, &b256));
    let a64 = Matrix::from_fn(64, 64, |_, _| r.uniform(-1.0, 1.0));
    let b64w = Matrix::from_fn(64, 4096, |_, _| r.uniform(-1.0, 1.0));
    suite.bench("gemm_64x64x4096_packed", || ops::matmul(&a64, &b64w));
    suite.bench("gemm_64x64x4096_ikj", || ops::matmul_ikj(&a64, &b64w));
    let lu_m = {
        let mut m = Matrix::from_fn(128, 128, |_, _| r.uniform(-1.0, 1.0));
        for i in 0..128 {
            m[(i, i)] += 128.0;
        }
        m
    };
    suite.bench("lu_factorize_128", || LuFactors::factorize(&lu_m).unwrap());
    let lu = LuFactors::factorize(&lu_m).unwrap();
    let rhs = Matrix::from_fn(128, 64, |_, _| r.uniform(-1.0, 1.0));
    suite.bench("lu_solve_128x64rhs", || lu.solve_matrix(&rhs).unwrap());

    // Encode throughput (m = 4096 rows, d = 32).
    let a = Matrix::from_fn(4096, 32, |_, _| r.uniform(-1.0, 1.0));
    let mds = MdsCode::new(16, 8).unwrap();
    let hier = HierarchicalCode::homogeneous(4, 2, 4, 2).unwrap();
    let prod = ProductCode::new(4, 2, 4, 2).unwrap();
    let poly = PolynomialCode::new(16, 8).unwrap();
    let rep = ReplicationCode::new(16, 8).unwrap();
    suite.bench("encode_mds_16_8_4096x32", || mds.encode(&a).unwrap());
    suite.bench("encode_hier_4,2x4,2_4096x32", || hier.encode(&a).unwrap());
    suite.bench("encode_product_4,2x4,2_4096x32", || prod.encode(&a).unwrap());
    suite.bench("encode_poly_16_8_4096x32", || poly.encode(&a).unwrap());
    suite.bench("encode_rep_16_8_4096x32", || rep.encode(&a).unwrap());

    // Decode throughput, parity-forcing subsets.
    let x = Matrix::from_fn(32, 4, |_, _| r.uniform(-1.0, 1.0));
    let run_decode = |code: &dyn CodedScheme, drop: usize| {
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        all[drop..].to_vec()
    };
    let subset_h = run_decode(&hier, 2);
    suite.bench("decode_hier_parity_4096x4", || {
        hier.decode(&subset_h, 4096).unwrap().flops
    });
    // Parallel intra-group decode with a pool.
    let pool = Arc::new(DecodePool::new(4).unwrap());
    let hier_par = HierarchicalCode::homogeneous(4, 2, 4, 2)
        .unwrap()
        .with_pool(pool);
    suite.bench("decode_hier_parity_4096x4_pooled", || {
        hier_par.decode(&subset_h, 4096).unwrap().flops
    });
    let subset_p = run_decode(&prod, 2);
    suite.bench("decode_product_parity_4096x4", || {
        prod.decode(&subset_p, 4096).unwrap().flops
    });
    let subset_y = run_decode(&poly, 2);
    suite.bench("decode_poly_parity_4096x4", || {
        poly.decode(&subset_y, 4096).unwrap().flops
    });
    let subset_m = run_decode(&mds, 2);
    suite.bench("decode_mds_parity_4096x4", || {
        mds.decode(&subset_m, 4096).unwrap().flops
    });
    // Systematic fast path (0 flops) for contrast.
    let all_h = {
        let shards = hier.encode(&a).unwrap();
        compute_all_products(&shards, &x)
    };
    suite.bench("decode_hier_systematic_4096x4", || {
        hier.decode(&all_h, 4096).unwrap().flops
    });

    suite.finish();
}
