//! Load-allocation optimizer: distribute inner recovery thresholds
//! `k1_g` across heterogeneous groups to minimize the §III upper bound.
//!
//! The paper's expected-time analysis (§III) and decoding-cost tradeoff
//! (§IV) are really about how code rates are *allocated*: a group with
//! straggly workers (small `µ1_g`) should carry a smaller recovery
//! threshold (more redundancy per worker it actually waits for — or be
//! written off entirely when the outer code can route around it), while
//! reliable groups can shoulder a larger share of the inner dimension.
//! Related hierarchical schemes (Ferdinand–Draper '18, Kiani et al.
//! '19) win precisely by such non-uniform rate/load splits.
//!
//! [`optimize`] searches `k1_g` assignments under a fixed total budget
//! `Σ_g k1_g` (the "global recovery fraction" of the deployment's
//! total workers), minimizing [`crate::sim::bounds::topology_upper`].
//! The search is a deterministic first-improvement hill climb over
//! single-unit transfers starting from the uniform assignment, so the
//! result is always at least as good as uniform — the comparison the
//! `hiercode allocate` CLI and `figures::allocation` report.

use crate::scenario::{GroupSpec, Topology};
use crate::sim::bounds;
use crate::sim::straggler::StragglerModel;
use crate::{Error, Result};

/// An allocation problem: fixed group sizes and straggler rates, a
/// total inner-dimension budget to distribute.
#[derive(Clone, Debug)]
pub struct AllocationProblem {
    /// Workers per group (`n1_g`), fixed.
    pub n1: Vec<usize>,
    /// Outer recovery threshold.
    pub k2: usize,
    /// Per-group worker completion rates `µ1_g`.
    pub mu1: Vec<f64>,
    /// Per-group link rates `µ2_g`.
    pub mu2: Vec<f64>,
    /// Total inner dimension to distribute: `Σ_g k1_g` (each group
    /// needs at least 1 and at most `n1_g`).
    pub total_k1: usize,
}

impl AllocationProblem {
    /// Problem from a global recovery fraction `η`: the budget is
    /// `round(η · Σ n1_g)`, clamped to the feasible range
    /// `[n2, Σ n1_g]`.
    pub fn with_recovery_fraction(
        n1: Vec<usize>,
        k2: usize,
        mu1: Vec<f64>,
        mu2: Vec<f64>,
        recovery: f64,
    ) -> Result<Self> {
        if !(0.0..=1.0).contains(&recovery) {
            return Err(Error::InvalidParams(format!(
                "recovery fraction must be in [0, 1], got {recovery}"
            )));
        }
        if n1.is_empty() || n1.iter().any(|&n| n == 0) {
            return Err(Error::InvalidParams(
                "allocate: every group needs at least one worker".into(),
            ));
        }
        let total: usize = n1.iter().sum();
        let budget = ((recovery * total as f64).round() as usize)
            .clamp(n1.len(), total);
        let p = Self {
            n1,
            k2,
            mu1,
            mu2,
            total_k1: budget,
        };
        p.validate()?;
        Ok(p)
    }

    /// Validate shapes and feasibility.
    pub fn validate(&self) -> Result<()> {
        let n2 = self.n1.len();
        if n2 == 0 || self.k2 == 0 || self.k2 > n2 {
            return Err(Error::InvalidParams(format!(
                "allocate: need 1 <= k2 <= n2, got ({n2}, {})",
                self.k2
            )));
        }
        if self.mu1.len() != n2 || self.mu2.len() != n2 {
            return Err(Error::InvalidParams(format!(
                "allocate: expected {n2} rates, got mu1:{} mu2:{}",
                self.mu1.len(),
                self.mu2.len()
            )));
        }
        if self.n1.iter().any(|&n| n == 0) {
            return Err(Error::InvalidParams("allocate: empty group".into()));
        }
        if self.mu1.iter().chain(&self.mu2).any(|&m| !m.is_finite() || m <= 0.0) {
            return Err(Error::InvalidParams(
                "allocate: rates must be positive and finite".into(),
            ));
        }
        let max: usize = self.n1.iter().sum();
        if self.total_k1 < n2 || self.total_k1 > max {
            return Err(Error::InvalidParams(format!(
                "allocate: total_k1 = {} outside the feasible [{}, {}]",
                self.total_k1, n2, max
            )));
        }
        Ok(())
    }

    /// The topology induced by a `k1` assignment.
    pub fn topology(&self, k1: &[usize]) -> Topology {
        Topology {
            groups: self
                .n1
                .iter()
                .zip(k1)
                .zip(self.mu1.iter().zip(&self.mu2))
                .map(|((&n1, &k1), (&mu1, &mu2))| GroupSpec {
                    n1,
                    k1,
                    worker: StragglerModel::exp(mu1),
                    link: StragglerModel::exp(mu2),
                    scale: None,
                    dead_workers: Vec::new(),
                    subtasks: 1,
                })
                .collect(),
            k2: self.k2,
        }
    }

    /// The uniform (budget spread as evenly as the per-group `n1_g`
    /// caps allow) assignment — the baseline the optimizer must beat.
    pub fn uniform_assignment(&self) -> Vec<usize> {
        let n2 = self.n1.len();
        let mut k1 = vec![1usize; n2];
        let mut left = self.total_k1.saturating_sub(n2);
        // Round-robin single units so the spread stays maximally even
        // under heterogeneous caps.
        while left > 0 {
            let mut placed = false;
            for g in 0..n2 {
                if left == 0 {
                    break;
                }
                if k1[g] < self.n1[g] {
                    k1[g] += 1;
                    left -= 1;
                    placed = true;
                }
            }
            debug_assert!(placed, "validate() guarantees total_k1 <= sum n1");
            if !placed {
                break;
            }
        }
        k1
    }
}

/// Result of an allocation search.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// The optimized per-group thresholds.
    pub k1: Vec<usize>,
    /// §III upper bound of the optimized assignment.
    pub bound: f64,
    /// The uniform baseline assignment.
    pub uniform_k1: Vec<usize>,
    /// §III upper bound of the uniform baseline.
    pub uniform_bound: f64,
    /// Improving single-unit transfers the hill climb took.
    pub moves: usize,
}

impl Allocation {
    /// The optimized topology (paper-rate models).
    pub fn topology(&self, p: &AllocationProblem) -> Topology {
        p.topology(&self.k1)
    }
}

/// Search `k1_g` assignments minimizing the §III upper bound
/// ([`bounds::topology_upper`]) under the problem's total budget.
///
/// Deterministic first-improvement hill climb over single-unit
/// transfers `(k1_a − 1, k1_b + 1)`, starting from
/// [`AllocationProblem::uniform_assignment`]; therefore the returned
/// bound is always ≤ the uniform bound. The move count is capped well
/// above anything a real instance needs, purely as a runaway guard.
pub fn optimize(p: &AllocationProblem) -> Result<Allocation> {
    p.validate()?;
    let n2 = p.n1.len();
    let uniform_k1 = p.uniform_assignment();
    let uniform_bound = bounds::topology_upper(&p.topology(&uniform_k1))?;
    let mut k1 = uniform_k1.clone();
    let mut best = uniform_bound;
    let mut moves = 0usize;
    const MAX_MOVES: usize = 10_000;
    // Strict-improvement threshold keeps the climb from cycling on
    // floating-point noise.
    const EPS: f64 = 1e-12;
    loop {
        let mut improved = false;
        'outer: for a in 0..n2 {
            for b in 0..n2 {
                if a == b || k1[a] <= 1 || k1[b] >= p.n1[b] {
                    continue;
                }
                k1[a] -= 1;
                k1[b] += 1;
                let cand = bounds::topology_upper(&p.topology(&k1))?;
                if cand < best - EPS {
                    best = cand;
                    moves += 1;
                    improved = true;
                    break 'outer;
                }
                // Revert.
                k1[a] += 1;
                k1[b] -= 1;
            }
        }
        if !improved || moves >= MAX_MOVES {
            break;
        }
    }
    Ok(Allocation {
        k1,
        bound: best,
        uniform_k1,
        uniform_bound,
        moves,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::DecodePool;
    use crate::sim::montecarlo;

    fn skewed_problem() -> AllocationProblem {
        // Three reliable groups and one badly straggling group; worker
        // completion times are comparable to link delays so the k1_g
        // assignment genuinely moves E[T], and the budget forces real
        // trade-offs (uniform = 5 per group).
        AllocationProblem {
            n1: vec![10, 10, 10, 10],
            k2: 3,
            mu1: vec![1.0, 1.0, 1.0, 0.05],
            mu2: vec![1.0, 1.0, 1.0, 1.0],
            total_k1: 20,
        }
    }

    #[test]
    fn optimized_bound_beats_uniform_on_skewed_stragglers() {
        // Acceptance: `hiercode allocate` must return an assignment
        // whose §III upper bound is ≤ the uniform assignment's bound.
        let p = skewed_problem();
        let alloc = optimize(&p).unwrap();
        assert_eq!(alloc.uniform_k1, vec![5, 5, 5, 5]);
        assert_eq!(alloc.k1.iter().sum::<usize>(), 20);
        assert!(alloc.k1.iter().all(|&k| (1..=10).contains(&k)));
        assert!(
            alloc.bound <= alloc.uniform_bound,
            "optimized {} must be <= uniform {}",
            alloc.bound,
            alloc.uniform_bound
        );
        // The skew is heavy enough that the optimizer must find a
        // strictly better assignment (it parks budget on the straggly
        // group the subset bound ignores, lightening the groups that
        // actually carry the job).
        assert!(
            alloc.bound < alloc.uniform_bound * 0.99,
            "expected a strict improvement: {} vs {}",
            alloc.bound,
            alloc.uniform_bound
        );
        assert!(alloc.moves > 0);
        // And the improvement is real, not an artifact of the bound:
        // Monte-Carlo E[T] of the optimized topology is no worse.
        let pool = DecodePool::serial();
        let et_uni = montecarlo::expected_latency_topology(
            &p.topology(&alloc.uniform_k1),
            60_000,
            7,
            &pool,
        )
        .unwrap();
        let et_opt =
            montecarlo::expected_latency_topology(&alloc.topology(&p), 60_000, 8, &pool)
                .unwrap();
        assert!(
            et_opt.mean <= et_uni.mean + 3.0 * (et_opt.ci95 + et_uni.ci95),
            "optimized E[T] {} must not exceed uniform {}",
            et_opt.mean,
            et_uni.mean
        );
    }

    #[test]
    fn search_is_deterministic_and_budget_preserving() {
        // Even on a symmetric instance the subset objective may
        // legitimately sacrifice one redundant group (park budget on it
        // and lighten the k2 groups that carry the job) — what must
        // hold is determinism, budget conservation, per-group caps and
        // never losing to uniform.
        let p = AllocationProblem {
            n1: vec![8, 8, 8],
            k2: 2,
            mu1: vec![10.0, 10.0, 10.0],
            mu2: vec![1.0, 1.0, 1.0],
            total_k1: 12,
        };
        let a1 = optimize(&p).unwrap();
        let a2 = optimize(&p).unwrap();
        assert_eq!(a1.k1, a2.k1, "hill climb must be deterministic");
        assert_eq!(a1.bound.to_bits(), a2.bound.to_bits());
        assert_eq!(a1.uniform_k1, vec![4, 4, 4]);
        assert_eq!(a1.k1.iter().sum::<usize>(), 12);
        for (g, &k) in a1.k1.iter().enumerate() {
            assert!(k >= 1 && k <= p.n1[g], "group {g}: k1 = {k}");
        }
        assert!(a1.bound <= a1.uniform_bound);
    }

    #[test]
    fn recovery_fraction_budget_and_validation() {
        let p = AllocationProblem::with_recovery_fraction(
            vec![10, 10],
            1,
            vec![10.0, 10.0],
            vec![1.0, 1.0],
            0.5,
        )
        .unwrap();
        assert_eq!(p.total_k1, 10);
        assert!(AllocationProblem::with_recovery_fraction(
            vec![10, 10],
            1,
            vec![10.0, 10.0],
            vec![1.0, 1.0],
            1.5,
        )
        .is_err());
        // Mismatched rate lists rejected.
        let bad = AllocationProblem {
            n1: vec![4, 4],
            k2: 1,
            mu1: vec![1.0],
            mu2: vec![1.0, 1.0],
            total_k1: 4,
        };
        assert!(bad.validate().is_err());
        // Budget outside the feasible range rejected.
        let bad = AllocationProblem {
            n1: vec![4, 4],
            k2: 1,
            mu1: vec![1.0, 1.0],
            mu2: vec![1.0, 1.0],
            total_k1: 9,
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn uniform_assignment_respects_caps() {
        let p = AllocationProblem {
            n1: vec![2, 10, 3],
            k2: 2,
            mu1: vec![10.0; 3],
            mu2: vec![1.0; 3],
            total_k1: 12,
        };
        let k1 = p.uniform_assignment();
        assert_eq!(k1.iter().sum::<usize>(), 12);
        for (g, &k) in k1.iter().enumerate() {
            assert!(k >= 1 && k <= p.n1[g], "group {g}: k1 = {k}");
        }
        // The small groups saturate, the big one absorbs the rest.
        assert_eq!(k1[0], 2);
        assert_eq!(k1[2], 3);
        assert_eq!(k1[1], 7);
    }
}
