//! Latency analysis of hierarchical coded computation (§III).
//!
//! The paper models worker completion times as i.i.d. `Exp(µ1)` and
//! group→master (ToR) communication as i.i.d. `Exp(µ2)`; the total
//! computation time of the `(n1,k1)×(n2,k2)` code is
//!
//! ```text
//! T = k2-th min over groups i of ( T_i^(c) + S_i ),
//! S_i = k1-th min over workers j of T_{i,j}                    (1)–(2)
//! ```
//!
//! This module provides every piece of the §III analysis:
//!
//! * [`straggler`] — the completion-time distributions;
//! * [`montecarlo`] — direct sampling of `E[T]` (the "simulation" series
//!   of Fig. 6) for hierarchical and all baseline schemes;
//! * [`markov`] — the auxiliary Markov chain of Lemma 1 whose hitting
//!   time is the lower bound `L` of Theorem 1, solved exactly by
//!   first-step analysis;
//! * [`bounds`] — the Lemma 2 and Theorem 2 upper bounds, plus the
//!   heterogeneous-topology generalization (`topology_upper`);
//! * [`allocate`] — the load-allocation optimizer: distribute `k1_g`
//!   across groups to minimize the §III upper bound;
//! * [`events`] — a discrete-event simulation engine, used by
//!   [`engine`] to replay the same job at full event granularity
//!   (validates the direct sampler and powers failure-injection tests).

pub mod allocate;
pub mod bounds;
pub mod engine;
pub mod events;
pub mod markov;
pub mod montecarlo;
pub mod straggler;

/// Parameters of a simulated `(n1,k1)×(n2,k2)` deployment.
#[derive(Clone, Debug, PartialEq)]
pub struct SimParams {
    /// Workers per group.
    pub n1: usize,
    /// Inner code dimension (workers to wait for per group).
    pub k1: usize,
    /// Number of groups (racks).
    pub n2: usize,
    /// Outer code dimension (groups to wait for).
    pub k2: usize,
    /// Worker completion rate `µ1`.
    pub mu1: f64,
    /// Group→master (ToR) communication rate `µ2`.
    pub mu2: f64,
}

impl SimParams {
    /// Validate the parameter set.
    pub fn validate(&self) -> crate::Result<()> {
        if self.k1 == 0 || self.k1 > self.n1 {
            return Err(crate::Error::InvalidParams(format!(
                "need 1 <= k1 <= n1, got ({}, {})",
                self.n1, self.k1
            )));
        }
        if self.k2 == 0 || self.k2 > self.n2 {
            return Err(crate::Error::InvalidParams(format!(
                "need 1 <= k2 <= n2, got ({}, {})",
                self.n2, self.k2
            )));
        }
        if self.mu1 <= 0.0 || self.mu2 <= 0.0 {
            return Err(crate::Error::InvalidParams(format!(
                "rates must be positive: mu1={}, mu2={}",
                self.mu1, self.mu2
            )));
        }
        Ok(())
    }

    /// The paper's Fig. 6 defaults: `n1 = (1+δ1)·k1` with `δ1 = 1`,
    /// `n2 = 10`, `µ1 = 10`, `µ2 = 1`.
    pub fn fig6(k1: usize, k2: usize) -> Self {
        Self {
            n1: 2 * k1,
            k1,
            n2: 10,
            k2,
            mu1: 10.0,
            mu2: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        assert!(SimParams::fig6(5, 5).validate().is_ok());
        let mut p = SimParams::fig6(5, 5);
        p.k1 = 11;
        assert!(p.validate().is_err());
        let mut p = SimParams::fig6(5, 5);
        p.k2 = 11;
        assert!(p.validate().is_err());
        let mut p = SimParams::fig6(5, 5);
        p.mu1 = 0.0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn fig6_defaults_match_paper() {
        let p = SimParams::fig6(300, 7);
        assert_eq!(p.n1, 600);
        assert_eq!(p.n2, 10);
        assert_eq!(p.mu1, 10.0);
        assert_eq!(p.mu2, 1.0);
    }
}
