//! Straggler (completion-time) models.
//!
//! The paper's analysis assumes pure `Exp(µ)` completion times (§III).
//! Real clusters are better fit by a shifted exponential (a deterministic
//! service floor plus an exponential tail — Lee et al., 2017), and heavy
//! tails are sometimes modeled as Weibull. The simulator and coordinator
//! accept any of these so the paper's conclusions can be stress-tested
//! beyond its own model (ablation bench `straggler_models`).

use crate::util::rng::Rng;

/// A completion-time distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StragglerModel {
    /// Pure exponential with rate `mu` — the paper's model.
    Exponential {
        /// Rate parameter (mean `1/mu`).
        mu: f64,
    },
    /// `shift + Exp(mu)`: a deterministic minimum service time.
    ShiftedExponential {
        /// Deterministic floor.
        shift: f64,
        /// Exponential tail rate.
        mu: f64,
    },
    /// Weibull with shape `k` and scale `lambda` (heavy tail for k < 1).
    Weibull {
        /// Shape parameter.
        shape: f64,
        /// Scale parameter.
        scale: f64,
    },
    /// Deterministic time (no straggling) — useful as a control.
    Deterministic {
        /// The fixed completion time.
        value: f64,
    },
}

impl StragglerModel {
    /// The paper's worker model at rate `mu`.
    pub fn exp(mu: f64) -> Self {
        StragglerModel::Exponential { mu }
    }

    /// Draw a completion time.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            StragglerModel::Exponential { mu } => rng.exponential(mu),
            StragglerModel::ShiftedExponential { shift, mu } => {
                rng.shifted_exponential(shift, mu)
            }
            StragglerModel::Weibull { shape, scale } => {
                // Inverse CDF: scale * (-ln(1-U))^(1/shape).
                let u = 1.0 - rng.next_f64();
                scale * (-u.ln()).powf(1.0 / shape)
            }
            StragglerModel::Deterministic { value } => value,
        }
    }

    /// Expected value of the distribution.
    pub fn mean(&self) -> f64 {
        match *self {
            StragglerModel::Exponential { mu } => 1.0 / mu,
            StragglerModel::ShiftedExponential { shift, mu } => shift + 1.0 / mu,
            StragglerModel::Weibull { shape, scale } => scale * gamma_fn(1.0 + 1.0 / shape),
            StragglerModel::Deterministic { value } => value,
        }
    }
}

/// Lanczos approximation of Γ(x) for x > 0 (used only for Weibull
/// means; accuracy ~1e-13 over the needed range).
pub fn gamma_fn(x: f64) -> f64 {
    // Lanczos g = 7, n = 9 coefficients.
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma_fn(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc_mean(model: StragglerModel, n: usize, seed: u64) -> f64 {
        let mut r = Rng::new(seed);
        (0..n).map(|_| model.sample(&mut r)).sum::<f64>() / n as f64
    }

    #[test]
    fn gamma_known_values() {
        assert!((gamma_fn(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma_fn(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma_fn(3.0) - 2.0).abs() < 1e-10);
        assert!((gamma_fn(4.0) - 6.0).abs() < 1e-9);
        assert!((gamma_fn(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn exponential_mean() {
        let m = StragglerModel::exp(10.0);
        assert!((m.mean() - 0.1).abs() < 1e-12);
        assert!((mc_mean(m, 100_000, 1) - 0.1).abs() < 2e-3);
    }

    #[test]
    fn shifted_exponential_mean() {
        let m = StragglerModel::ShiftedExponential { shift: 1.0, mu: 2.0 };
        assert!((m.mean() - 1.5).abs() < 1e-12);
        assert!((mc_mean(m, 100_000, 2) - 1.5).abs() < 5e-3);
        // No sample below the shift.
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(m.sample(&mut r) >= 1.0);
        }
    }

    #[test]
    fn weibull_mean_and_exponential_equivalence() {
        // Weibull(shape=1, scale=s) == Exp(1/s).
        let m = StragglerModel::Weibull { shape: 1.0, scale: 0.5 };
        assert!((m.mean() - 0.5).abs() < 1e-10);
        assert!((mc_mean(m, 200_000, 4) - 0.5).abs() < 5e-3);
        // Heavy-tail shape < 1 has mean > scale.
        let h = StragglerModel::Weibull { shape: 0.5, scale: 1.0 };
        assert!((h.mean() - 2.0).abs() < 1e-9); // Γ(3) = 2
    }

    #[test]
    fn deterministic_is_deterministic() {
        let m = StragglerModel::Deterministic { value: 2.5 };
        let mut r = Rng::new(5);
        for _ in 0..10 {
            assert_eq!(m.sample(&mut r), 2.5);
        }
        assert_eq!(m.mean(), 2.5);
    }
}
