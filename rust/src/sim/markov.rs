//! The auxiliary Markov chain of Lemma 1 and its hitting time — the
//! lower bound `L` of Theorem 1.
//!
//! The chain `C` lives on states `(u, v)` where `u` counts completed
//! workers (globally, across all groups) and `v` counts groups whose
//! results reached the master. Transition rates (Lemma 1):
//!
//! * `(u, v) → (u+1, v)` at rate `(n1·n2 − u)·µ1` while `u < n2·k1`;
//! * `(u, v) → (u, v+1)` at rate `(⌊u/k1⌋ − v)·µ2` while
//!   `v < min(⌊u/k1⌋, k2)`.
//!
//! `L` is the expected hitting time from `(0,0)` to `{v = k2}`. Because
//! every transition increases `u` or `v`, the chain is a DAG and the
//! first-step equations solve exactly by one backward sweep — no linear
//! system needed. Fig. 5 of the paper is this chain for
//! `(3,2) × (3,2)`.

use crate::sim::SimParams;
use crate::util::rng::Rng;
use crate::Result;

/// Exact lower bound `L` via first-step analysis of the Lemma 1 chain.
///
/// Complexity: `O(n2·k1·k2)` states, `O(1)` work each.
pub fn lower_bound(p: &SimParams) -> Result<f64> {
    p.validate()?;
    let (n1, k1, n2, k2) = (p.n1, p.k1, p.n2, p.k2);
    let u_max = n2 * k1;
    let total_workers = n1 * n2;
    // h[u][v] = expected time to reach v = k2 from (u, v).
    let mut h = vec![vec![0.0f64; k2 + 1]; u_max + 1];
    // Backward sweep: h(u, v) depends on h(u+1, v) and h(u, v+1).
    for v in (0..k2).rev() {
        for u in (0..=u_max).rev() {
            // Unreachable corner (v groups delivered needs u >= v·k1
            // workers done) — leave at 0; never queried from (0,0).
            let rate_right = if u < u_max {
                (total_workers - u) as f64 * p.mu1
            } else {
                0.0
            };
            let groups_ready = (u / k1).min(n2);
            let rate_up = if v < groups_ready.min(k2) {
                (groups_ready - v) as f64 * p.mu2
            } else {
                0.0
            };
            let total = rate_right + rate_up;
            if total == 0.0 {
                // No outgoing transition with v < k2 can only happen in
                // unreachable states (u = u_max forces groups_ready =
                // n2 ≥ k2 > v, so rate_up > 0 there).
                h[u][v] = f64::INFINITY;
                continue;
            }
            let mut acc = 1.0;
            if rate_right > 0.0 {
                acc += rate_right * h[u + 1][v];
            }
            if rate_up > 0.0 {
                acc += rate_up * h[u][v + 1];
            }
            h[u][v] = acc / total;
        }
    }
    Ok(h[0][0])
}

/// Monte-Carlo estimate of `L` straight from its definition (Theorem 1,
/// eq. 3): `L = E[ k2-th min_i ( T_i^(c) + T_(i·k1) ) ]` where `T_(m)`
/// is the `m`-th smallest of all `n1·n2` worker times. Used to validate
/// [`lower_bound`]'s chain construction.
pub fn lower_bound_monte_carlo(p: &SimParams, trials: usize, seed: u64) -> Result<f64> {
    p.validate()?;
    let mut rng = Rng::new(seed);
    let total = p.n1 * p.n2;
    let mut sum = 0.0;
    let mut times = vec![0.0f64; total];
    for _ in 0..trials {
        for t in times.iter_mut() {
            *t = rng.exponential(p.mu1);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut candidates: Vec<f64> = (1..=p.n2)
            .map(|i| rng.exponential(p.mu2) + times[i * p.k1 - 1])
            .collect();
        sum += crate::sim::montecarlo::kth_min(&mut candidates, p.k2)?;
    }
    Ok(sum / trials as f64)
}

/// A full trajectory of the chain (for tests and the `markov_solver`
/// bench): simulate jumps until `v = k2`, return elapsed time.
pub fn simulate_hitting_time(p: &SimParams, rng: &mut Rng) -> f64 {
    let (k1, n2, k2) = (p.k1, p.n2, p.k2);
    let u_max = n2 * k1;
    let total_workers = p.n1 * n2;
    let (mut u, mut v) = (0usize, 0usize);
    let mut t = 0.0;
    while v < k2 {
        let rate_right = if u < u_max {
            (total_workers - u) as f64 * p.mu1
        } else {
            0.0
        };
        let groups_ready = (u / k1).min(n2);
        let rate_up = if v < groups_ready.min(k2) {
            (groups_ready - v) as f64 * p.mu2
        } else {
            0.0
        };
        let total = rate_right + rate_up;
        debug_assert!(total > 0.0, "absorbing non-target state ({u},{v})");
        t += rng.exponential(total);
        if rng.next_f64() < rate_right / total {
            u += 1;
        } else {
            v += 1;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial chain (1,1)×(1,1): L = 1/µ1 + 1/µ2 exactly.
    #[test]
    fn trivial_chain_exact() {
        let p = SimParams {
            n1: 1,
            k1: 1,
            n2: 1,
            k2: 1,
            mu1: 10.0,
            mu2: 1.0,
        };
        let l = lower_bound(&p).unwrap();
        assert!((l - (0.1 + 1.0)).abs() < 1e-12, "L = {l}");
    }

    /// Single group, n1 workers: L = (H_n1 − H_{n1−k1})/µ1 + 1/µ2.
    #[test]
    fn single_group_exact() {
        let p = SimParams {
            n1: 8,
            k1: 5,
            n2: 1,
            k2: 1,
            mu1: 4.0,
            mu2: 2.0,
        };
        let l = lower_bound(&p).unwrap();
        let expect =
            crate::util::harmonic::expected_kth_of_n_exponential(5, 8, 4.0) + 0.5;
        assert!((l - expect).abs() < 1e-10, "L = {l}, expect {expect}");
    }

    /// First-step analysis must agree with simulated trajectories of
    /// the same chain.
    #[test]
    fn fsa_matches_chain_simulation() {
        let p = SimParams {
            n1: 3,
            k1: 2,
            n2: 3,
            k2: 2,
            mu1: 10.0,
            mu2: 1.0,
        };
        let exact = lower_bound(&p).unwrap();
        let mut rng = Rng::new(101);
        let trials = 200_000;
        let mc: f64 =
            (0..trials).map(|_| simulate_hitting_time(&p, &mut rng)).sum::<f64>()
                / trials as f64;
        assert!(
            (exact - mc).abs() < 0.01,
            "first-step {exact} vs trajectory MC {mc}"
        );
    }

    /// The chain's hitting time must equal the definition of L (eq. 3).
    /// This is the content of Lemma 1 — the strongest correctness check.
    #[test]
    fn lemma1_chain_equals_definition() {
        for (n1, k1, n2, k2) in [(3, 2, 3, 2), (4, 2, 3, 3), (5, 3, 4, 2)] {
            let p = SimParams {
                n1,
                k1,
                n2,
                k2,
                mu1: 10.0,
                mu2: 1.0,
            };
            let exact = lower_bound(&p).unwrap();
            let mc = lower_bound_monte_carlo(&p, 300_000, 55).unwrap();
            assert!(
                (exact - mc).abs() / exact < 0.02,
                "({n1},{k1})x({n2},{k2}): chain {exact} vs definition-MC {mc}"
            );
        }
    }

    /// Theorem 1: L ≤ E[T] (statistically, with generous margin).
    #[test]
    fn theorem1_lower_bounds_simulation() {
        for k2 in [1, 3, 5, 7, 10] {
            let p = SimParams::fig6(5, k2);
            let l = lower_bound(&p).unwrap();
            let et = crate::sim::montecarlo::expected_latency(&p, 50_000, 77)
                .unwrap();
            assert!(
                l <= et.mean + 3.0 * et.ci95,
                "k2={k2}: L={l} must be ≤ E[T]={}",
                et.mean
            );
        }
    }

    /// L is increasing in k2 (more groups to wait for).
    #[test]
    fn monotone_in_k2() {
        let mut prev = 0.0;
        for k2 in 1..=10 {
            let p = SimParams::fig6(5, k2);
            let l = lower_bound(&p).unwrap();
            assert!(l > prev, "k2={k2}: L={l} <= prev={prev}");
            prev = l;
        }
    }

    /// Large-k1 chain stays finite and fast (Fig. 6b uses k1 = 300 —
    /// a 3000×10 state space).
    #[test]
    fn large_k1_feasible() {
        let p = SimParams::fig6(300, 5);
        let l = lower_bound(&p).unwrap();
        assert!(l.is_finite() && l > 0.0);
    }
}
