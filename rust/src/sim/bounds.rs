//! Upper bounds on `E[T]` — Lemma 2 and Theorem 2 (§III-B).

use crate::sim::SimParams;
use crate::util::harmonic::harmonic;
use crate::Result;

/// Lemma 2: `E[T] ≤ H_{n1·n2}/µ1 + (H_{n2} − H_{n2−k2})/µ2`.
///
/// Wait for *all* `n1·n2` workers (expected `H_{n1n2}/µ1`), then for the
/// `k2`-th fastest of the `n2` group→master links. Valid for all
/// parameters; tight for small `k1` (Fig. 6a).
pub fn lemma2_upper(p: &SimParams) -> Result<f64> {
    p.validate()?;
    Ok(harmonic(p.n1 * p.n2) / p.mu1
        + (harmonic(p.n2) - harmonic(p.n2 - p.k2)) / p.mu2)
}

/// Theorem 2 (asymptotic in `k1`, fixed `δ1 = n1/k1 − 1 > 0`):
/// `E[T] ≤ log((1+δ1)/δ1)/µ1 + (H_{n2} − H_{n2−k2})/µ2 + o(1)`.
///
/// The first term is the limit of the intra-group order statistic
/// `(H_{n1} − H_{n1−k1})/µ1`; concentration (Hoeffding) makes *every*
/// group finish by then, so only the link order statistic is added.
/// Tight for large `k1` (Fig. 6b); anti-conservative for small `k1`.
pub fn theorem2_upper(p: &SimParams) -> Result<f64> {
    p.validate()?;
    if p.n1 <= p.k1 {
        return Err(crate::Error::InvalidParams(format!(
            "theorem 2 needs δ1 = n1/k1 − 1 > 0 (n1={}, k1={})",
            p.n1, p.k1
        )));
    }
    let delta1 = p.n1 as f64 / p.k1 as f64 - 1.0;
    Ok(((1.0 + delta1) / delta1).ln() / p.mu1
        + (harmonic(p.n2) - harmonic(p.n2 - p.k2)) / p.mu2)
}

/// The exact expected intra-group latency `(H_{n1} − H_{n1−k1})/µ1`
/// (the `k1`-th order statistic of one group) — the quantity Theorem 2's
/// `t0` tracks.
pub fn intra_group_latency(p: &SimParams) -> Result<f64> {
    p.validate()?;
    Ok((harmonic(p.n1) - harmonic(p.n1 - p.k1)) / p.mu1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::markov;
    use crate::sim::montecarlo;

    #[test]
    fn lemma2_dominates_simulation() {
        for k2 in [1, 4, 7, 10] {
            let p = SimParams::fig6(5, k2);
            let ub = lemma2_upper(&p).unwrap();
            let et = montecarlo::expected_latency(&p, 50_000, 3).unwrap();
            assert!(
                et.mean <= ub + 3.0 * et.ci95,
                "k2={k2}: E[T]={} must be ≤ Lemma2={ub}",
                et.mean
            );
        }
    }

    #[test]
    fn theorem2_dominates_simulation_for_large_k1() {
        // Fig. 6b regime: k1 = 300, δ1 = 1.
        for k2 in [1, 5, 10] {
            let p = SimParams::fig6(300, k2);
            let ub = theorem2_upper(&p).unwrap();
            let et = montecarlo::expected_latency(&p, 20_000, 5).unwrap();
            assert!(
                et.mean <= ub + 3.0 * et.ci95,
                "k2={k2}: E[T]={} must be ≤ Thm2={ub}",
                et.mean
            );
        }
    }

    #[test]
    fn bounds_sandwich_everything() {
        // L ≤ E[T] ≤ min(Lemma2, Thm2-for-large-k1).
        let p = SimParams::fig6(300, 7);
        let l = markov::lower_bound(&p).unwrap();
        let et = montecarlo::expected_latency(&p, 20_000, 6).unwrap();
        let ub2 = lemma2_upper(&p).unwrap();
        let ubt = theorem2_upper(&p).unwrap();
        assert!(l <= et.mean + 3.0 * et.ci95);
        assert!(et.mean <= ub2 + 3.0 * et.ci95);
        assert!(et.mean <= ubt + 3.0 * et.ci95);
    }

    #[test]
    fn fig6_regime_tightness_flip() {
        // §III-C: "the asymptotic upper bound in Theorem 2 becomes
        // tighter as k1 grows". Theorem 2's expression (o(1) dropped) is
        // only *valid* asymptotically — at small k1 it can dip below the
        // true E[T] (which is why the paper calls Lemma 2 the tighter
        // usable bound there). Robust checks: the Lemma2−Thm2 gap grows
        // with k1, and at k1=300 Theorem 2 is a valid bound strictly
        // tighter than Lemma 2.
        let gap = |k1: usize| {
            let p = SimParams::fig6(k1, 5);
            lemma2_upper(&p).unwrap() - theorem2_upper(&p).unwrap()
        };
        assert!(gap(5) < gap(50));
        assert!(gap(50) < gap(300));
        let large = SimParams::fig6(300, 5);
        assert!(
            theorem2_upper(&large).unwrap() < lemma2_upper(&large).unwrap(),
            "large k1: Theorem 2 should be tighter"
        );
        let et = montecarlo::expected_latency(&large, 20_000, 8).unwrap();
        assert!(et.mean <= theorem2_upper(&large).unwrap() + 3.0 * et.ci95);
    }

    #[test]
    fn theorem2_requires_redundancy() {
        let p = SimParams {
            n1: 5,
            k1: 5,
            n2: 10,
            k2: 5,
            mu1: 10.0,
            mu2: 1.0,
        };
        assert!(theorem2_upper(&p).is_err());
        assert!(lemma2_upper(&p).is_ok(), "Lemma 2 holds for all params");
    }

    #[test]
    fn intra_group_latency_approaches_t0() {
        // (H_{n1} − H_{n1−k1})/µ1 → log((1+δ)/δ)/µ1 as k1 → ∞.
        let limit = (2.0f64).ln() / 10.0; // δ1 = 1, µ1 = 10
        let small = intra_group_latency(&SimParams::fig6(5, 1)).unwrap();
        let large = intra_group_latency(&SimParams::fig6(3000, 1)).unwrap();
        assert!((large - limit).abs() < (small - limit).abs());
        assert!((large - limit).abs() < 1e-3);
    }
}
