//! Upper bounds on `E[T]` — Lemma 2 and Theorem 2 (§III-B), plus the
//! heterogeneous-topology generalization ([`topology_upper`]) the
//! load-allocation optimizer minimizes.

use crate::scenario::Topology;
use crate::sim::SimParams;
use crate::util::harmonic::harmonic;
use crate::{Error, Result};

/// Lemma 2: `E[T] ≤ H_{n1·n2}/µ1 + (H_{n2} − H_{n2−k2})/µ2`.
///
/// Wait for *all* `n1·n2` workers (expected `H_{n1n2}/µ1`), then for the
/// `k2`-th fastest of the `n2` group→master links. Valid for all
/// parameters; tight for small `k1` (Fig. 6a).
pub fn lemma2_upper(p: &SimParams) -> Result<f64> {
    p.validate()?;
    Ok(harmonic(p.n1 * p.n2) / p.mu1
        + (harmonic(p.n2) - harmonic(p.n2 - p.k2)) / p.mu2)
}

/// Theorem 2 (asymptotic in `k1`, fixed `δ1 = n1/k1 − 1 > 0`):
/// `E[T] ≤ log((1+δ1)/δ1)/µ1 + (H_{n2} − H_{n2−k2})/µ2 + o(1)`.
///
/// The first term is the limit of the intra-group order statistic
/// `(H_{n1} − H_{n1−k1})/µ1`; concentration (Hoeffding) makes *every*
/// group finish by then, so only the link order statistic is added.
/// Tight for large `k1` (Fig. 6b); anti-conservative for small `k1`.
pub fn theorem2_upper(p: &SimParams) -> Result<f64> {
    p.validate()?;
    if p.n1 <= p.k1 {
        return Err(crate::Error::InvalidParams(format!(
            "theorem 2 needs δ1 = n1/k1 − 1 > 0 (n1={}, k1={})",
            p.n1, p.k1
        )));
    }
    let delta1 = p.n1 as f64 / p.k1 as f64 - 1.0;
    Ok(((1.0 + delta1) / delta1).ln() / p.mu1
        + (harmonic(p.n2) - harmonic(p.n2 - p.k2)) / p.mu2)
}

/// The exact expected intra-group latency `(H_{n1} − H_{n1−k1})/µ1`
/// (the `k1`-th order statistic of one group) — the quantity Theorem 2's
/// `t0` tracks.
pub fn intra_group_latency(p: &SimParams) -> Result<f64> {
    p.validate()?;
    Ok((harmonic(p.n1) - harmonic(p.n1 - p.k1)) / p.mu1)
}

/// Expected §III group-completion time `E[S_g + C_g]` of one group of a
/// [`Topology`]: the `k1_g`-th order statistic of the group's alive
/// workers plus its mean link delay. `None` when the group can never
/// complete (alive < `k1_g`) or its models are not exponential.
pub fn topology_group_mean(topo: &Topology, g: usize) -> Option<f64> {
    let spec = topo.groups.get(g)?;
    // The slowdown multiplier divides the effective rates.
    let (mu1, mu2) = spec.exponential_rates()?;
    let (mu1, mu2) = (mu1 / spec.slowdown(), mu2 / spec.slowdown());
    let alive = spec.alive();
    if alive < spec.k1 {
        return None;
    }
    Some((harmonic(alive) - harmonic(alive - spec.k1)) / mu1 + 1.0 / mu2)
}

/// Heterogeneous-topology upper bound on `E[T]` — the §III
/// generalization the load allocator minimizes.
///
/// Derivation, following Lemma 2's subset argument: the `k2`-th
/// smallest over *all* groups is dominated by the maximum over any
/// fixed `k2`-subset `G`, so
///
/// ```text
/// E[T] <= E[max_{g∈G} Z_g],   Z_g = S_g + C_g,
/// ```
///
/// with `G` chosen greedily as the `k2` groups of smallest mean
/// `E[Z_g]`. Under the paper's exponential model each `Z_g` is a
/// hypoexponential sum (Rényi's spacings: rates `(a_g − l)·µ1_g` for
/// `l < k1_g` over the `a_g` alive workers, plus the link's `µ2_g`),
/// whose MGF is a closed-form product, and the maximum is bounded by
/// the standard Chernoff/MGF device
///
/// ```text
/// E[max_{g∈G} Z_g] <= min_{0<s<λ_min} (1/s)·ln Σ_{g∈G} M_g(s).
/// ```
///
/// **Partial-work mode** (`subtasks = r > 1`): a group needs `k1·r`
/// sub-results, each worker a rate-`r·µ1` Poisson stream capped at `r`
/// events. After `l` total sub-results at most `⌊l/r⌋` workers are
/// exhausted, so by memorylessness the `l→l+1` spacing is
/// stochastically dominated by `Exp((a_g − ⌊l/r⌋)·r·µ1)` — giving the
/// valid hypoexponential domination `S_g ≤st hypo((a_g − ⌊l/r⌋)·r·µ1)`
/// with the **same mean** as the all-or-nothing spacings (so
/// [`topology_group_mean`] is unchanged) and exactly the `r = 1` rates
/// when sub-tasks are off. The true multi-round `E[T]` drops below
/// this bound as `r` grows (the `figures partial` sweep shows the
/// gap).
///
/// The minimization is a deterministic grid search (the objective is
/// smooth and unimodal in practice; the grid keeps the bound exactly
/// reproducible). Unlike Lemma 2, this bound moves with every `k1_g`,
/// which is what makes it a usable allocation objective. Requires
/// exponential worker/link models on every usable group; errors when
/// fewer than `k2` groups can complete.
pub fn topology_upper(topo: &Topology) -> Result<f64> {
    topo.validate()?;
    // Per usable group: (mean, hypoexponential rates of Z_g).
    let mut cands: Vec<(f64, Vec<f64>)> = Vec::new();
    for (g, spec) in topo.groups.iter().enumerate() {
        let Some((mu1, mu2)) = spec.exponential_rates() else {
            return Err(Error::InvalidParams(format!(
                "topology_upper: group {g} has non-exponential straggler \
                 models (the §III analysis needs Exp(µ))"
            )));
        };
        // A scaled exponential is an exponential at the divided rate.
        let (mu1, mu2) = (mu1 / spec.slowdown(), mu2 / spec.slowdown());
        let alive = spec.alive();
        if alive < spec.k1 {
            continue; // can never complete: excluded from every subset
        }
        let mean = (harmonic(alive) - harmonic(alive - spec.k1)) / mu1 + 1.0 / mu2;
        // Multi-round spacings: (alive − ⌊l/r⌋)·r·µ1 for the k1·r
        // sub-result arrivals — reduces to (alive − l)·µ1 at r = 1.
        let r = spec.subtasks;
        let mut rates: Vec<f64> = (0..spec.recovery_subresults())
            .map(|l| (alive - l / r) as f64 * r as f64 * mu1)
            .collect();
        rates.push(mu2);
        cands.push((mean, rates));
    }
    if cands.len() < topo.k2 {
        return Err(Error::InvalidParams(format!(
            "topology_upper: only {} of {} groups can complete (< k2 = {})",
            cands.len(),
            topo.n2(),
            topo.k2
        )));
    }
    cands.sort_by(|a, b| a.0.total_cmp(&b.0));
    let chosen = &cands[..topo.k2];
    let lam_min = chosen
        .iter()
        .flat_map(|(_, rates)| rates.iter().copied())
        .fold(f64::INFINITY, f64::min);
    // Grid-minimize (1/s)·ln Σ_g M_g(s) over s ∈ (0, λ_min).
    const GRID: usize = 400;
    let mut best = f64::INFINITY;
    let mut logm = vec![0.0f64; chosen.len()];
    for i in 1..=GRID {
        let s = lam_min * i as f64 / (GRID + 1) as f64;
        for (slot, (_, rates)) in logm.iter_mut().zip(chosen.iter()) {
            *slot = rates.iter().map(|&l| (l / (l - s)).ln()).sum::<f64>();
        }
        let mx = logm.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
        let lse = mx + logm.iter().map(|&v| (v - mx).exp()).sum::<f64>().ln();
        best = best.min(lse / s);
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::markov;
    use crate::sim::montecarlo;

    #[test]
    fn lemma2_dominates_simulation() {
        for k2 in [1, 4, 7, 10] {
            let p = SimParams::fig6(5, k2);
            let ub = lemma2_upper(&p).unwrap();
            let et = montecarlo::expected_latency(&p, 50_000, 3).unwrap();
            assert!(
                et.mean <= ub + 3.0 * et.ci95,
                "k2={k2}: E[T]={} must be ≤ Lemma2={ub}",
                et.mean
            );
        }
    }

    #[test]
    fn theorem2_dominates_simulation_for_large_k1() {
        // Fig. 6b regime: k1 = 300, δ1 = 1.
        for k2 in [1, 5, 10] {
            let p = SimParams::fig6(300, k2);
            let ub = theorem2_upper(&p).unwrap();
            let et = montecarlo::expected_latency(&p, 20_000, 5).unwrap();
            assert!(
                et.mean <= ub + 3.0 * et.ci95,
                "k2={k2}: E[T]={} must be ≤ Thm2={ub}",
                et.mean
            );
        }
    }

    #[test]
    fn bounds_sandwich_everything() {
        // L ≤ E[T] ≤ min(Lemma2, Thm2-for-large-k1).
        let p = SimParams::fig6(300, 7);
        let l = markov::lower_bound(&p).unwrap();
        let et = montecarlo::expected_latency(&p, 20_000, 6).unwrap();
        let ub2 = lemma2_upper(&p).unwrap();
        let ubt = theorem2_upper(&p).unwrap();
        assert!(l <= et.mean + 3.0 * et.ci95);
        assert!(et.mean <= ub2 + 3.0 * et.ci95);
        assert!(et.mean <= ubt + 3.0 * et.ci95);
    }

    #[test]
    fn fig6_regime_tightness_flip() {
        // §III-C: "the asymptotic upper bound in Theorem 2 becomes
        // tighter as k1 grows". Theorem 2's expression (o(1) dropped) is
        // only *valid* asymptotically — at small k1 it can dip below the
        // true E[T] (which is why the paper calls Lemma 2 the tighter
        // usable bound there). Robust checks: the Lemma2−Thm2 gap grows
        // with k1, and at k1=300 Theorem 2 is a valid bound strictly
        // tighter than Lemma 2.
        let gap = |k1: usize| {
            let p = SimParams::fig6(k1, 5);
            lemma2_upper(&p).unwrap() - theorem2_upper(&p).unwrap()
        };
        assert!(gap(5) < gap(50));
        assert!(gap(50) < gap(300));
        let large = SimParams::fig6(300, 5);
        assert!(
            theorem2_upper(&large).unwrap() < lemma2_upper(&large).unwrap(),
            "large k1: Theorem 2 should be tighter"
        );
        let et = montecarlo::expected_latency(&large, 20_000, 8).unwrap();
        assert!(et.mean <= theorem2_upper(&large).unwrap() + 3.0 * et.ci95);
    }

    #[test]
    fn topology_upper_dominates_simulation() {
        use crate::parallel::DecodePool;
        use crate::scenario::{GroupSpec, Topology};
        use crate::sim::straggler::StragglerModel;
        let mk = |n1: usize, k1: usize, mu1: f64| GroupSpec {
            worker: StragglerModel::exp(mu1),
            link: StragglerModel::exp(1.0),
            ..GroupSpec::new(n1, k1)
        };
        // Homogeneous check against the seed sampler…
        let hom = Topology::homogeneous(10, 5, 6, 3);
        let ub = topology_upper(&hom).unwrap();
        let et = montecarlo::expected_latency_topology(
            &hom,
            50_000,
            17,
            &DecodePool::serial(),
        )
        .unwrap();
        assert!(
            et.mean <= ub + 3.0 * et.ci95,
            "homogeneous: E[T]={} must be ≤ topology_upper={ub}",
            et.mean
        );
        // …and a skewed heterogeneous topology.
        let het = Topology {
            groups: vec![mk(12, 3, 20.0), mk(8, 6, 10.0), mk(6, 3, 1.0), mk(5, 2, 0.5)],
            k2: 2,
        };
        let ub = topology_upper(&het).unwrap();
        let et = montecarlo::expected_latency_topology(
            &het,
            50_000,
            18,
            &DecodePool::serial(),
        )
        .unwrap();
        assert!(
            et.mean <= ub + 3.0 * et.ci95,
            "heterogeneous: E[T]={} must be ≤ topology_upper={ub}",
            et.mean
        );
        // The bound is at least the best group's mean (max ≥ mean).
        let best_mean = (0..4)
            .filter_map(|g| topology_group_mean(&het, g))
            .fold(f64::INFINITY, f64::min);
        assert!(ub >= best_mean);
    }

    /// Multi-round validity: the spacing-domination bound still
    /// dominates the partial-work E[T] at every r (the true latency
    /// only drops as sub-tasks harvest more straggler work).
    #[test]
    fn topology_upper_dominates_multi_round_simulation() {
        use crate::parallel::DecodePool;
        use crate::scenario::{GroupSpec, Topology};
        use crate::sim::straggler::StragglerModel;
        let topo = |r: usize| Topology {
            groups: vec![
                GroupSpec {
                    worker: StragglerModel::exp(10.0),
                    subtasks: r,
                    ..GroupSpec::new(8, 4)
                },
                GroupSpec {
                    worker: StragglerModel::exp(0.5),
                    subtasks: r,
                    ..GroupSpec::new(6, 3)
                },
            ],
            k2: 2,
        };
        let ub1 = topology_upper(&topo(1)).unwrap();
        let pool = DecodePool::serial();
        for r in [2, 4, 8] {
            let t = topo(r);
            let ub = topology_upper(&t).unwrap();
            let et = montecarlo::expected_latency_topology(&t, 40_000, 23, &pool).unwrap();
            assert!(
                et.mean <= ub + 3.0 * et.ci95,
                "r={r}: E[T]={} must be ≤ topology_upper={ub}",
                et.mean
            );
            // Same mean spacings but lighter tails: the multi-round
            // bound can only tighten relative to r = 1.
            assert!(
                ub <= ub1 * (1.0 + 1e-9),
                "multi-round bound {ub} must not exceed the r=1 bound {ub1}"
            );
        }
    }

    #[test]
    fn topology_upper_moves_with_k1() {
        // Unlike Lemma 2, the heterogeneous bound must respond to the
        // k1_g assignment — that is what makes it an allocation
        // objective. Raising every k1 raises the bound.
        use crate::scenario::Topology;
        let low = Topology::homogeneous(10, 2, 4, 2);
        let high = Topology::homogeneous(10, 8, 4, 2);
        assert!(topology_upper(&low).unwrap() < topology_upper(&high).unwrap());
    }

    #[test]
    fn topology_upper_rejects_bad_inputs() {
        use crate::scenario::{GroupSpec, Topology};
        use crate::sim::straggler::StragglerModel;
        // Non-exponential model.
        let mut t = Topology::homogeneous(4, 2, 2, 1);
        t.groups[0].worker = StragglerModel::Deterministic { value: 1.0 };
        assert!(topology_upper(&t).is_err());
        // Too many dead workers: fewer than k2 usable groups.
        let mut t = Topology {
            groups: vec![GroupSpec::new(3, 2), GroupSpec::new(3, 2)],
            k2: 2,
        };
        t.groups[0].dead_workers = vec![0, 1];
        assert!(topology_upper(&t).is_err());
    }

    #[test]
    fn theorem2_requires_redundancy() {
        let p = SimParams {
            n1: 5,
            k1: 5,
            n2: 10,
            k2: 5,
            mu1: 10.0,
            mu2: 1.0,
        };
        assert!(theorem2_upper(&p).is_err());
        assert!(lemma2_upper(&p).is_ok(), "Lemma 2 holds for all params");
    }

    #[test]
    fn intra_group_latency_approaches_t0() {
        // (H_{n1} − H_{n1−k1})/µ1 → log((1+δ)/δ)/µ1 as k1 → ∞.
        let limit = (2.0f64).ln() / 10.0; // δ1 = 1, µ1 = 10
        let small = intra_group_latency(&SimParams::fig6(5, 1)).unwrap();
        let large = intra_group_latency(&SimParams::fig6(3000, 1)).unwrap();
        assert!((large - limit).abs() < (small - limit).abs());
        assert!((large - limit).abs() < 1e-3);
    }
}
