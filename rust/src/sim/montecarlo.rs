//! Monte-Carlo estimation of expected total computation time `E[T]`.
//!
//! Directly samples the paper's latency expression (1)–(2) — the
//! "Expected total computation time" series of Fig. 6 — as well as the
//! corresponding expressions for the baseline schemes of Table I, all
//! under a pluggable straggler model.

use crate::parallel::DecodePool;
use crate::scenario::Topology;
use crate::sim::straggler::StragglerModel;
use crate::sim::SimParams;
use crate::util::rng::{Rng, SplitMix64};
use crate::util::stats::Welford;
use crate::{Error, Result};

/// `k`-th smallest of a scratch buffer (1-indexed `k`), via quickselect
/// under `f64::total_cmp` — never panics on NaN (total order: negative
/// NaN sorts below every finite value, positive NaN above), so a
/// misbehaving straggler model surfaces as the drivers'
/// [`Error::Numerical`] rather than a quickselect panic. Callers must
/// reject NaN inputs if they need finite order statistics; every
/// in-crate sampler does so at the straggler-model boundary.
///
/// An out-of-range `k` (`k == 0`, whose former `k - 1` would underflow,
/// or `k > buf.len()`, whose `select_nth_unstable_by` would index out
/// of bounds) is a caller bug in the topology arithmetic — rejected
/// with a real [`Error::Numerical`] instead of a release-build panic.
#[inline]
pub fn kth_min(buf: &mut [f64], k: usize) -> Result<f64> {
    if k == 0 || k > buf.len() {
        return Err(Error::Numerical(format!(
            "order statistic k={k} out of range for {} samples \
             (need 1 <= k <= len)",
            buf.len()
        )));
    }
    let (_, v, _) = buf.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
    Ok(*v)
}

/// One sample of the `k`-th order statistic of `n` i.i.d. `Exp(mu)`
/// via Rényi's spacings representation: the gaps between consecutive
/// order statistics are independent `Exp((n−l)·mu)`, so the k-th is a
/// sum of `k` exponentials. §Perf: replaces `n` draws + quickselect
/// with `k` draws — 3.6× faster MC sampling at the Fig. 6b scale.
#[inline]
pub fn sample_kth_of_n_exponential(n: usize, k: usize, mu: f64, rng: &mut Rng) -> f64 {
    debug_assert!(k >= 1 && k <= n);
    let mut t = 0.0;
    for l in 0..k {
        t += rng.exponential((n - l) as f64 * mu);
    }
    t
}

/// One sample of the hierarchical total computation time `T` per
/// (1)–(2): per group, the `k1`-th fastest of `n1` workers plus an
/// `Exp(µ2)` ToR delay; across groups, the `k2`-th fastest sum.
pub fn sample_hierarchical(p: &SimParams, rng: &mut Rng) -> f64 {
    let mut group_done = Vec::with_capacity(p.n2);
    for _ in 0..p.n2 {
        let s_i = sample_kth_of_n_exponential(p.n1, p.k1, p.mu1, rng);
        let t_c = rng.exponential(p.mu2);
        group_done.push(s_i + t_c);
    }
    // Out-of-range k2 poisons the sample; the drivers reject it.
    kth_min(&mut group_done, p.k2).unwrap_or(f64::NAN)
}

/// Same as [`sample_hierarchical`] but with arbitrary worker / link
/// distributions (ablations beyond the paper's Exp model).
///
/// NaN containment: a single NaN worker draw could otherwise vanish
/// inside the order statistic (under `total_cmp` a positive NaN sorts
/// past every finite value, so the `k1`-th min may still be finite)
/// and silently bias the estimate, so any NaN at the straggler-model
/// boundary poisons the whole sample — the MC drivers then reject it
/// with [`Error::Numerical`].
pub fn sample_hierarchical_with(
    p: &SimParams,
    worker_model: &StragglerModel,
    link_model: &StragglerModel,
    rng: &mut Rng,
) -> f64 {
    let mut group_done = Vec::with_capacity(p.n2);
    let mut workers = vec![0.0f64; p.n1];
    for _ in 0..p.n2 {
        for w in workers.iter_mut() {
            *w = worker_model.sample(rng);
        }
        if workers.iter().any(|t| t.is_nan()) {
            return f64::NAN;
        }
        let Ok(s_i) = kth_min(&mut workers, p.k1) else {
            return f64::NAN;
        };
        let link = link_model.sample(rng);
        if link.is_nan() {
            return f64::NAN;
        }
        group_done.push(s_i + link);
    }
    kth_min(&mut group_done, p.k2).unwrap_or(f64::NAN)
}

/// One sample for heterogeneous groups (`n1[i], k1[i]` per group),
/// uniform exponential rates — a thin wrapper over the scenario-layer
/// [`sample_topology`], kept for API convenience (no more parallel
/// sampling logic to drift).
pub fn sample_heterogeneous(
    n1: &[usize],
    k1: &[usize],
    k2: usize,
    mu1: f64,
    mu2: f64,
    rng: &mut Rng,
) -> f64 {
    assert_eq!(n1.len(), k1.len());
    let topo = Topology {
        groups: n1
            .iter()
            .zip(k1)
            .map(|(&n, &k)| crate::scenario::GroupSpec {
                worker: StragglerModel::exp(mu1),
                link: StragglerModel::exp(mu2),
                ..crate::scenario::GroupSpec::new(n, k)
            })
            .collect(),
        k2,
    };
    sample_topology(&topo, rng)
}

/// One sample of the total computation time `T` over a scenario-layer
/// [`Topology`]: per group, the `k1_g`-th fastest of that group's
/// *alive* workers (each drawn from the group's own worker model) plus
/// one draw of the group's link model, the whole group scaled by its
/// slowdown multiplier; across groups, the `k2`-th fastest. A group
/// whose alive worker count is below `k1_g` never completes and
/// contributes `+∞`; NaN draws poison the whole sample (the drivers
/// reject non-finite samples with [`Error::Numerical`]).
///
/// **Partial-work mode** (`subtasks = r > 1`): each alive worker runs
/// `r` sequential sub-tasks of duration `sample/r` each, so its
/// sub-results complete at the partial sums; the group finishes at the
/// `k1·r`-th smallest of all per-sub-task completion times — the
/// order-statistics model of the multi-round scheme (harvested partial
/// work included). Reduces draw-for-draw to the all-or-nothing
/// expression at `r = 1`.
pub fn sample_topology(topo: &Topology, rng: &mut Rng) -> f64 {
    let mut group_done = Vec::with_capacity(topo.n2());
    let mut workers: Vec<f64> = Vec::new();
    for spec in &topo.groups {
        workers.clear();
        let r = spec.subtasks;
        for j in 0..spec.n1 {
            if spec.dead_workers.contains(&j) {
                continue;
            }
            if r == 1 {
                let t = spec.worker.sample(rng);
                if t.is_nan() {
                    return f64::NAN;
                }
                workers.push(t);
            } else {
                // Sequential sub-tasks: sub-result s lands at the
                // partial sum of s+1 draws of sample/r.
                let mut done_at = 0.0f64;
                for _ in 0..r {
                    let d = spec.worker.sample(rng);
                    if d.is_nan() {
                        return f64::NAN;
                    }
                    done_at += d / r as f64;
                    workers.push(done_at);
                }
            }
        }
        if workers.len() < spec.recovery_subresults() {
            group_done.push(f64::INFINITY);
            continue;
        }
        let Ok(s) = kth_min(&mut workers, spec.recovery_subresults()) else {
            return f64::NAN;
        };
        let link = spec.link.sample(rng);
        if link.is_nan() {
            return f64::NAN;
        }
        group_done.push((s + link) * spec.slowdown());
    }
    kth_min(&mut group_done, topo.k2).unwrap_or(f64::NAN)
}

/// Trials per Monte-Carlo shard. Fixed — the shard grid is a function
/// of `trials` alone, never of the thread count — so sharded estimates
/// are bit-identical at any pool width.
pub const MC_SHARD: usize = 8192;

/// Counter-based per-shard RNG stream: shard `s` of run `seed` draws
/// from `xoshiro256++` seeded by `SplitMix64(seed ⊕ s·φ64)`. Streams
/// are a pure function of `(seed, shard)`, so any thread may execute
/// any shard and the sample sequence is unchanged.
fn shard_rng(seed: u64, shard: u64) -> Rng {
    let mut sm = SplitMix64::new(seed ^ shard.wrapping_mul(0x9E3779B97F4A7C15));
    Rng::new(sm.next_u64())
}

/// Monte-Carlo `E[T]` estimate with 95% CI for the hierarchical scheme.
pub fn expected_latency(p: &SimParams, trials: usize, seed: u64) -> Result<Estimate> {
    expected_latency_with(p, trials, seed, &DecodePool::serial())
}

/// [`expected_latency`] with the trials sharded across `pool`.
pub fn expected_latency_with(
    p: &SimParams,
    trials: usize,
    seed: u64,
    pool: &DecodePool,
) -> Result<Estimate> {
    p.validate()?;
    estimate_sharded(trials, seed, pool, |rng| sample_hierarchical(p, rng))
}

/// Monte-Carlo `E[T]` over a scenario-layer [`Topology`], sharded
/// across `pool` — the one estimator heterogeneous scenarios route
/// through. Uniform exponential topologies (the paper's homogeneous
/// case) delegate to the Rényi-spacings sampler of
/// [`expected_latency`], so a uniform config produces **bit-identical**
/// estimates through the Topology path. Topologies that can never
/// decode (too many dead workers) are rejected up front.
pub fn expected_latency_topology(
    topo: &Topology,
    trials: usize,
    seed: u64,
    pool: &DecodePool,
) -> Result<Estimate> {
    topo.validate()?;
    if !topo.survivable() {
        return Err(Error::InvalidParams(format!(
            "topology cannot decode: fewer than k2 = {} groups can reach \
             their recovery threshold",
            topo.k2
        )));
    }
    if let Some(p) = topo.sim_params() {
        return expected_latency_with(&p, trials, seed, pool);
    }
    estimate_sharded(trials, seed, pool, |rng| sample_topology(topo, rng))
}

/// Hierarchical `E[T]` under arbitrary worker / link models, sharded
/// across `pool`. Rejects non-finite samples at the straggler-model
/// boundary with [`Error::Numerical`].
pub fn expected_latency_models(
    p: &SimParams,
    worker_model: &StragglerModel,
    link_model: &StragglerModel,
    trials: usize,
    seed: u64,
    pool: &DecodePool,
) -> Result<Estimate> {
    p.validate()?;
    estimate_sharded(trials, seed, pool, |rng| {
        sample_hierarchical_with(p, worker_model, link_model, rng)
    })
}

/// Sharded MC driver: split `trials` into [`MC_SHARD`]-sized shards,
/// each with its own counter-based RNG stream, fan the shards across
/// `pool`, and merge the per-shard Welford accumulators **in shard
/// order** (Chan's parallel update). Results are bit-identical at any
/// thread count. A non-finite sample (a NaN-producing straggler model)
/// aborts the run with [`Error::Numerical`] instead of poisoning the
/// estimate or panicking downstream order statistics.
pub fn estimate_sharded(
    trials: usize,
    seed: u64,
    pool: &DecodePool,
    sampler: impl Fn(&mut Rng) -> f64 + Sync,
) -> Result<Estimate> {
    let shards: Vec<(u64, usize)> = (0..trials.div_ceil(MC_SHARD))
        .map(|s| (s as u64, MC_SHARD.min(trials - s * MC_SHARD)))
        .collect();
    let accs: Vec<Result<Welford>> = pool.map(shards, |(s, count)| {
        let mut rng = shard_rng(seed, s);
        let mut acc = Welford::new();
        for _ in 0..count {
            let t = sampler(&mut rng);
            if !t.is_finite() {
                return Err(Error::Numerical(format!(
                    "straggler model produced a non-finite sample ({t}) \
                     in Monte-Carlo shard {s}"
                )));
            }
            acc.push(t);
        }
        Ok(acc)
    });
    let mut all = Welford::new();
    for acc in accs {
        all.merge(&acc?);
    }
    Ok(Estimate::from(&all))
}

/// Baseline samplers under Table I's model for non-hierarchical
/// schemes: each of the `n` workers' end-to-end completion (compute +
/// direct cross-rack delivery to the master) is `Exp(µ2)`-dominated.
pub mod baselines {
    use super::*;

    /// Replication `(n, k)`: each block completes at the min of its
    /// `n/k` replicas; the job at the max over blocks.
    pub fn sample_replication(n: usize, k: usize, mu2: f64, rng: &mut Rng) -> f64 {
        assert!(k >= 1 && n % k == 0, "replication needs k | n");
        let r = n / k;
        let mut worst: f64 = 0.0;
        for _ in 0..k {
            let fastest = (0..r).map(|_| rng.exponential(mu2)).fold(f64::INFINITY, f64::min);
            worst = worst.max(fastest);
        }
        worst
    }

    /// MDS-type `(n, k)` (polynomial code): the `k`-th fastest worker.
    pub fn sample_mds(n: usize, k: usize, mu2: f64, rng: &mut Rng) -> f64 {
        let mut times: Vec<f64> = (0..n).map(|_| rng.exponential(mu2)).collect();
        // An out-of-range k poisons the estimate instead of panicking.
        kth_min(&mut times, k).unwrap_or(f64::NAN)
    }

    /// Product code `(n1,k1)×(n2,k2)`: completion when the received
    /// pattern first becomes peelable. Samples all worker times, then
    /// sweeps them in order, testing peelability incrementally.
    pub fn sample_product(
        n1: usize,
        k1: usize,
        n2: usize,
        k2: usize,
        mu2: f64,
        rng: &mut Rng,
    ) -> f64 {
        use crate::coding::CodedScheme;
        let code = crate::coding::ProductCode::new(n1, k1, n2, k2)
            .expect("valid product params");
        let n = n1 * n2;
        let mut order: Vec<(f64, usize)> = (0..n)
            .map(|w| (rng.exponential(mu2), w))
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut present: Vec<usize> = Vec::with_capacity(n);
        // The earliest the pattern can possibly decode is k = k1·k2
        // arrivals; test peelability from there on.
        for (t, w) in order {
            present.push(w);
            if present.len() >= k1 * k2 && code.can_decode(&present) {
                return t;
            }
        }
        f64::INFINITY // unreachable: full grid always decodes
    }
}

/// A Monte-Carlo estimate: mean with uncertainty.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    /// Sample mean.
    pub mean: f64,
    /// 95% confidence half-width.
    pub ci95: f64,
    /// Number of trials.
    pub trials: u64,
}

impl From<&Welford> for Estimate {
    fn from(w: &Welford) -> Self {
        Estimate {
            mean: w.mean(),
            ci95: w.ci95_half_width(),
            trials: w.count(),
        }
    }
}

/// Generic MC driver: average `sampler` over `trials`.
pub fn estimate(trials: usize, seed: u64, mut sampler: impl FnMut(&mut Rng) -> f64) -> Estimate {
    let mut rng = Rng::new(seed);
    let mut acc = Welford::new();
    for _ in 0..trials {
        acc.push(sampler(&mut rng));
    }
    Estimate::from(&acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::harmonic::expected_kth_of_n_exponential;

    #[test]
    fn kth_min_works() {
        let mut v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(kth_min(&mut v, 1).unwrap(), 1.0);
        let mut v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(kth_min(&mut v, 3).unwrap(), 3.0);
        let mut v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(kth_min(&mut v, 5).unwrap(), 5.0);
    }

    #[test]
    fn kth_min_rejects_out_of_range_k_instead_of_panicking() {
        // Satellite regression: k = 0 used to underflow `k - 1` and
        // k > len used to index out of bounds inside quickselect — both
        // are now a real Error::Numerical.
        let mut v = [5.0, 1.0, 3.0];
        assert!(matches!(
            kth_min(&mut v, 0),
            Err(crate::Error::Numerical(_))
        ));
        let mut v = [5.0, 1.0, 3.0];
        assert!(matches!(
            kth_min(&mut v, 4),
            Err(crate::Error::Numerical(_))
        ));
        let mut empty: [f64; 0] = [];
        assert!(kth_min(&mut empty, 1).is_err());
    }

    #[test]
    fn kth_min_tolerates_nan_without_panicking() {
        // total_cmp orders NaN last: finite order statistics are still
        // correct, and nothing panics.
        let mut v = [5.0, f64::NAN, 3.0, 2.0, 4.0];
        assert_eq!(kth_min(&mut v, 1).unwrap(), 2.0);
        let mut v = [5.0, f64::NAN, 3.0, 2.0, 4.0];
        assert!(kth_min(&mut v, 5).unwrap().is_nan());
    }

    #[test]
    fn nan_straggler_model_rejected_at_boundary() {
        let p = SimParams {
            n1: 3,
            k1: 2,
            n2: 2,
            k2: 1,
            mu1: 1.0,
            mu2: 1.0,
        };
        let bad = StragglerModel::Deterministic { value: f64::NAN };
        let link = StragglerModel::Deterministic { value: 0.5 };
        let err = expected_latency_models(
            &p,
            &bad,
            &link,
            1_000,
            3,
            &crate::parallel::DecodePool::serial(),
        );
        assert!(
            matches!(err, Err(crate::Error::Numerical(_))),
            "NaN samples must surface as Error::Numerical, got {err:?}"
        );
    }

    #[test]
    fn sharded_estimate_is_bit_identical_at_any_thread_count() {
        let p = SimParams {
            n1: 6,
            k1: 3,
            n2: 4,
            k2: 2,
            mu1: 10.0,
            mu2: 1.0,
        };
        // Trials spanning several shards plus a partial tail.
        let trials = 3 * MC_SHARD + 517;
        let serial = expected_latency(&p, trials, 99).unwrap();
        for threads in [2, 4, 8] {
            let pool = crate::parallel::DecodePool::new(threads).unwrap();
            let par = expected_latency_with(&p, trials, 99, &pool).unwrap();
            assert_eq!(serial.mean.to_bits(), par.mean.to_bits(), "threads={threads}");
            assert_eq!(serial.ci95.to_bits(), par.ci95.to_bits());
            assert_eq!(serial.trials, par.trials);
        }
    }

    /// Degenerate single-group case: E[T] = (H_n1 - H_{n1-k1})/µ1 + 1/µ2
    /// exactly (order statistic plus one exponential).
    #[test]
    fn single_group_matches_closed_form() {
        let p = SimParams {
            n1: 10,
            k1: 6,
            n2: 1,
            k2: 1,
            mu1: 10.0,
            mu2: 1.0,
        };
        let est = expected_latency(&p, 200_000, 42).unwrap();
        let expect = expected_kth_of_n_exponential(6, 10, 10.0) + 1.0;
        assert!(
            (est.mean - expect).abs() < 4.0 * est.ci95.max(1e-3),
            "mc {} vs closed form {expect}",
            est.mean
        );
    }

    /// k1 = n1 = 1, so S_i = Exp(µ1) and T is the k2-th order statistic
    /// of i.i.d. sums — sanity check monotonicity in k2.
    #[test]
    fn monotone_in_k2() {
        let mut prev = 0.0;
        for k2 in 1..=5 {
            let p = SimParams {
                n1: 4,
                k1: 2,
                n2: 5,
                k2,
                mu1: 10.0,
                mu2: 1.0,
            };
            let est = expected_latency(&p, 50_000, 7).unwrap();
            assert!(
                est.mean > prev,
                "E[T] must increase with k2: k2={k2} mean={}",
                est.mean
            );
            prev = est.mean;
        }
    }

    #[test]
    fn deterministic_models_give_exact_latency() {
        let p = SimParams {
            n1: 3,
            k1: 2,
            n2: 2,
            k2: 2,
            mu1: 1.0,
            mu2: 1.0,
        };
        let wm = StragglerModel::Deterministic { value: 2.0 };
        let lm = StragglerModel::Deterministic { value: 0.5 };
        let mut rng = Rng::new(1);
        let t = sample_hierarchical_with(&p, &wm, &lm, &mut rng);
        assert!((t - 2.5).abs() < 1e-12);
    }

    #[test]
    fn uniform_topology_is_bit_identical_to_seed_sampler() {
        // Acceptance: a uniform config routed through the Topology path
        // must produce the exact bits of the homogeneous estimator.
        let p = SimParams {
            n1: 6,
            k1: 3,
            n2: 4,
            k2: 2,
            mu1: 5.0,
            mu2: 1.0,
        };
        let mut topo = crate::scenario::Topology::homogeneous(6, 3, 4, 2);
        for g in &mut topo.groups {
            g.worker = StragglerModel::exp(5.0);
            g.link = StragglerModel::exp(1.0);
        }
        let trials = MC_SHARD + 321;
        let pool = crate::parallel::DecodePool::serial();
        let direct = expected_latency(&p, trials, 1234).unwrap();
        let via_topo = expected_latency_topology(&topo, trials, 1234, &pool).unwrap();
        assert_eq!(direct.mean.to_bits(), via_topo.mean.to_bits());
        assert_eq!(direct.ci95.to_bits(), via_topo.ci95.to_bits());
        assert_eq!(direct.trials, via_topo.trials);
    }

    #[test]
    fn heterogeneous_topology_estimate_and_dead_workers() {
        use crate::scenario::{GroupSpec, Topology};
        // Two fast groups and one straggly group; k2 = 2 → E[T] should
        // be close to the two fast groups' completion.
        let mk = |n1: usize, k1: usize, mu1: f64| GroupSpec {
            worker: StragglerModel::exp(mu1),
            link: StragglerModel::exp(1.0),
            ..GroupSpec::new(n1, k1)
        };
        // The slow group's rate is extreme so it is essentially never
        // among the k2 fastest — killing it then barely moves E[T].
        let topo = Topology {
            groups: vec![mk(6, 3, 20.0), mk(6, 3, 20.0), mk(6, 3, 0.02)],
            k2: 2,
        };
        let pool = crate::parallel::DecodePool::serial();
        let est = expected_latency_topology(&topo, 50_000, 5, &pool).unwrap();
        assert!(est.mean.is_finite() && est.mean > 0.0);
        // Killing the slow group entirely barely moves the estimate
        // (its samples were almost never among the k2 fastest)...
        let mut dead_slow = topo.clone();
        dead_slow.groups[2].dead_workers = (0..6).collect();
        let est2 = expected_latency_topology(&dead_slow, 50_000, 5, &pool).unwrap();
        assert!(
            (est2.mean - est.mean).abs() < 5.0 * (est.ci95 + est2.ci95) + 0.02,
            "dead slow group: {} vs {}",
            est2.mean,
            est.mean
        );
        // ...but killing a fast group's workers below k1 in TWO groups
        // makes the topology undecodable → clean error, not a hang.
        let mut dead_two = topo.clone();
        dead_two.groups[0].dead_workers = (0..4).collect();
        dead_two.groups[1].dead_workers = (0..4).collect();
        assert!(expected_latency_topology(&dead_two, 1_000, 5, &pool).is_err());
    }

    /// Tentpole acceptance (analysis side): on a straggler-skewed
    /// topology, the multi-round model's E[T] sits strictly below the
    /// all-or-nothing baseline — partial work harvested from the slow
    /// group shortens the critical path (arXiv:1806.10250's tradeoff).
    #[test]
    fn multi_round_subtasks_reduce_expected_latency() {
        use crate::scenario::{GroupSpec, Topology};
        let mk = |mu1: f64, r: usize| GroupSpec {
            worker: StragglerModel::exp(mu1),
            link: StragglerModel::exp(1.0),
            subtasks: r,
            ..GroupSpec::new(6, 3)
        };
        let pool = crate::parallel::DecodePool::serial();
        // k2 = n2: the slow group is always on the critical path.
        let base = Topology {
            groups: vec![mk(10.0, 1), mk(0.5, 1)],
            k2: 2,
        };
        let multi = Topology {
            groups: vec![mk(10.0, 8), mk(0.5, 8)],
            k2: 2,
        };
        let et1 = expected_latency_topology(&base, 60_000, 71, &pool).unwrap();
        let et8 = expected_latency_topology(&multi, 60_000, 72, &pool).unwrap();
        assert!(
            et8.mean + 3.0 * (et8.ci95 + et1.ci95) < et1.mean,
            "multi-round E[T] {} must sit strictly below all-or-nothing {}",
            et8.mean,
            et1.mean
        );
    }

    #[test]
    fn slowdown_multiplier_equals_divided_rates() {
        use crate::scenario::{GroupSpec, Topology};
        // A group with slowdown m under Exp(µ) must behave like an
        // unscaled group at rate µ/m — cluster, sampler and bounds all
        // share that reading.
        let scaled = Topology {
            groups: vec![
                GroupSpec::new(6, 3),
                GroupSpec {
                    scale: Some(4.0),
                    ..GroupSpec::new(6, 3)
                },
            ],
            k2: 2,
        };
        let divided = Topology {
            groups: vec![
                GroupSpec::new(6, 3),
                GroupSpec {
                    worker: StragglerModel::exp(crate::scenario::DEFAULT_MU1 / 4.0),
                    link: StragglerModel::exp(crate::scenario::DEFAULT_MU2 / 4.0),
                    ..GroupSpec::new(6, 3)
                },
            ],
            k2: 2,
        };
        let pool = crate::parallel::DecodePool::serial();
        let a = expected_latency_topology(&scaled, 60_000, 21, &pool).unwrap();
        let b = expected_latency_topology(&divided, 60_000, 22, &pool).unwrap();
        assert!(
            (a.mean - b.mean).abs() < 3.0 * (a.ci95 + b.ci95),
            "scaled {} vs divided-rate {}",
            a.mean,
            b.mean
        );
        // The analytic bound sees the multiplier identically.
        let ub_a = crate::sim::bounds::topology_upper(&scaled).unwrap();
        let ub_b = crate::sim::bounds::topology_upper(&divided).unwrap();
        assert!(
            (ub_a - ub_b).abs() < 1e-9,
            "bounds must agree: {ub_a} vs {ub_b}"
        );
    }

    #[test]
    fn sharded_topology_estimate_bit_identical_across_threads() {
        use crate::scenario::{GroupSpec, Topology};
        let topo = Topology {
            groups: vec![
                GroupSpec::new(8, 4),
                GroupSpec::new(4, 2),
                GroupSpec::new(6, 5),
            ],
            k2: 2,
        };
        let trials = 2 * MC_SHARD + 77;
        let serial =
            expected_latency_topology(&topo, trials, 31, &DecodePool::serial()).unwrap();
        for threads in [2, 4] {
            let pool = crate::parallel::DecodePool::new(threads).unwrap();
            let par = expected_latency_topology(&topo, trials, 31, &pool).unwrap();
            assert_eq!(serial.mean.to_bits(), par.mean.to_bits(), "threads={threads}");
            assert_eq!(serial.ci95.to_bits(), par.ci95.to_bits());
        }
    }

    #[test]
    fn heterogeneous_reduces_to_homogeneous() {
        let p = SimParams {
            n1: 6,
            k1: 3,
            n2: 4,
            k2: 2,
            mu1: 5.0,
            mu2: 1.0,
        };
        let hom = expected_latency(&p, 100_000, 9).unwrap();
        let het = estimate(100_000, 9, |rng| {
            sample_heterogeneous(&[6; 4], &[3; 4], 2, 5.0, 1.0, rng)
        });
        assert!(
            (hom.mean - het.mean).abs() < 3.0 * (hom.ci95 + het.ci95),
            "hom {} vs het {}",
            hom.mean,
            het.mean
        );
    }

    #[test]
    fn replication_matches_table1_formula() {
        // E = k·H_k/(n·µ2).
        let (n, k, mu2) = (12, 4, 2.0);
        let est = estimate(200_000, 11, |rng| {
            baselines::sample_replication(n, k, mu2, rng)
        });
        let expect =
            k as f64 * crate::util::harmonic::harmonic(k) / (n as f64 * mu2);
        assert!(
            (est.mean - expect).abs() < 4.0 * est.ci95.max(1e-3),
            "mc {} vs formula {expect}",
            est.mean
        );
    }

    #[test]
    fn mds_matches_order_statistic() {
        let (n, k, mu2) = (10, 7, 1.0);
        let est = estimate(200_000, 13, |rng| baselines::sample_mds(n, k, mu2, rng));
        let expect = expected_kth_of_n_exponential(k, n, mu2);
        assert!((est.mean - expect).abs() < 4.0 * est.ci95.max(1e-3));
    }

    #[test]
    fn product_sampler_between_mds_and_all() {
        // Peelability needs ≥ k1k2 arrivals but can need more, so the
        // product latency dominates the (n, k1k2) MDS latency and is
        // dominated by waiting for everyone.
        let (n1, k1, n2, k2, mu2) = (4, 2, 4, 2, 1.0);
        let prod = estimate(5_000, 17, |rng| {
            baselines::sample_product(n1, k1, n2, k2, mu2, rng)
        });
        let mds = estimate(100_000, 17, |rng| {
            baselines::sample_mds(n1 * n2, k1 * k2, mu2, rng)
        });
        let all = expected_kth_of_n_exponential(n1 * n2, n1 * n2, mu2);
        assert!(prod.mean >= mds.mean - 3.0 * (prod.ci95 + mds.ci95));
        assert!(prod.mean <= all);
    }
}
