//! Generic discrete-event simulation core: a time-ordered event queue
//! with deterministic tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event: fires at `time`, carries a payload `E`.
#[derive(Clone, Debug)]
struct Scheduled<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on (time, seq): reverse of the natural max-heap order.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A discrete-event queue: schedule events at absolute times, pop them
/// in time order. Ties break by insertion order (deterministic replay).
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at time 0.
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute time `time` (must not precede the
    /// current time).
    pub fn schedule(&mut self, time: f64, payload: E) {
        assert!(
            time >= self.now,
            "cannot schedule into the past: {time} < {}",
            self.now
        );
        assert!(time.is_finite(), "non-finite event time");
        self.heap.push(Scheduled {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` after the current time.
    pub fn schedule_after(&mut self, delay: f64, payload: E) {
        self.schedule(self.now + delay.max(0.0), payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            self.now = s.time;
            (s.time, s.payload)
        })
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.schedule_after(2.0, ());
        assert_eq!(q.pop().unwrap().0, 7.0);
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.pop();
        q.schedule(1.0, ());
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1.0, 0);
        assert_eq!(q.len(), 1);
    }
}
