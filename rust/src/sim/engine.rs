//! Event-driven replay of a hierarchical coded job.
//!
//! Where [`crate::sim::montecarlo`] samples the closed-form latency
//! expression (1)–(2) directly, this engine simulates the *system*:
//! worker-finish events, submaster collection (decode trigger at the
//! `k1`-th arrival), group→master transfers, master completion at the
//! `k2`-th group. Both must agree on `E[T]` under the paper's model —
//! a strong cross-validation — and the engine additionally supports
//! worker/group failure injection and per-event traces the closed form
//! cannot express.

use crate::coding::{CodedScheme, DecodeOutput, WorkerResult};
use crate::linalg::{ops, Matrix};
use crate::scenario::Topology;
use crate::sim::events::EventQueue;
use crate::sim::straggler::StragglerModel;
use crate::sim::SimParams;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Failure injection plan for one simulated job.
#[derive(Clone, Debug, Default)]
pub struct FailurePlan {
    /// Workers that never complete: `(group, index)` pairs.
    pub dead_workers: Vec<(usize, usize)>,
    /// Groups whose uplink to the master is severed.
    pub dead_links: Vec<usize>,
}

/// Timeline of one simulated job.
#[derive(Clone, Debug)]
pub struct JobTrace {
    /// Time each group's subtask finished (`S_i` + queueing), if ever.
    pub group_done: Vec<Option<f64>>,
    /// Time each group's result reached the master, if ever.
    pub group_delivered: Vec<Option<f64>>,
    /// Completion time of the whole job (`T`), if it completed.
    pub total: Option<f64>,
    /// Number of worker-finish events processed.
    pub workers_finished: usize,
}

#[derive(Clone, Copy, Debug)]
enum Event {
    WorkerDone { group: usize },
    GroupDelivered { group: usize },
}

/// Simulate one hierarchical job at event granularity under the
/// paper's uniform model (a thin wrapper over
/// [`simulate_job_topology`] with every group on the same spec).
pub fn simulate_job(
    p: &SimParams,
    worker_model: &StragglerModel,
    link_model: &StragglerModel,
    failures: &FailurePlan,
    rng: &mut Rng,
) -> Result<JobTrace> {
    p.validate()?;
    let topo = Topology::homogeneous_with_models(
        p.n1,
        p.k1,
        p.n2,
        p.k2,
        *worker_model,
        *link_model,
    );
    simulate_job_topology(&topo, failures, rng)
}

/// Simulate one job over a scenario-layer [`Topology`] at event
/// granularity: each group schedules its alive workers from its own
/// worker model, decodes at its own `k1_g`-th arrival, and ships over
/// its own link model; the job completes at the `k2`-th delivery.
/// Dead workers baked into the topology and the ad-hoc `failures` plan
/// are merged.
///
/// **Partial-work mode** (`subtasks = r > 1`): each alive worker emits
/// one event per completed sub-task (at the partial sums of `sample/r`
/// draws) and a group decodes at its `k1·r`-th sub-result — the same
/// multi-round model [`crate::sim::montecarlo::sample_topology`]
/// integrates in closed form, replayed at event granularity.
/// [`JobTrace::workers_finished`] counts *sub-results* (identical to
/// worker results at `r = 1`).
pub fn simulate_job_topology(
    topo: &Topology,
    failures: &FailurePlan,
    rng: &mut Rng,
) -> Result<JobTrace> {
    topo.validate()?;
    let n2 = topo.n2();
    let mut q: EventQueue<Event> = EventQueue::new();
    // Schedule every live worker's (sub-)completions (times scaled by
    // the group's slowdown multiplier, like the live cluster's sleeps).
    for (g, spec) in topo.groups.iter().enumerate() {
        for w in 0..spec.n1 {
            if failures.dead_workers.contains(&(g, w)) || spec.dead_workers.contains(&w) {
                continue;
            }
            if spec.subtasks == 1 {
                q.schedule(
                    spec.worker.sample(rng) * spec.slowdown(),
                    Event::WorkerDone { group: g },
                );
            } else {
                let mut done_at = 0.0f64;
                for _ in 0..spec.subtasks {
                    done_at += spec.worker.sample(rng) / spec.subtasks as f64;
                    q.schedule(done_at * spec.slowdown(), Event::WorkerDone { group: g });
                }
            }
        }
    }
    let mut done_count = vec![0usize; n2];
    let mut group_done: Vec<Option<f64>> = vec![None; n2];
    let mut group_delivered: Vec<Option<f64>> = vec![None; n2];
    let mut delivered = 0usize;
    let mut workers_finished = 0usize;
    let mut total = None;

    while let Some((t, ev)) = q.pop() {
        match ev {
            Event::WorkerDone { group } => {
                workers_finished += 1;
                done_count[group] += 1;
                // Submaster decodes at this group's k1·r-th sub-result
                // and starts the uplink transfer (unless the link is
                // dead).
                if done_count[group] == topo.groups[group].recovery_subresults() {
                    group_done[group] = Some(t);
                    if !failures.dead_links.contains(&group) {
                        let spec = &topo.groups[group];
                        q.schedule_after(
                            spec.link.sample(rng) * spec.slowdown(),
                            Event::GroupDelivered { group },
                        );
                    }
                }
            }
            Event::GroupDelivered { group } => {
                if group_delivered[group].is_none() {
                    group_delivered[group] = Some(t);
                    delivered += 1;
                    if delivered == topo.k2 {
                        total = Some(t);
                        break;
                    }
                }
            }
        }
    }
    Ok(JobTrace {
        group_done,
        group_delivered,
        total,
        workers_finished,
    })
}

/// Outcome of replaying one job's worker arrivals through a streaming
/// decode session (see [`replay_decode`]).
#[derive(Debug)]
pub struct DecodeReplay {
    /// Results pushed before the session reported `Ready` (the job's
    /// recovery threshold under this arrival order).
    pub pushed: usize,
    /// The decode output — real result, flops and session seconds.
    pub output: DecodeOutput,
}

/// Sample a worker arrival order: draw one completion time per worker
/// from `model` and sort.
pub fn sample_arrival_order(
    n: usize,
    model: &StragglerModel,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    let mut times: Vec<(f64, usize)> = (0..n).map(|w| (model.sample(rng), w)).collect();
    // total_cmp keeps the sort panic-free; a NaN completion time is a
    // broken straggler model, not a slow worker, and is rejected at
    // this boundary like the montecarlo drivers reject it at theirs.
    if times.iter().any(|(t, _)| t.is_nan()) {
        return Err(Error::Numerical(
            "straggler model produced NaN sample times".into(),
        ));
    }
    times.sort_by(|a, b| a.0.total_cmp(&b.0));
    Ok(times.into_iter().map(|(_, w)| w).collect())
}

/// Simulated decode-cost accounting through the **same streaming
/// [`crate::coding::Decoder`] sessions the live cluster runs**: encode
/// `a`, feed worker products in `arrival_order` until the session is
/// ready (later arrivals are the discarded stragglers), then finish.
/// Because simulator and coordinator share the sessions, their flop
/// accounting cannot drift apart.
pub fn replay_decode(
    scheme: &dyn CodedScheme,
    a: &Matrix,
    x: &Matrix,
    arrival_order: &[usize],
) -> Result<DecodeReplay> {
    let shards = scheme.encode(a)?;
    let mut session = scheme.decoder(a.rows(), x.cols());
    let mut pushed = 0usize;
    for &w in arrival_order {
        if w >= shards.len() {
            return Err(Error::InvalidParams(format!(
                "arrival order names worker {w}, scheme has {}",
                shards.len()
            )));
        }
        let data = ops::matmul(&shards[w], x);
        pushed += 1;
        if session.push(WorkerResult { shard: w, data })?.is_ready() {
            break;
        }
    }
    Ok(DecodeReplay {
        pushed,
        output: session.finish()?,
    })
}

/// Expected latency by running the event engine `trials` times under
/// the paper's Exp(µ1)/Exp(µ2) model.
pub fn expected_latency_event_driven(
    p: &SimParams,
    trials: usize,
    seed: u64,
) -> Result<crate::sim::montecarlo::Estimate> {
    let wm = StragglerModel::exp(p.mu1);
    let lm = StragglerModel::exp(p.mu2);
    let no_failures = FailurePlan::default();
    let mut rng = Rng::new(seed);
    let mut acc = crate::util::stats::Welford::new();
    for _ in 0..trials {
        let trace = simulate_job(p, &wm, &lm, &no_failures, &mut rng)?;
        acc.push(trace.total.expect("failure-free job must complete"));
    }
    Ok(crate::sim::montecarlo::Estimate::from(&acc))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::montecarlo;

    #[test]
    fn event_engine_agrees_with_direct_sampler() {
        let p = SimParams {
            n1: 6,
            k1: 3,
            n2: 5,
            k2: 3,
            mu1: 10.0,
            mu2: 1.0,
        };
        let ev = expected_latency_event_driven(&p, 40_000, 21).unwrap();
        let mc = montecarlo::expected_latency(&p, 40_000, 22).unwrap();
        assert!(
            (ev.mean - mc.mean).abs() < 3.0 * (ev.ci95 + mc.ci95),
            "event-driven {} vs direct {}",
            ev.mean,
            mc.mean
        );
    }

    #[test]
    fn job_completes_despite_tolerable_failures() {
        // Kill n1 − k1 workers in one group and one whole other group's
        // link: with n2 − k2 ≥ 1 slack, the job must still finish.
        let p = SimParams {
            n1: 4,
            k1: 2,
            n2: 4,
            k2: 3,
            mu1: 10.0,
            mu2: 1.0,
        };
        let failures = FailurePlan {
            dead_workers: vec![(0, 0), (0, 1)], // group 0 down to exactly k1
            dead_links: vec![1],                // group 1 unreachable
        };
        let mut rng = Rng::new(33);
        let trace = simulate_job(
            &p,
            &StragglerModel::exp(p.mu1),
            &StragglerModel::exp(p.mu2),
            &failures,
            &mut rng,
        )
        .unwrap();
        assert!(trace.total.is_some(), "job should survive");
        assert!(trace.group_delivered[1].is_none(), "dead link delivers nothing");
    }

    #[test]
    fn job_stalls_under_excess_failures() {
        // Kill links of n2 − k2 + 1 groups: delivery can never reach k2.
        let p = SimParams {
            n1: 3,
            k1: 2,
            n2: 3,
            k2: 2,
            mu1: 10.0,
            mu2: 1.0,
        };
        let failures = FailurePlan {
            dead_workers: vec![],
            dead_links: vec![0, 1],
        };
        let mut rng = Rng::new(34);
        let trace = simulate_job(
            &p,
            &StragglerModel::exp(p.mu1),
            &StragglerModel::exp(p.mu2),
            &failures,
            &mut rng,
        )
        .unwrap();
        assert!(trace.total.is_none(), "job must not complete");
        // All workers still ran to completion.
        assert_eq!(trace.workers_finished, 9);
    }

    #[test]
    fn decode_replay_agrees_with_batch_path_for_every_scheme() {
        use crate::coding::{build_scheme, compute_all_products, select_results, SchemeKind};
        let mut rng = Rng::new(77);
        let a = Matrix::from_fn(16, 4, |_, _| rng.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(4, 1, |_, _| rng.uniform(-1.0, 1.0));
        let expect = ops::matmul(&a, &x);
        for kind in SchemeKind::ALL {
            let scheme = build_scheme(kind, 4, 2, 4, 2).unwrap();
            let order =
                sample_arrival_order(scheme.num_workers(), &StragglerModel::exp(10.0), &mut rng)
                    .unwrap();
            let replay = replay_decode(scheme.as_ref(), &a, &x, &order).unwrap();
            // Batch decode replays the same order → bit-for-bit equal.
            let shards = scheme.encode(&a).unwrap();
            let all = compute_all_products(&shards, &x);
            let batch = scheme.decode(&select_results(&all, &order), 16).unwrap();
            assert_eq!(
                replay.output.result.data(),
                batch.result.data(),
                "{kind}: results diverge"
            );
            assert_eq!(replay.output.flops, batch.flops, "{kind}: flops diverge");
            assert!(
                replay.output.result.max_abs_diff(&expect) < 1e-6,
                "{kind}: wrong product"
            );
            // The recovery threshold is at least k.
            assert!(replay.pushed >= scheme.num_data_blocks(), "{kind}");
        }
    }

    #[test]
    fn heterogeneous_event_engine_agrees_with_topology_sampler() {
        use crate::scenario::{GroupSpec, Topology};
        use crate::sim::straggler::StragglerModel;
        let mk = |n1: usize, k1: usize, mu1: f64| GroupSpec {
            worker: StragglerModel::exp(mu1),
            link: StragglerModel::exp(1.0),
            ..GroupSpec::new(n1, k1)
        };
        let topo = Topology {
            groups: vec![mk(8, 4, 10.0), mk(4, 2, 2.0), mk(6, 3, 10.0), mk(6, 5, 5.0)],
            k2: 3,
        };
        let trials = 30_000;
        let mut rng = Rng::new(91);
        let mut acc = crate::util::stats::Welford::new();
        let no_failures = FailurePlan::default();
        for _ in 0..trials {
            let trace = simulate_job_topology(&topo, &no_failures, &mut rng).unwrap();
            acc.push(trace.total.expect("failure-free job must complete"));
        }
        let ev = crate::sim::montecarlo::Estimate::from(&acc);
        let mc = crate::sim::montecarlo::expected_latency_topology(
            &topo,
            trials,
            92,
            &crate::parallel::DecodePool::serial(),
        )
        .unwrap();
        assert!(
            (ev.mean - mc.mean).abs() < 3.0 * (ev.ci95 + mc.ci95),
            "event-driven {} vs direct {}",
            ev.mean,
            mc.mean
        );
    }

    /// Multi-round cross-validation: the event engine and the direct
    /// order-statistics sampler integrate the same partial-work model.
    #[test]
    fn multi_round_engine_agrees_with_topology_sampler() {
        use crate::scenario::{GroupSpec, Topology};
        let mk = |n1: usize, k1: usize, mu1: f64, r: usize| GroupSpec {
            worker: StragglerModel::exp(mu1),
            link: StragglerModel::exp(1.0),
            subtasks: r,
            ..GroupSpec::new(n1, k1)
        };
        let topo = Topology {
            groups: vec![mk(6, 3, 10.0, 4), mk(6, 3, 1.0, 4), mk(4, 2, 5.0, 2)],
            k2: 2,
        };
        let trials = 30_000;
        let mut rng = Rng::new(95);
        let mut acc = crate::util::stats::Welford::new();
        let no_failures = FailurePlan::default();
        for _ in 0..trials {
            let trace = simulate_job_topology(&topo, &no_failures, &mut rng).unwrap();
            acc.push(trace.total.expect("failure-free job must complete"));
        }
        let ev = crate::sim::montecarlo::Estimate::from(&acc);
        let mc = crate::sim::montecarlo::expected_latency_topology(
            &topo,
            trials,
            96,
            &crate::parallel::DecodePool::serial(),
        )
        .unwrap();
        assert!(
            (ev.mean - mc.mean).abs() < 3.0 * (ev.ci95 + mc.ci95),
            "event-driven {} vs direct {}",
            ev.mean,
            mc.mean
        );
    }

    #[test]
    fn topology_dead_workers_merge_with_failure_plan() {
        use crate::scenario::{GroupSpec, Topology};
        let mut topo = Topology {
            groups: vec![GroupSpec::new(3, 2), GroupSpec::new(3, 2)],
            k2: 1,
        };
        // Group 0 loses one worker in the scenario and one more from
        // the ad-hoc plan — exactly k1 = 2 alive, still completes.
        topo.groups[0].dead_workers = vec![0];
        let failures = FailurePlan {
            dead_workers: vec![(0, 1)],
            dead_links: vec![1],
        };
        let mut rng = Rng::new(93);
        let trace = simulate_job_topology(&topo, &failures, &mut rng).unwrap();
        assert!(trace.total.is_some());
        // Group 0's two alive workers must both have finished for it to
        // decode; only 5 worker events exist in total (the engine stops
        // at the k2-th delivery, so late group-1 events may be unseen).
        assert!(
            (2..=5).contains(&trace.workers_finished),
            "workers_finished = {}",
            trace.workers_finished
        );
        assert!(trace.group_done[0].is_some(), "group 0 must decode at k1 = 2 alive");
        assert!(trace.group_delivered[1].is_none(), "dead link delivers nothing");
    }

    #[test]
    fn group_done_precedes_delivery() {
        let p = SimParams {
            n1: 4,
            k1: 2,
            n2: 3,
            k2: 2,
            mu1: 5.0,
            mu2: 2.0,
        };
        let mut rng = Rng::new(35);
        let trace = simulate_job(
            &p,
            &StragglerModel::exp(p.mu1),
            &StragglerModel::exp(p.mu2),
            &FailurePlan::default(),
            &mut rng,
        )
        .unwrap();
        for g in 0..p.n2 {
            if let (Some(d), Some(del)) = (trace.group_done[g], trace.group_delivered[g]) {
                assert!(d <= del, "group {g}: done {d} after delivered {del}");
            }
        }
    }
}
