//! `hiercode transport` — socket-cluster verification harness.
//!
//! The socket transport's whole claim is that it is *transparent*: a
//! multi-process cluster must serve the exact bytes the in-memory
//! channels serve, survive a node loss the way the supervisor survives
//! a worker loss, and fail fast when too much of the tree goes dark.
//! This harness measures all three against a live cluster:
//!
//! 1. **Bit-identity** — the same seeded job stream runs once over
//!    in-memory channels and once over a real UDS hub with one node
//!    per group. Every output must match bit for bit
//!    (`f64::to_bits`), and the job/decode counters must agree
//!    exactly: the determinism verdict.
//! 2. **Reconnect** — one group's node goes away mid-stream (real
//!    process kill, or a hub-side sever in `--threads` mode) and comes
//!    back. Jobs during the outage must still complete (`k2 < n2`
//!    redundancy), jobs after recovery must complete, and the hub must
//!    log at least one reconnect with shards re-shipped.
//! 3. **Fast-fail** — `n2 − k2 + 1` nodes go away and stay away.
//!    Probes submitted after the failure detector ages them out must
//!    fail with [`Error::Insufficient`] well before the admission
//!    deadline, never by hanging.
//!
//! By default every node is a real `hiercode node` OS process (spawned
//! from `current_exe`, joined by the wire handshake); `--threads` runs
//! the same node code on in-process threads, which is what the unit
//! test uses (the test binary has no `node` subcommand to exec).
//!
//! Results go to `BENCH_transport.json` in `--out` (default `.`) and
//! the harness exits nonzero when any verdict fails, so CI catches
//! transport regressions, not just crashes. `--smoke` shrinks
//! everything for CI (≈3s total).

use crate::cli::args::Args;
use crate::config::schema::{ClusterConfig, TransportMode};
use crate::coordinator::chaos::FaultInjector;
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::ClusterCore;
use crate::linalg::Matrix;
use crate::transport::node::{run_node, NodeOptions};
use crate::transport::TransportAddr;
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// JSON-safe float literal (same convention as `hiercode bench`).
fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9e}")
    } else {
        "null".to_string()
    }
}

/// The model every run registers and streams against.
const MODEL: &str = "transport";
/// Model shape: rows divisible by both presets' k2·k1 = 4.
const ROWS: usize = 16;
const COLS: usize = 4;

/// A config the harness and every `hiercode node --preset` process can
/// rebuild *identically* — the handshake's cluster id only covers the
/// seed, so the rest of the config (grid, liveness windows) must come
/// from a shared constructor rather than flags that could drift.
pub fn preset(name: &str) -> Result<ClusterConfig> {
    match name {
        // No-redundancy grid: every shard is needed for every decode,
        // so outputs are bitwise independent of arrival order — the
        // bit-identity scenario's oracle.
        "bitident" => {
            let mut config = ClusterConfig::demo(2, 2, 2, 2);
            config.serving.queue_cap = 64;
            Ok(config)
        }
        // Redundant grid with tight liveness windows: same tuning as
        // `hiercode chaos`, for the reconnect and fast-fail scenarios.
        "chaos" => {
            let mut config = ClusterConfig::demo(3, 2, 3, 2);
            config.chaos.liveness = true;
            config.chaos.heartbeat_ms = 5.0;
            config.chaos.suspect_ms = 40.0;
            config.chaos.dead_ms = 120.0;
            config.serving.queue_cap = 64;
            config.serving.default_deadline_ms = 10_000.0;
            config.serving.drain_ms = 2_000.0;
            config.batching.max_wait_ms = 1.0;
            Ok(config)
        }
        other => Err(Error::InvalidParams(format!(
            "unknown transport preset {other:?} (expected bitident or chaos)"
        ))),
    }
}

/// Workload knobs shared by every scenario.
struct TransportLoad {
    seed: u64,
    jobs: usize,
    probe_jobs: usize,
    max_dial_ms: u64,
}

/// How node groups run.
#[derive(Clone, Copy, PartialEq, Eq)]
enum NodeMode {
    /// In-process threads calling `run_node` (unit tests; outages are
    /// hub-side severs).
    Threads,
    /// Real `hiercode node` child processes (the default; outages are
    /// real kills).
    Processes,
}

impl NodeMode {
    fn label(self) -> &'static str {
        match self {
            NodeMode::Threads => "threads",
            NodeMode::Processes => "processes",
        }
    }
}

static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// A fresh per-run UDS address (pid + counter keeps parallel test
/// binaries and repeated runs from colliding on a stale path).
fn fresh_uds() -> String {
    let path = std::env::temp_dir().join(format!(
        "hiercode-tp-{}-{}.sock",
        std::process::id(),
        SOCK_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    format!("uds:{}", path.display())
}

/// One node per group, as threads or child processes, with enough
/// context retained to kill and respawn individual groups.
struct NodeSet {
    mode: NodeMode,
    preset_name: &'static str,
    config: ClusterConfig,
    addr: String,
    max_dial_ms: u64,
    threads: Vec<Option<JoinHandle<Result<()>>>>,
    children: Vec<Option<Child>>,
}

impl NodeSet {
    fn spawn(
        mode: NodeMode,
        preset_name: &'static str,
        config: &ClusterConfig,
        addr: &str,
        load: &TransportLoad,
    ) -> Result<NodeSet> {
        let groups = config.code.topology.n2();
        let mut set = NodeSet {
            mode,
            preset_name,
            config: config.clone(),
            addr: addr.to_string(),
            max_dial_ms: load.max_dial_ms,
            threads: (0..groups).map(|_| None).collect(),
            children: (0..groups).map(|_| None).collect(),
        };
        for g in 0..groups {
            set.start(g)?;
        }
        Ok(set)
    }

    /// (Re)launch group `g`'s node.
    fn start(&mut self, g: usize) -> Result<()> {
        match self.mode {
            NodeMode::Threads => {
                let opts = NodeOptions {
                    config: self.config.clone(),
                    group: g,
                    addr: TransportAddr::parse(&self.addr)?,
                    max_dial_ms: self.max_dial_ms,
                    dial_backoff_ms: 5,
                    dial_backoff_max_ms: 50,
                };
                self.threads[g] = Some(std::thread::spawn(move || run_node(opts)));
            }
            NodeMode::Processes => {
                let exe = std::env::current_exe()?;
                let child = Command::new(exe)
                    .args([
                        "node",
                        "--preset",
                        self.preset_name,
                        "--seed",
                        &self.config.seed.to_string(),
                        "--group",
                        &g.to_string(),
                        "--connect",
                        &self.addr,
                        "--max-dial-ms",
                        &self.max_dial_ms.to_string(),
                        "--backoff-ms",
                        "5",
                        "--backoff-max-ms",
                        "50",
                    ])
                    .stdout(Stdio::null())
                    .spawn()?;
                self.children[g] = Some(child);
            }
        }
        Ok(())
    }

    /// Take group `g` down: a real kill in process mode, a hub-side
    /// sever (connection teardown + reject-while-severed) in thread
    /// mode — a thread cannot be killed from outside.
    fn take_down(&mut self, injector: &Arc<dyn FaultInjector>, g: usize) -> Result<()> {
        match self.mode {
            NodeMode::Threads => {
                injector.link_sever(g);
                Ok(())
            }
            NodeMode::Processes => {
                if let Some(mut child) = self.children[g].take() {
                    child.kill()?;
                    child.wait()?;
                }
                // The node thread slot stays empty until `bring_back`.
                Ok(())
            }
        }
    }

    /// Undo [`take_down`]: heal the sever (the node is still dialing)
    /// or respawn the killed process.
    fn bring_back(&mut self, injector: &Arc<dyn FaultInjector>, g: usize) -> Result<()> {
        match self.mode {
            NodeMode::Threads => {
                injector.link_heal(g);
                Ok(())
            }
            NodeMode::Processes => self.start(g),
        }
    }

    /// Reap every node. Errors are tolerated: a killed process exits
    /// nonzero by design, and a node whose dial window expired after
    /// the hub closed returns `Err` — neither says anything the
    /// scenario verdicts have not already measured.
    fn join(mut self) {
        for t in &mut self.threads {
            if let Some(t) = t.take() {
                let _ = t.join();
            }
        }
        for c in &mut self.children {
            if let Some(mut c) = c.take() {
                let _ = c.wait();
            }
        }
    }
}

/// Launch a socket-mode core on a fresh UDS hub plus one node per
/// group, and wait for the full tree to join.
fn launch_socket(
    preset_name: &'static str,
    load: &TransportLoad,
    mode: NodeMode,
) -> Result<(ClusterCore, NodeSet)> {
    let mut config = preset(preset_name)?;
    config.seed = load.seed;
    config.transport.mode = TransportMode::Socket;
    config.transport.listen = fresh_uds();
    let addr = config.transport.listen.clone();
    let core = ClusterCore::launch(&config)?;
    let nodes = NodeSet::spawn(mode, preset_name, &config, &addr, load)?;
    if !core.wait_connected(load.max_dial_ms) {
        nodes.join();
        core.shutdown();
        return Err(Error::Coordinator(format!(
            "transport harness: nodes failed to join {addr} within {}ms",
            load.max_dial_ms
        )));
    }
    Ok((core, nodes))
}

/// Register the seeded model and serve `jobs` seeded requests
/// sequentially (submit-then-wait, so each batch holds exactly one
/// request and the jobs counter is deterministic).
fn run_stream(core: &ClusterCore, rng: &mut Rng, jobs: usize) -> Result<Vec<Vec<f64>>> {
    let client = core.handle();
    let mut outputs = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        let x: Vec<f64> = (0..COLS).map(|_| rng.uniform(-1.0, 1.0)).collect();
        outputs.push(client.submit_to(MODEL, x)?.wait_timeout(Duration::from_secs(15))?);
    }
    Ok(outputs)
}

/// The counters that must agree exactly between transports. (Worker
/// products and decode timings are node-local in socket mode, so they
/// are deliberately absent.)
#[derive(PartialEq, Eq)]
struct StreamCounters {
    jobs: u64,
    completed: u64,
    group_decodes: u64,
    decode_flops: u64,
}

impl StreamCounters {
    fn of(snap: &MetricsSnapshot) -> StreamCounters {
        StreamCounters {
            jobs: snap.jobs,
            completed: snap.completed,
            group_decodes: snap.group_decodes,
            decode_flops: snap.decode_flops,
        }
    }

    fn render(&self) -> String {
        format!(
            "{{\"jobs\": {}, \"completed\": {}, \"group_decodes\": {}, \
             \"decode_flops\": {}}}",
            self.jobs, self.completed, self.group_decodes, self.decode_flops
        )
    }
}

/// Scenario 1 outcome.
struct BitIdentity {
    memory: StreamCounters,
    socket: StreamCounters,
    socket_metrics_json: String,
    bit_identical: bool,
}

impl BitIdentity {
    fn ok(&self) -> bool {
        self.bit_identical && self.memory == self.socket
    }
}

/// Same seeded stream over in-memory channels and over a UDS hub; the
/// outputs must match bit for bit and the counters exactly.
fn run_bit_identity(load: &TransportLoad, mode: NodeMode) -> Result<BitIdentity> {
    // Reference run: in-memory transport.
    let config = {
        let mut c = preset("bitident")?;
        c.seed = load.seed;
        c
    };
    let core = ClusterCore::launch(&config)?;
    let mut rng = Rng::new(load.seed);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| rng.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a)?;
    let mem_out = run_stream(&core, &mut rng, load.jobs)?;
    let mem_snap = core.metrics();
    core.shutdown();

    // Same stream over the wire.
    let (core, nodes) = launch_socket("bitident", load, mode)?;
    let mut rng = Rng::new(load.seed);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| rng.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a)?;
    let sock_out = run_stream(&core, &mut rng, load.jobs)?;
    let sock_snap = core.metrics();
    core.shutdown();
    nodes.join();

    let bit_identical = mem_out.len() == sock_out.len()
        && mem_out.iter().zip(&sock_out).all(|(m, s)| {
            m.len() == s.len()
                && m.iter().zip(s).all(|(x, y)| x.to_bits() == y.to_bits())
        });
    Ok(BitIdentity {
        memory: StreamCounters::of(&mem_snap),
        socket: StreamCounters::of(&sock_snap),
        socket_metrics_json: sock_snap.to_json(),
        bit_identical,
    })
}

/// Scenario 2 outcome.
struct Reconnect {
    baseline_completed: u64,
    outage_completed: u64,
    post_completed: u64,
    reconnects: u64,
}

impl Reconnect {
    fn ok(&self, jobs: usize) -> bool {
        self.baseline_completed == jobs as u64
            && self.outage_completed == jobs as u64
            && self.post_completed == jobs as u64
            && self.reconnects >= 1
    }
}

/// Count how many of `jobs` seeded submissions complete (a failure is
/// tallied, not fatal — the verdict is the count).
fn count_completed(core: &ClusterCore, rng: &mut Rng, jobs: usize) -> Result<u64> {
    let client = core.handle();
    let mut completed = 0u64;
    for _ in 0..jobs {
        let x: Vec<f64> = (0..COLS).map(|_| rng.uniform(-1.0, 1.0)).collect();
        if client
            .submit_to(MODEL, x)?
            .wait_timeout(Duration::from_secs(15))
            .is_ok()
        {
            completed += 1;
        }
    }
    Ok(completed)
}

/// Kill one group's node mid-stream and bring it back: jobs must keep
/// completing throughout (k2 = 2 of 3 groups suffice) and the hub must
/// record the reconnect (which also re-ships the model shards).
fn run_reconnect(load: &TransportLoad, mode: NodeMode) -> Result<Reconnect> {
    let (core, mut nodes) = launch_socket("chaos", load, mode)?;
    let injector = core.injector();
    let mut rng = Rng::new(load.seed);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| rng.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a)?;
    let baseline_completed = count_completed(&core, &mut rng, load.jobs)?;

    let victim = core.metrics().per_group.len() - 1;
    nodes.take_down(&injector, victim)?;
    let outage_completed = count_completed(&core, &mut rng, load.jobs)?;

    nodes.bring_back(&injector, victim)?;
    let rejoined = core.wait_connected(load.max_dial_ms);
    let post_completed = count_completed(&core, &mut rng, load.jobs)?;
    let reconnects = core.metrics().transport_reconnects;
    core.shutdown();
    nodes.join();
    if !rejoined {
        return Err(Error::Coordinator(format!(
            "transport harness: group {victim} never rejoined after recovery"
        )));
    }
    Ok(Reconnect {
        baseline_completed,
        outage_completed,
        post_completed,
        reconnects,
    })
}

/// Scenario 3 outcome.
struct FastFail {
    baseline_completed: u64,
    severed: usize,
    insufficient: u64,
    unexpected: u64,
    max_fail_ms: f64,
}

impl FastFail {
    fn ok(&self, probe_jobs: usize) -> bool {
        self.insufficient == probe_jobs as u64 && self.unexpected == 0
    }
}

/// Take down an unsurvivable `n2 − k2 + 1` groups and verify probes
/// fail fast with [`Error::Insufficient`] once the detector ages the
/// silent groups out.
fn run_fast_fail(load: &TransportLoad, mode: NodeMode) -> Result<FastFail> {
    let config = preset("chaos")?;
    let (core, mut nodes) = launch_socket("chaos", load, mode)?;
    let injector = core.injector();
    let mut rng = Rng::new(load.seed);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| rng.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a)?;
    let baseline_completed = count_completed(&core, &mut rng, 2)?;

    let n2 = config.code.topology.n2();
    let k2 = config.code.topology.k2;
    let victims: Vec<usize> = (0..n2).rev().take(n2 - k2 + 1).collect();
    for &g in &victims {
        nodes.take_down(&injector, g)?;
    }
    // Let the teardown land and the detector age the silent groups out
    // (dead_ms), with margin.
    std::thread::sleep(Duration::from_millis(50 + config.chaos.dead_ms as u64 + 80));

    let client = core.handle();
    let (mut insufficient, mut unexpected) = (0u64, 0u64);
    let mut max_fail_ms = 0.0f64;
    for _ in 0..load.probe_jobs {
        let x: Vec<f64> = (0..COLS).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let t = Instant::now();
        // 5s guard, far below the 10s admission deadline: a probe that
        // needs it did NOT fail fast.
        match client.submit_to(MODEL, x)?.wait_timeout(Duration::from_secs(5)) {
            Err(Error::Insufficient { .. }) => {
                insufficient += 1;
                max_fail_ms = max_fail_ms.max(t.elapsed().as_secs_f64() * 1e3);
            }
            _ => unexpected += 1,
        }
    }
    // Heal before shutdown so still-dialing thread-mode nodes can
    // rejoin and receive their Shutdown frames instead of burning
    // their full dial window against a closed hub.
    if mode == NodeMode::Threads {
        for &g in &victims {
            nodes.bring_back(&injector, g)?;
        }
        core.wait_connected(load.max_dial_ms);
    }
    core.shutdown();
    nodes.join();
    Ok(FastFail {
        baseline_completed,
        severed: victims.len(),
        insufficient,
        unexpected,
        max_fail_ms,
    })
}

/// Render the `BENCH_transport.json` document.
fn render_json(
    smoke: bool,
    mode: NodeMode,
    load: &TransportLoad,
    bit: &BitIdentity,
    rec: &Reconnect,
    ff: &FastFail,
    pass: bool,
) -> String {
    format!(
        "{{\n\
         \x20 \"schema\": \"hiercode-bench/transport/v1\",\n\
         \x20 \"smoke\": {smoke},\n\
         \x20 \"seed\": {},\n\
         \x20 \"mode\": \"{}\",\n\
         \x20 \"bit_identity\": {{\n\
         \x20   \"jobs\": {}, \"memory\": {}, \"socket\": {},\n\
         \x20   \"bit_identical\": {}, \"counters_match\": {}\n\
         \x20 }},\n\
         \x20 \"reconnect\": {{\n\
         \x20   \"baseline_completed\": {}, \"outage_completed\": {},\n\
         \x20   \"post_completed\": {}, \"reconnects\": {}, \"ok\": {}\n\
         \x20 }},\n\
         \x20 \"fast_fail\": {{\n\
         \x20   \"baseline_completed\": {}, \"severed\": {}, \"probe_jobs\": {},\n\
         \x20   \"insufficient\": {}, \"unexpected\": {},\n\
         \x20   \"max_fail_ms\": {}, \"ok\": {}\n\
         \x20 }},\n\
         \x20 \"verdict\": \"{}\",\n\
         \x20 \"metrics\": {}\n\
         }}\n",
        load.seed,
        mode.label(),
        load.jobs,
        bit.memory.render(),
        bit.socket.render(),
        bit.bit_identical,
        bit.memory == bit.socket,
        rec.baseline_completed,
        rec.outage_completed,
        rec.post_completed,
        rec.reconnects,
        rec.ok(load.jobs),
        ff.baseline_completed,
        ff.severed,
        load.probe_jobs,
        ff.insufficient,
        ff.unexpected,
        jf(ff.max_fail_ms),
        ff.ok(load.probe_jobs),
        if pass { "pass" } else { "fail" },
        bit.socket_metrics_json,
    )
}

/// Run the transport harness; writes `BENCH_transport.json`.
pub fn run(args: &Args) -> Result<()> {
    let smoke = args.has_flag("smoke");
    let out_dir = args.get_str("out").unwrap_or(".").to_string();
    let mode = if args.has_flag("threads") {
        NodeMode::Threads
    } else {
        NodeMode::Processes
    };
    let load = TransportLoad {
        seed: args.get_usize("seed")?.unwrap_or(42) as u64,
        jobs: args.get_usize("jobs")?.unwrap_or(if smoke { 3 } else { 8 }),
        probe_jobs: args.get_usize("probe-jobs")?.unwrap_or(if smoke { 2 } else { 3 }),
        max_dial_ms: args
            .get_usize("max-dial-ms")?
            .unwrap_or(if smoke { 4_000 } else { 10_000 }) as u64,
    };
    if load.jobs == 0 || load.probe_jobs == 0 || load.max_dial_ms == 0 {
        return Err(Error::InvalidParams(
            "--jobs, --probe-jobs and --max-dial-ms must be positive".into(),
        ));
    }
    eprintln!(
        "## hiercode transport (smoke={smoke}, mode={}, seed={}, {} jobs, \
         {} probes)",
        mode.label(),
        load.seed,
        load.jobs,
        load.probe_jobs
    );
    let bit = run_bit_identity(&load, mode)?;
    println!(
        "transport bit-identity: identical={} counters_match={} \
         (memory {}, socket {})",
        bit.bit_identical,
        bit.memory == bit.socket,
        bit.memory.render(),
        bit.socket.render()
    );
    let rec = run_reconnect(&load, mode)?;
    println!(
        "transport reconnect: {}/{}/{} completed (baseline/outage/post), \
         {} reconnects",
        rec.baseline_completed, rec.outage_completed, rec.post_completed, rec.reconnects
    );
    let ff = run_fast_fail(&load, mode)?;
    println!(
        "transport fast-fail: {} baseline ok, {} severed, {}/{} probes \
         Insufficient (max fail {:.1}ms)",
        ff.baseline_completed, ff.severed, ff.insufficient, load.probe_jobs, ff.max_fail_ms
    );
    let pass = bit.ok() && rec.ok(load.jobs) && ff.baseline_completed == 2 && ff.ok(load.probe_jobs);
    let json = render_json(smoke, mode, &load, &bit, &rec, &ff, pass);
    let path = format!("{out_dir}/BENCH_transport.json");
    std::fs::write(&path, json)?;
    println!("wrote {path}");
    if !pass {
        return Err(Error::Coordinator(format!(
            "transport verdict FAILED (see {path}): bit_identity={}, \
             reconnect={}, fast_fail={}",
            bit.ok(),
            rec.ok(load.jobs),
            ff.ok(load.probe_jobs)
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_transport_writes_report_and_passes() {
        let dir = std::env::temp_dir().join("hiercode_transport_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.to_str().unwrap().to_string();
        // Thread mode: the test binary cannot exec itself as `hiercode
        // node`, and hub-side severs exercise the same reconnect path.
        let args = Args::parse(&[
            "--smoke".to_string(),
            "--threads".to_string(),
            "--jobs".to_string(),
            "2".to_string(),
            "--probe-jobs".to_string(),
            "2".to_string(),
            "--out".to_string(),
            out,
        ])
        .unwrap();
        run(&args).unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_transport.json")).unwrap();
        let v = crate::config::json::Json::parse(&text).unwrap();
        assert_eq!(
            v.get("schema").and_then(|s| s.as_str()),
            Some("hiercode-bench/transport/v1")
        );
        let bit = v.get("bit_identity").unwrap();
        assert_eq!(bit.get("bit_identical").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(bit.get("counters_match").and_then(|b| b.as_bool()), Some(true));
        let rec = v.get("reconnect").unwrap();
        assert_eq!(rec.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert!(rec.get("reconnects").and_then(|n| n.as_usize()).unwrap() >= 1);
        let ff = v.get("fast_fail").unwrap();
        assert_eq!(ff.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(v.get("verdict").and_then(|s| s.as_str()), Some("pass"));
        // The embedded metrics snapshot carries real transport traffic.
        let metrics = v.get("metrics").unwrap();
        assert!(
            metrics
                .get("transport_bytes_sent")
                .and_then(|n| n.as_usize())
                .unwrap()
                > 0
        );
    }

    #[test]
    fn transport_rejects_bad_arguments_and_presets() {
        for bad in [vec!["--jobs", "0"], vec!["--probe-jobs", "0"]] {
            let argv: Vec<String> = bad.iter().map(|s| s.to_string()).collect();
            let args = Args::parse(&argv).unwrap();
            assert!(run(&args).is_err(), "must reject {bad:?}");
        }
        assert!(preset("bitident").is_ok());
        assert!(preset("chaos").is_ok());
        assert!(preset("carrier-pigeon").is_err());
    }
}
