//! `hiercode node` — one submaster/worker group as its own OS process.
//!
//! The master side launches with `transport.mode = "socket"` (or
//! `hiercode serve --transport uds:/tmp/hub.sock`) and listens; each
//! `hiercode node` process rebuilds the same scheme from the same
//! config, dials in, handshakes, and serves its group until the hub
//! sends `Shutdown`:
//!
//! ```text
//! hiercode serve --transport uds:/tmp/hub.sock --requests 8 &
//! hiercode node --demo 4,2,4,2 --group 0 --connect uds:/tmp/hub.sock &
//! hiercode node --demo 4,2,4,2 --group 1 --connect uds:/tmp/hub.sock &
//! ...
//! ```
//!
//! The config **must** match the master's — the handshake checks the
//! seed as a cluster id, which catches the obvious mispairings (and
//! `--preset` reproduces the exact configs the `hiercode transport`
//! harness uses, so its child processes cannot drift).

use crate::cli::args::Args;
use crate::config::schema::ClusterConfig;
use crate::transport::node::{run_node, NodeOptions};
use crate::transport::TransportAddr;
use crate::{Error, Result};

/// Parse the CLI into [`NodeOptions`] (separated from [`run`] so tests
/// can inspect the resolved options without dialing anything).
pub fn options(args: &Args) -> Result<NodeOptions> {
    let group = args.get_usize("group")?.ok_or_else(|| {
        Error::InvalidParams("--group is required (which group this node hosts)".into())
    })?;
    let connect = args.get_str("connect").ok_or_else(|| {
        Error::InvalidParams(
            "--connect is required (the hub address, e.g. uds:/tmp/hub.sock)".into(),
        )
    })?;
    let addr = TransportAddr::parse(connect)?;
    let mut config = match (
        args.get_str("config"),
        args.get_str("preset"),
        args.get_usize_list("demo")?,
    ) {
        (Some(path), None, None) => ClusterConfig::from_file(path)?,
        (None, Some(name), None) => super::transportcmd::preset(name)?,
        (None, None, Some(dims)) => match dims.as_slice() {
            &[n1, k1, n2, k2] => ClusterConfig::demo(n1, k1, n2, k2),
            _ => {
                return Err(Error::InvalidParams(
                    "--demo expects n1,k1,n2,k2 (four integers)".into(),
                ))
            }
        },
        (None, None, None) => {
            return Err(Error::InvalidParams(
                "one of --config FILE, --preset NAME or --demo n1,k1,n2,k2 \
                 is required (must match the master's config)"
                    .into(),
            ))
        }
        _ => {
            return Err(Error::InvalidParams(
                "--config, --preset and --demo are mutually exclusive".into(),
            ))
        }
    };
    if let Some(seed) = args.get_usize("seed")? {
        config.seed = seed as u64;
    }
    if args.has_flag("no-pjrt") {
        config.runtime.use_pjrt = false;
    }
    let dial_backoff_ms = args
        .get_usize("backoff-ms")?
        .map(|v| v as u64)
        .unwrap_or(config.transport.dial_backoff_ms as u64);
    let dial_backoff_max_ms = args
        .get_usize("backoff-max-ms")?
        .map(|v| v as u64)
        .unwrap_or(config.transport.dial_backoff_max_ms as u64);
    let max_dial_ms = args
        .get_usize("max-dial-ms")?
        .map(|v| v as u64)
        .unwrap_or(config.transport.connect_wait_ms as u64);
    Ok(NodeOptions {
        config,
        group,
        addr,
        max_dial_ms,
        dial_backoff_ms,
        dial_backoff_max_ms,
    })
}

/// Run a node process until clean shutdown or a fatal transport error.
pub fn run(args: &Args) -> Result<()> {
    run_node(options(args)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        let argv: Vec<String> = parts.iter().map(|s| s.to_string()).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn required_arguments_are_enforced() {
        assert!(options(&parse(&["--connect", "uds:/tmp/x.sock"])).is_err());
        assert!(options(&parse(&["--group", "0"])).is_err());
        // No config source.
        assert!(options(&parse(&["--group", "0", "--connect", "uds:/tmp/x.sock"])).is_err());
        // Mutually exclusive sources.
        assert!(options(&parse(&[
            "--group", "0", "--connect", "uds:/tmp/x.sock", "--demo", "2,2,2,2",
            "--preset", "bitident",
        ]))
        .is_err());
        // Malformed demo grid.
        assert!(options(&parse(&[
            "--group", "0", "--connect", "uds:/tmp/x.sock", "--demo", "2,2,2",
        ]))
        .is_err());
        // Bad address family.
        assert!(options(&parse(&[
            "--group", "0", "--connect", "carrier:/x", "--demo", "2,2,2,2",
        ]))
        .is_err());
    }

    #[test]
    fn options_resolve_with_overrides() {
        let o = options(&parse(&[
            "--group", "1", "--connect", "uds:/tmp/x.sock", "--demo", "3,2,3,2",
            "--seed", "7", "--max-dial-ms", "123", "--backoff-ms", "4",
            "--backoff-max-ms", "40",
        ]))
        .unwrap();
        assert_eq!(o.group, 1);
        assert_eq!(o.config.seed, 7);
        assert_eq!(o.max_dial_ms, 123);
        assert_eq!(o.dial_backoff_ms, 4);
        assert_eq!(o.dial_backoff_max_ms, 40);
        assert_eq!(o.addr, TransportAddr::Uds("/tmp/x.sock".into()));
        // Defaults flow from the config's transport section.
        let d = options(&parse(&[
            "--group", "0", "--connect", "uds:/tmp/x.sock", "--preset", "bitident",
        ]))
        .unwrap();
        assert_eq!(d.dial_backoff_ms, 25);
        assert_eq!(d.dial_backoff_max_ms, 1000);
    }
}
