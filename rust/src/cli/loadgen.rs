//! `hiercode loadgen` — closed-loop load generator for the multi-tenant
//! job service.
//!
//! The paper's latency analysis is per-job; serving millions of users
//! is a queueing problem. This harness measures the difference: for
//! each `scheme × concurrency` point it launches a fresh
//! [`ClusterCore`], registers `--models` synthetic models, and spawns
//! `c` **closed-loop** clients — each submits, waits for its reply,
//! and immediately submits again (the canonical closed-loop driver, so
//! offered load tracks service capacity and the system sits at its
//! natural operating point). Clients round-robin across the registered
//! models, so every run exercises multi-tenant batching lanes.
//!
//! Outcomes are accounted exactly once per submission: a reply (its
//! latency lands in the percentile sample), an [`Error::Busy`] bounce
//! (admission backpressure), a deadline shed, or a failure. The run
//! cross-checks its client-side ledger against the service's own
//! metrics and reports `accounting_consistent` in the output.
//!
//! Results go to `BENCH_serving.json` in `--out` (default `.`):
//! throughput and p50/p95/p99 latency per scheme and concurrency —
//! the serving-layer perf baseline, next to `BENCH_decode.json` /
//! `BENCH_sim.json`.
//!
//! `--smoke` shrinks everything for CI (sub-second runs).

use crate::cli::args::Args;
use crate::coding::SchemeKind;
use crate::config::schema::ClusterConfig;
use crate::coordinator::{ClusterCore, SubmitOptions};
use crate::linalg::Matrix;
use crate::util::rng::Rng;
use crate::util::stats::percentile;
use crate::{Error, Result};
use std::time::{Duration, Instant};

/// JSON-safe float literal (same convention as `hiercode bench`).
fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9e}")
    } else {
        "null".to_string()
    }
}

/// One `scheme × concurrency` measurement.
struct RunStats {
    scheme: String,
    clients: usize,
    wall_s: f64,
    completed: u64,
    busy: u64,
    shed: u64,
    failed: u64,
    /// Submissions that errored at submit time with a non-`Busy` error
    /// (never accepted, so outside the service's `requests` ledger).
    aborted: u64,
    latencies_s: Vec<f64>,
    accounting_consistent: bool,
}

impl RunStats {
    fn throughput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    fn quantile_ms(&self, q: f64) -> f64 {
        if self.latencies_s.is_empty() {
            f64::NAN
        } else {
            percentile(&self.latencies_s, q) * 1e3
        }
    }

    fn mean_ms(&self) -> f64 {
        if self.latencies_s.is_empty() {
            f64::NAN
        } else {
            self.latencies_s.iter().sum::<f64>() / self.latencies_s.len() as f64 * 1e3
        }
    }
}

/// Workload shape shared by every run.
struct LoadConfig {
    n_models: usize,
    rows: usize,
    cols: usize,
    queue_cap: usize,
    deadline_ms: f64,
    duration_s: f64,
    seed: u64,
}

/// Run the load generator; writes `BENCH_serving.json`.
pub fn run(args: &Args) -> Result<()> {
    let smoke = args.has_flag("smoke");
    let out_dir = args.get_str("out").unwrap_or(".").to_string();
    let duration_s = args
        .get_f64("duration-s")?
        .unwrap_or(if smoke { 0.3 } else { 2.0 });
    if !duration_s.is_finite() || duration_s <= 0.0 {
        return Err(Error::InvalidParams(
            "--duration-s must be a positive number of seconds".into(),
        ));
    }
    let clients_list = args
        .get_usize_list("clients")?
        .unwrap_or(if smoke { vec![1, 4] } else { vec![1, 4, 8, 16] });
    if clients_list.is_empty() || clients_list.contains(&0) {
        return Err(Error::InvalidParams(
            "--clients expects positive client counts (e.g. 1,4,8)".into(),
        ));
    }
    let schemes: Vec<SchemeKind> = match args.get_str("schemes") {
        Some(s) => s
            .split(',')
            .map(SchemeKind::parse)
            .collect::<Result<Vec<_>>>()?,
        None => vec![SchemeKind::Hierarchical, SchemeKind::Mds],
    };
    let load = LoadConfig {
        n_models: args.get_usize("models")?.unwrap_or(2).max(1),
        rows: args.get_usize("rows")?.unwrap_or(if smoke { 64 } else { 256 }),
        cols: args.get_usize("cols")?.unwrap_or(if smoke { 16 } else { 64 }),
        queue_cap: args.get_usize("queue-cap")?.unwrap_or(8),
        deadline_ms: args.get_f64("deadline-ms")?.unwrap_or(1_000.0),
        duration_s,
        seed: args.get_usize("seed")?.unwrap_or(42) as u64,
    };
    eprintln!(
        "## hiercode loadgen (smoke={smoke}, schemes={:?}, clients={clients_list:?}, \
         {} models of {}x{}, cap {}, deadline {}ms, {duration_s}s/run)",
        schemes.iter().map(|s| s.name()).collect::<Vec<_>>(),
        load.n_models,
        load.rows,
        load.cols,
        load.queue_cap,
        load.deadline_ms
    );
    let mut runs = Vec::new();
    for &scheme in &schemes {
        for &clients in &clients_list {
            let stats = run_one(scheme, clients, &load)?;
            println!(
                "loadgen {:<14} c={:<3} {:>7.1} req/s  p50 {:>7.2}ms  p95 {:>7.2}ms  \
                 p99 {:>7.2}ms  ({} ok, {} busy, {} shed, {} failed{})",
                stats.scheme,
                stats.clients,
                stats.throughput_rps(),
                stats.quantile_ms(0.5),
                stats.quantile_ms(0.95),
                stats.quantile_ms(0.99),
                stats.completed,
                stats.busy,
                stats.shed,
                stats.failed,
                if stats.accounting_consistent {
                    ""
                } else {
                    ", ACCOUNTING MISMATCH"
                }
            );
            runs.push(stats);
        }
    }
    let json = render_json(smoke, &load, &runs);
    let path = format!("{out_dir}/BENCH_serving.json");
    std::fs::write(&path, json)?;
    println!("wrote {path}");
    Ok(())
}

/// One closed-loop measurement against a fresh service.
fn run_one(scheme: SchemeKind, clients: usize, load: &LoadConfig) -> Result<RunStats> {
    // The demo 4×2 grid is valid for all five schemes and AOT-free.
    let mut config = ClusterConfig::demo_scheme(scheme, 4, 2, 4, 2);
    config.code.validate()?;
    config.serving.queue_cap = load.queue_cap;
    config.serving.default_deadline_ms = load.deadline_ms;
    config.serving.drain_ms = 2_000.0;
    // A tight batch window keeps the closed loop moving; stragglers
    // stay on (tiny scale) so the measured path is the real one.
    config.batching.max_wait_ms = 1.0;
    config.straggler.enabled = true;
    config.straggler.scale = 0.0002;
    let core = ClusterCore::launch(&config)?;
    let mut mr = Rng::new(load.seed);
    let model_names: Vec<String> =
        (0..load.n_models).map(|i| format!("model{i}")).collect();
    for name in &model_names {
        let a = Matrix::from_fn(load.rows, load.cols, |_, _| mr.uniform(-1.0, 1.0));
        core.register_model(name, &a)?;
    }
    let t_end = Instant::now() + Duration::from_secs_f64(load.duration_s);
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for t in 0..clients {
        let client = core.handle();
        let names = model_names.clone();
        let cols = load.cols;
        let mut rng = Rng::new(load.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(t as u64 + 1));
        joins.push(std::thread::spawn(move || {
            let mut latencies = Vec::new();
            let (mut busy, mut shed, mut failed, mut aborted) = (0u64, 0u64, 0u64, 0u64);
            let mut i = 0usize;
            while Instant::now() < t_end {
                let name = &names[i % names.len()];
                i += 1;
                let x: Vec<f64> = (0..cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let t_req = Instant::now();
                match client.submit_with(x, SubmitOptions::to_model(name)) {
                    Ok(handle) => match handle.wait() {
                        Ok(_) => latencies.push(t_req.elapsed().as_secs_f64()),
                        Err(Error::DeadlineExceeded) => shed += 1,
                        Err(_) => failed += 1,
                    },
                    Err(Error::Busy { .. }) => {
                        // Explicit backpressure: back off briefly.
                        busy += 1;
                        std::thread::yield_now();
                    }
                    Err(_) => {
                        // Never accepted (shutdown/misconfiguration):
                        // outside the service ledger. Stop this client.
                        aborted += 1;
                        break;
                    }
                }
            }
            (latencies, busy, shed, failed, aborted)
        }));
    }
    let mut latencies_s = Vec::new();
    let (mut busy, mut shed, mut failed, mut aborted) = (0u64, 0u64, 0u64, 0u64);
    for j in joins {
        let (lat, b, s, f, ab) = j.join().expect("loadgen client panicked");
        latencies_s.extend(lat);
        busy += b;
        shed += s;
        failed += f;
        aborted += ab;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let completed = latencies_s.len() as u64;
    let snap = core.metrics();
    core.shutdown();
    // Exactly-once accounting: the client-side ledger must agree with
    // the service's own counters. `aborted` submissions were never
    // accepted, so they sit outside the `requests` equation.
    let accounting_consistent = snap.rejected == busy
        && snap.shed == shed
        && snap.requests == completed + shed + failed;
    Ok(RunStats {
        scheme: scheme.name().to_string(),
        clients,
        wall_s,
        completed,
        busy,
        shed,
        failed,
        aborted,
        latencies_s,
        accounting_consistent,
    })
}

/// Render the `BENCH_serving.json` document.
fn render_json(smoke: bool, load: &LoadConfig, runs: &[RunStats]) -> String {
    let entries: Vec<String> = runs
        .iter()
        .map(|r| {
            format!(
                "    {{\"scheme\": \"{}\", \"clients\": {}, \"wall_s\": {}, \
                 \"completed\": {}, \"busy_rejected\": {}, \"deadline_shed\": {}, \
                 \"failed\": {}, \"submit_aborted\": {}, \"throughput_rps\": {}, \
                 \"latency_ms\": {{\"mean\": {}, \"p50\": {}, \"p95\": {}, \
                 \"p99\": {}}}, \"accounting_consistent\": {}}}",
                r.scheme,
                r.clients,
                jf(r.wall_s),
                r.completed,
                r.busy,
                r.shed,
                r.failed,
                r.aborted,
                jf(r.throughput_rps()),
                jf(r.mean_ms()),
                jf(r.quantile_ms(0.5)),
                jf(r.quantile_ms(0.95)),
                jf(r.quantile_ms(0.99)),
                r.accounting_consistent
            )
        })
        .collect();
    format!(
        "{{\n\
         \x20 \"schema\": \"hiercode-bench/serving/v1\",\n\
         \x20 \"smoke\": {smoke},\n\
         \x20 \"grid\": {{\"n1\": 4, \"k1\": 2, \"n2\": 4, \"k2\": 2}},\n\
         \x20 \"models\": {}, \"rows\": {}, \"cols\": {},\n\
         \x20 \"queue_cap\": {}, \"deadline_ms\": {},\n\
         \x20 \"duration_s\": {},\n\
         \x20 \"runs\": [\n{}\n  ]\n\
         }}\n",
        load.n_models,
        load.rows,
        load.cols,
        load.queue_cap,
        jf(load.deadline_ms),
        jf(load.duration_s),
        entries.join(",\n")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_loadgen_writes_serving_baseline() {
        let dir = std::env::temp_dir().join("hiercode_loadgen_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.to_str().unwrap().to_string();
        let args = Args::parse(&[
            "--smoke".to_string(),
            "--duration-s".to_string(),
            "0.15".to_string(),
            "--clients".to_string(),
            "1,2".to_string(),
            "--out".to_string(),
            out,
        ])
        .unwrap();
        run(&args).unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_serving.json")).unwrap();
        let v = crate::config::json::Json::parse(&text).unwrap();
        assert_eq!(
            v.get("schema").and_then(|s| s.as_str()),
            Some("hiercode-bench/serving/v1")
        );
        let runs = v.get("runs").and_then(|r| r.as_array()).unwrap();
        // Default schemes (hierarchical, mds) × clients (1, 2).
        assert_eq!(runs.len(), 4);
        for entry in runs {
            assert_eq!(
                entry.get("accounting_consistent").and_then(|b| b.as_bool()),
                Some(true),
                "every submission must be accounted exactly once"
            );
            // The closed loop must actually complete work.
            assert!(entry.get("completed").and_then(|c| c.as_usize()).unwrap() > 0);
        }
    }

    /// Satellite regression: a run that completed nothing has no
    /// latency sample — its p50/p95/p99 must serialize as `null`,
    /// never as a fake `0.0` "zero latency" tail.
    #[test]
    fn empty_latency_sample_renders_null_not_zero() {
        let load = LoadConfig {
            n_models: 1,
            rows: 8,
            cols: 2,
            queue_cap: 1,
            deadline_ms: 10.0,
            duration_s: 0.1,
            seed: 1,
        };
        let runs = vec![RunStats {
            scheme: "hierarchical".into(),
            clients: 1,
            wall_s: 0.1,
            completed: 0,
            busy: 3,
            shed: 0,
            failed: 0,
            aborted: 0,
            latencies_s: Vec::new(),
            accounting_consistent: true,
        }];
        assert!(runs[0].quantile_ms(0.99).is_nan());
        let json = render_json(true, &load, &runs);
        assert!(
            json.contains("\"p99\": null"),
            "empty sample must render null, got: {json}"
        );
        assert!(!json.contains("\"p99\": 0"), "no fake zero-latency tail");
        // The document stays parseable by our own JSON parser.
        let v = crate::config::json::Json::parse(&json).unwrap();
        assert!(v.get("runs").is_some());
    }

    #[test]
    fn loadgen_rejects_bad_arguments() {
        for bad in [
            vec!["--duration-s", "0"],
            vec!["--duration-s", "-1"],
            vec!["--clients", "0,2"],
            vec!["--schemes", "raptor"],
        ] {
            let argv: Vec<String> = bad.iter().map(|s| s.to_string()).collect();
            let args = Args::parse(&argv).unwrap();
            assert!(run(&args).is_err(), "must reject {bad:?}");
        }
    }
}
