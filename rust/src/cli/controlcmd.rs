//! `hiercode control` — control-plane verification harness.
//!
//! The control plane's whole claim is that an operator can change what
//! a live cluster serves *without dropping anything*. This harness
//! measures that claim against a real cluster driven through the real
//! admin surface (UDS control socket, framed protocol), never through
//! test-only backdoors:
//!
//! 1. **Zero drop** — a flood of jobs is dispatched, then a heavy
//!    rollout (changed per-group k1 plan) lands via `admin rollout`
//!    while they are still in the pipeline. Every pre-swap job must
//!    complete: no drop, no error.
//! 2. **Pre-swap bit-identity** — those pre-swap outputs must match a
//!    reference run (same seed, no rollout) bit for bit
//!    (`f64::to_bits`): the quiesce/cut-over must not perturb work
//!    admitted under the old generation.
//! 3. **Post-swap generation** — after the swap the cluster reports
//!    generation 2 (via `admin status` over the socket, not the
//!    in-process accessor), the rollout counter ticks, and a job
//!    decoded under the new plan is numerically correct.
//! 4. **Incompatible rejected** — an artifact with a changed outer
//!    code (k2) must be refused atomically: typed error, generation
//!    unchanged, cluster still serving.
//! 5. **Rollback restores** — `admin rollback` returns to generation
//!    1, and the original plan then serves the reference stream's
//!    first input bit-identically again.
//!
//! Results go to `BENCH_control.json` in `--out` (default `.`) and the
//! harness exits nonzero when any verdict fails, so CI catches control
//! plane regressions, not just crashes. `--smoke` shrinks the flood
//! for CI (the scenarios themselves are already second-scale).

use crate::cli::args::Args;
use crate::config::schema::ClusterConfig;
use crate::controlplane::admin::{self, AdminRequest};
use crate::controlplane::{self, AdminControl, AdminServer};
use crate::coordinator::ClusterCore;
use crate::linalg::{ops, Matrix};
use crate::transport::TransportAddr;
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The model every run registers and streams against.
const MODEL: &str = "control";
/// Model shape: rows divisible by both the launch plan's row divisor
/// (k2·k1 = 4) and the rollout plan's lcm(2·3, 2·2, 2·1) = 12.
const ROWS: usize = 24;
const COLS: usize = 4;
/// Per-job wait guard, far above any healthy completion time.
const WAIT: Duration = Duration::from_secs(30);

/// JSON-safe float literal (same convention as `hiercode bench`).
fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9e}")
    } else {
        "null".to_string()
    }
}

static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// A fresh per-run UDS control address (pid + counter keeps parallel
/// test binaries and repeated runs from colliding on a stale path).
fn fresh_admin_addr() -> TransportAddr {
    TransportAddr::Uds(std::env::temp_dir().join(format!(
        "hiercode-ctl-{}-{}.sock",
        std::process::id(),
        SOCK_SEQ.fetch_add(1, Ordering::Relaxed)
    )))
}

/// The config every scenario runs: a heterogeneous-capable demo grid
/// with single-request batches (batch composition must not depend on
/// flood timing, or bit-identity would race) and an admission queue
/// that holds the whole flood.
fn preset(seed: u64) -> ClusterConfig {
    let mut config = ClusterConfig::demo(4, 2, 3, 2);
    config.seed = seed;
    config.runtime.use_pjrt = false;
    config.serving.queue_cap = 256;
    config.serving.default_deadline_ms = 30_000.0;
    config.serving.drain_ms = 10_000.0;
    config.batching.max_batch = 1;
    config.batching.max_wait_ms = 0.5;
    config
}

/// Workload knobs shared by every scenario.
struct ControlLoad {
    seed: u64,
    inflight: usize,
}

/// Build the seeded model matrix and the seeded input stream — both
/// runs must derive them from the same RNG stream or "bit-identical"
/// would be vacuous.
fn seeded_workload(load: &ControlLoad) -> (Matrix, Vec<Vec<f64>>) {
    let mut rng = Rng::new(load.seed);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| rng.uniform(-1.0, 1.0));
    let inputs = (0..load.inflight)
        .map(|_| (0..COLS).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();
    (a, inputs)
}

/// Reference run: the same seeded flood with no rollout; its outputs
/// are the bit-identity oracle.
fn run_reference(load: &ControlLoad) -> Result<Vec<Vec<f64>>> {
    let config = preset(load.seed);
    let core = ClusterCore::launch(&config)?;
    let (a, inputs) = seeded_workload(load);
    core.register_model(MODEL, &a)?;
    let client = core.handle();
    let handles = inputs
        .iter()
        .map(|x| client.submit_to(MODEL, x.clone()))
        .collect::<Result<Vec<_>>>()?;
    let mut outputs = Vec::with_capacity(handles.len());
    for h in handles {
        outputs.push(h.wait_timeout(WAIT)?);
    }
    core.shutdown();
    Ok(outputs)
}

/// Everything the verdicts need from the rollout run.
struct RolloutOutcome {
    completed: u64,
    dropped: u64,
    bit_identical: bool,
    swap_generation: u64,
    status_generation: u64,
    rollouts: u64,
    post_swap_max_err: f64,
    incompatible_rejected: bool,
    generation_after_reject: u64,
    serves_after_reject: bool,
    rollback_generation: u64,
    rollbacks: u64,
    rollback_bit_identical: bool,
    metrics_json: String,
}

impl RolloutOutcome {
    fn zero_drop_ok(&self, inflight: usize) -> bool {
        self.dropped == 0 && self.completed == inflight as u64
    }
    fn post_swap_ok(&self) -> bool {
        self.swap_generation == 2
            && self.status_generation == 2
            && self.rollouts == 1
            && self.post_swap_max_err < 1e-6
    }
    fn reject_ok(&self) -> bool {
        self.incompatible_rejected
            && self.generation_after_reject == 2
            && self.serves_after_reject
    }
    fn rollback_ok(&self) -> bool {
        self.rollback_generation == 1 && self.rollbacks == 1 && self.rollback_bit_identical
    }
    fn ok(&self, inflight: usize) -> bool {
        self.zero_drop_ok(inflight)
            && self.bit_identical
            && self.post_swap_ok()
            && self.reject_ok()
            && self.rollback_ok()
    }
}

/// Bitwise comparison of two output streams.
fn bits_equal(a: &[Vec<f64>], b: &[Vec<f64>]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Send one admin request over the control socket and unwrap the
/// payload (the harness always talks to the cluster the way an
/// operator would).
fn admin_call(addr: &TransportAddr, req: &AdminRequest) -> Result<Vec<u8>> {
    admin::request(addr, req)?.into_payload()
}

/// The rollout run: flood, swap through the admin socket mid-pipeline,
/// reject an incompatible artifact, roll back.
fn run_rollout(load: &ControlLoad, reference: &[Vec<f64>]) -> Result<RolloutOutcome> {
    let config = preset(load.seed);
    let core = Arc::new(ClusterCore::launch(&config)?);
    let (a, inputs) = seeded_workload(load);
    core.register_model(MODEL, &a)?;
    let mut server = AdminServer::spawn(
        fresh_admin_addr(),
        Arc::clone(&core) as Arc<dyn AdminControl>,
    )?;
    let addr = server.addr().clone();
    let client = core.handle();

    // Flood the pre-swap jobs, then wait until the batcher has
    // dispatched every one of them (single-request batches, so the
    // jobs counter equals dispatched requests): the quiesce must drain
    // them under the *old* generation for bit-identity to be testable.
    let handles = inputs
        .iter()
        .map(|x| client.submit_to(MODEL, x.clone()))
        .collect::<Result<Vec<_>>>()?;
    let dispatch_deadline = Instant::now() + Duration::from_secs(10);
    while core.metrics().jobs < load.inflight as u64 {
        if Instant::now() > dispatch_deadline {
            return Err(Error::Coordinator(
                "control harness: flood never fully dispatched".into(),
            ));
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    // Heavy candidate: a skewed per-group k1 plan, rolled out over the
    // admin socket while the flood is in the pipeline.
    let mut cand = config.clone();
    let plan = [3usize, 2, 1];
    for (g, spec) in cand.code.topology.groups.iter_mut().enumerate() {
        spec.k1 = plan[g];
    }
    cand.code.k1 = plan[0];
    let payload = admin_call(&addr, &AdminRequest::Rollout(controlplane::compile(&cand)?))?;
    let swap_generation = admin::generation_from_payload(&payload)?;

    // Every pre-swap job must complete, bit-identical to the oracle.
    let (mut completed, mut dropped) = (0u64, 0u64);
    let mut outputs = Vec::with_capacity(handles.len());
    for h in handles {
        match h.wait_timeout(WAIT) {
            Ok(y) => {
                completed += 1;
                outputs.push(y);
            }
            Err(_) => {
                dropped += 1;
                outputs.push(Vec::new());
            }
        }
    }
    let bit_identical = bits_equal(&outputs, reference);

    // Post-swap: the admin surface reports the new generation and a
    // job decoded under the new plan is numerically correct.
    let status = String::from_utf8_lossy(&admin_call(&addr, &AdminRequest::Status)?).into_owned();
    let status_generation = crate::config::json::Json::parse(&status)
        .ok()
        .and_then(|v| v.get("generation").and_then(|g| g.as_usize()))
        .unwrap_or(0) as u64;
    let mut rng = Rng::new(load.seed ^ 0x5a5a);
    let x: Vec<f64> = (0..COLS).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let expect = ops::matvec(&a, &x);
    let y = client.submit_to(MODEL, x)?.wait_timeout(WAIT)?;
    let post_swap_max_err = y
        .iter()
        .zip(expect.iter())
        .map(|(got, want)| (got - want).abs())
        .fold(0.0f64, f64::max);
    let rollouts = core.metrics().rollouts;

    // Incompatible candidate: changed outer code → atomic refusal.
    let mut bad = config.clone();
    bad.code.k2 = 3;
    bad.code.topology.k2 = 3;
    let incompatible_rejected = matches!(
        admin::request(&addr, &AdminRequest::Rollout(controlplane::compile(&bad)?))?,
        admin::AdminResponse::Err(ref m) if m.contains("incompatible")
    );
    let generation_after_reject = core.artifact_generation();
    let serves_after_reject = client
        .submit_to(MODEL, vec![1.0; COLS])?
        .wait_timeout(WAIT)
        .is_ok();

    // Rollback: generation 1 again, and the original plan serves the
    // reference stream's first input bit-identically.
    let payload = admin_call(&addr, &AdminRequest::Rollback)?;
    let rollback_generation = admin::generation_from_payload(&payload)?;
    let y = client
        .submit_to(MODEL, inputs[0].clone())?
        .wait_timeout(WAIT)?;
    let rollback_bit_identical = !reference.is_empty()
        && y.len() == reference[0].len()
        && y.iter()
            .zip(reference[0].iter())
            .all(|(p, q)| p.to_bits() == q.to_bits());
    let snap = core.metrics();
    server.stop();
    drop(client);
    if let Ok(core) = Arc::try_unwrap(core) {
        core.shutdown();
    }
    Ok(RolloutOutcome {
        completed,
        dropped,
        bit_identical,
        swap_generation,
        status_generation,
        rollouts,
        post_swap_max_err,
        incompatible_rejected,
        generation_after_reject,
        serves_after_reject,
        rollback_generation,
        rollbacks: snap.rollbacks,
        rollback_bit_identical,
        metrics_json: snap.to_json(),
    })
}

/// Render the `BENCH_control.json` document.
fn render_json(smoke: bool, load: &ControlLoad, out: &RolloutOutcome, pass: bool) -> String {
    format!(
        "{{\n\
         \x20 \"schema\": \"hiercode-bench/control/v1\",\n\
         \x20 \"smoke\": {smoke},\n\
         \x20 \"seed\": {},\n\
         \x20 \"inflight\": {},\n\
         \x20 \"pre_swap_bit_identical\": {},\n\
         \x20 \"zero_drop\": {{\n\
         \x20   \"completed\": {}, \"dropped\": {}, \"ok\": {}\n\
         \x20 }},\n\
         \x20 \"post_swap_generation\": {{\n\
         \x20   \"generation\": {}, \"status_generation\": {}, \"rollouts\": {},\n\
         \x20   \"max_err\": {}, \"ok\": {}\n\
         \x20 }},\n\
         \x20 \"incompatible_rejected\": {{\n\
         \x20   \"rejected\": {}, \"generation\": {}, \"serves\": {}, \"ok\": {}\n\
         \x20 }},\n\
         \x20 \"rollback_restores\": {{\n\
         \x20   \"generation\": {}, \"rollbacks\": {}, \"bit_identical\": {}, \"ok\": {}\n\
         \x20 }},\n\
         \x20 \"verdict\": \"{}\",\n\
         \x20 \"metrics\": {}\n\
         }}\n",
        load.seed,
        load.inflight,
        out.bit_identical,
        out.completed,
        out.dropped,
        out.zero_drop_ok(load.inflight),
        out.swap_generation,
        out.status_generation,
        out.rollouts,
        jf(out.post_swap_max_err),
        out.post_swap_ok(),
        out.incompatible_rejected,
        out.generation_after_reject,
        out.serves_after_reject,
        out.reject_ok(),
        out.rollback_generation,
        out.rollbacks,
        out.rollback_bit_identical,
        out.rollback_ok(),
        if pass { "pass" } else { "fail" },
        out.metrics_json,
    )
}

/// Run the control harness; writes `BENCH_control.json`.
pub fn run(args: &Args) -> Result<()> {
    let smoke = args.has_flag("smoke");
    let out_dir = args.get_str("out").unwrap_or(".").to_string();
    let load = ControlLoad {
        seed: args.get_usize("seed")?.unwrap_or(42) as u64,
        inflight: args
            .get_usize("inflight")?
            .unwrap_or(if smoke { 4 } else { 12 }),
    };
    if load.inflight == 0 {
        return Err(Error::InvalidParams("--inflight must be positive".into()));
    }
    eprintln!(
        "## hiercode control (smoke={smoke}, seed={}, {} in-flight jobs)",
        load.seed, load.inflight
    );
    let reference = run_reference(&load)?;
    let out = run_rollout(&load, &reference)?;
    println!(
        "control zero-drop: {}/{} completed, {} dropped; pre-swap bit-identical={}",
        out.completed, load.inflight, out.dropped, out.bit_identical
    );
    println!(
        "control post-swap: generation {} (status {}), {} rollouts, max err {:.3e}",
        out.swap_generation, out.status_generation, out.rollouts, out.post_swap_max_err
    );
    println!(
        "control reject: incompatible rejected={} (generation {}, serving={})",
        out.incompatible_rejected, out.generation_after_reject, out.serves_after_reject
    );
    println!(
        "control rollback: generation {} ({} rollbacks), bit-identical={}",
        out.rollback_generation, out.rollbacks, out.rollback_bit_identical
    );
    let pass = out.ok(load.inflight);
    let json = render_json(smoke, &load, &out, pass);
    let path = format!("{out_dir}/BENCH_control.json");
    std::fs::write(&path, json)?;
    println!("wrote {path}");
    if !pass {
        return Err(Error::Coordinator(format!(
            "control verdict FAILED (see {path}): zero_drop={}, bit_identical={}, \
             post_swap={}, reject={}, rollback={}",
            out.zero_drop_ok(load.inflight),
            out.bit_identical,
            out.post_swap_ok(),
            out.reject_ok(),
            out.rollback_ok()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_control_writes_report_and_passes() {
        let dir = std::env::temp_dir().join("hiercode_control_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.to_str().unwrap().to_string();
        let args = Args::parse(&[
            "--smoke".to_string(),
            "--inflight".to_string(),
            "3".to_string(),
            "--out".to_string(),
            out,
        ])
        .unwrap();
        run(&args).unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_control.json")).unwrap();
        let v = crate::config::json::Json::parse(&text).unwrap();
        assert_eq!(
            v.get("schema").and_then(|s| s.as_str()),
            Some("hiercode-bench/control/v1")
        );
        assert_eq!(
            v.get("pre_swap_bit_identical").and_then(|b| b.as_bool()),
            Some(true)
        );
        let zd = v.get("zero_drop").unwrap();
        assert_eq!(zd.get("dropped").and_then(|n| n.as_usize()), Some(0));
        assert_eq!(zd.get("ok").and_then(|b| b.as_bool()), Some(true));
        let ps = v.get("post_swap_generation").unwrap();
        assert_eq!(ps.get("generation").and_then(|n| n.as_usize()), Some(2));
        assert_eq!(ps.get("ok").and_then(|b| b.as_bool()), Some(true));
        let ir = v.get("incompatible_rejected").unwrap();
        assert_eq!(ir.get("ok").and_then(|b| b.as_bool()), Some(true));
        let rb = v.get("rollback_restores").unwrap();
        assert_eq!(rb.get("generation").and_then(|n| n.as_usize()), Some(1));
        assert_eq!(rb.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(v.get("verdict").and_then(|s| s.as_str()), Some("pass"));
        // The embedded metrics snapshot carries the rollout counters.
        let metrics = v.get("metrics").unwrap();
        assert_eq!(metrics.get("rollouts").and_then(|n| n.as_usize()), Some(1));
        assert_eq!(metrics.get("rollbacks").and_then(|n| n.as_usize()), Some(1));
    }

    #[test]
    fn control_rejects_bad_arguments() {
        let args = Args::parse(&["--inflight".to_string(), "0".to_string()]).unwrap();
        assert!(run(&args).is_err());
    }
}
