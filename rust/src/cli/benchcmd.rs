//! `hiercode bench` — the decode/GEMM/simulator bench harness.
//!
//! Runs the perf-critical paths this crate is judged on and emits
//! machine-readable baselines — `BENCH_decode.json` and
//! `BENCH_sim.json` in `--out` (default: the current directory, i.e.
//! the repo root when invoked from there) — so every future change has
//! a measured trajectory to argue against:
//!
//! * `gemm_decode` — the packed 4×4-microkernel GEMM against the
//!   pre-packing i-k-j kernel at the decode hot shape (`k×k · k×n`);
//! * `gemm_simd` — the same packed GEMM on the runtime-dispatched
//!   kernel table ([`dispatch::active`]) against the forced-scalar
//!   table, with GFLOP/s + GB/s roofline numbers and a SIMD-vs-scalar
//!   bit-identity verdict;
//! * `lu_solve` — the blocked multi-RHS triangular solve;
//! * `lu_cache` — repeat-erasure-pattern decodes through an MDS code
//!   with the [`LuCache`] attached: cold (factorizing) vs warm
//!   (memoized) per-decode time, steady-traffic hit rate, and a
//!   cached-vs-uncached bit-identity verdict;
//! * `group_scaling` — hierarchical group decoding at 1..max threads,
//!   with speedup and efficiency-vs-ideal, plus a bit-identical
//!   cross-thread determinism check;
//! * `hetero_group_decode` — a heterogeneous topology with skewed
//!   per-group `k1_g` (unequal elimination sizes), serial vs pooled,
//!   with its own bit-identical check;
//! * `partial_decode` — partial-work mode at `r ∈ {1, 4}` sub-tasks
//!   per worker (the group elimination grows to `(k1·r)×(k1·r)`),
//!   serial vs pooled, with its own bit-identical verdict;
//! * `session_decode` — streaming-session batch decode per scheme;
//! * `BENCH_sim.json` — sharded Monte-Carlo throughput at 1..max
//!   threads with its own bit-identical check.
//!
//! `--smoke` shrinks every size for CI (seconds, not minutes);
//! `--threads N` caps the scaling sweep (default 4); `--iters N`
//! overrides the per-measurement iteration count; `--trend FILE`
//! compares the fresh `BENCH_decode.json` against a committed snapshot
//! — any determinism/bit-identity verdict flipping to `false` is a hard
//! failure, numeric figures only fail below a generous floor (¼ of the
//! snapshot value), so CI catches real regressions without flaking on
//! shared-runner noise.

use crate::cli::args::Args;
use crate::coding::{build_scheme_with, DecodeScratch, MdsCode, SchemeKind, WorkerResult};
use crate::config::json::Json;
use crate::linalg::{dispatch, lu::LuFactors, ops, LuCache, Matrix};
use crate::parallel::DecodePool;
use crate::sim::{montecarlo, SimParams};
use crate::util::bench::fmt_time;
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Instant;

/// Best-of-`iters` wall-clock of `f` (min is the standard noise-robust
/// point estimate for throughput benches).
fn time_min<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// JSON-safe float literal.
fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9e}")
    } else {
        "null".to_string()
    }
}

fn jf_list(vs: &[f64]) -> String {
    let items: Vec<String> = vs.iter().map(|&v| jf(v)).collect();
    format!("[{}]", items.join(", "))
}

fn ju_list(vs: &[usize]) -> String {
    let items: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
    format!("[{}]", items.join(", "))
}

fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
}

struct BenchConfig {
    smoke: bool,
    threads: Vec<usize>,
    iters: usize,
    warmup: usize,
    gemm_k: usize,
    gemm_n: usize,
    group_rows: usize,
    group_batch: usize,
    session_rows: usize,
    sim_trials: usize,
}

impl BenchConfig {
    fn new(smoke: bool, max_threads: usize, iters_override: Option<usize>) -> Self {
        let mut threads = vec![1];
        let mut t = 2;
        while t <= max_threads {
            threads.push(t);
            t *= 2;
        }
        if smoke {
            Self {
                smoke,
                threads,
                iters: iters_override.unwrap_or(3),
                warmup: 1,
                gemm_k: 64,
                gemm_n: 512,
                group_rows: 2048,
                group_batch: 2,
                session_rows: 512,
                sim_trials: 2 * montecarlo::MC_SHARD + 100,
            }
        } else {
            Self {
                smoke,
                threads,
                iters: iters_override.unwrap_or(15),
                warmup: 3,
                gemm_k: 64,
                gemm_n: 4096,
                group_rows: 32768,
                group_batch: 16,
                session_rows: 4096,
                sim_trials: 1 << 19,
            }
        }
    }
}

/// Run the bench harness; writes `BENCH_decode.json` / `BENCH_sim.json`.
pub fn run(args: &Args) -> Result<()> {
    let smoke = args.has_flag("smoke");
    let out_dir = args.get_str("out").unwrap_or(".").to_string();
    let max_threads = args.get_usize("threads")?.unwrap_or(4).max(1);
    let cfg = BenchConfig::new(smoke, max_threads, args.get_usize("iters")?);
    eprintln!(
        "## hiercode bench (smoke={}, threads={:?}, iters={})",
        cfg.smoke, cfg.threads, cfg.iters
    );
    let decode_json = bench_decode(&cfg)?;
    let sim_json = bench_sim(&cfg)?;
    let decode_path = format!("{out_dir}/BENCH_decode.json");
    let sim_path = format!("{out_dir}/BENCH_sim.json");
    std::fs::write(&decode_path, &decode_json)?;
    std::fs::write(&sim_path, sim_json)?;
    println!("wrote {decode_path}");
    println!("wrote {sim_path}");
    if let Some(trend_path) = args.get_str("trend") {
        let trend_text = std::fs::read_to_string(trend_path).map_err(|e| {
            Error::InvalidParams(format!("--trend {trend_path}: {e}"))
        })?;
        check_trend(&decode_json, &trend_text)?;
        println!("trend check vs {trend_path}: ok");
    }
    Ok(())
}

/// Verdicts in `BENCH_decode.json` that must never regress to `false`.
/// Dotted paths into the decode JSON; each resolves to a bool.
const TREND_VERDICTS: [&str; 5] = [
    "gemm_simd.bit_identical",
    "lu_cache.bit_identical",
    "hetero_group_decode.deterministic",
    "partial_decode.deterministic",
    "deterministic_across_threads",
];

/// Numeric figures compared against the committed snapshot. A figure
/// fails only below `snapshot × TREND_NUMERIC_TOLERANCE` — generous on
/// purpose: the snapshot records conservative floors, and CI runners
/// vary wildly (a scalar-only host legitimately reports `gemm_simd`
/// speedup ≈ 1.0 against an AVX2 snapshot floor of 1.5).
const TREND_NUMERICS: [&str; 2] = ["gemm_simd.speedup_vs_scalar", "lu_cache.hit_rate"];

/// Generous floor multiplier for [`TREND_NUMERICS`].
const TREND_NUMERIC_TOLERANCE: f64 = 0.25;

fn json_path<'a>(root: &'a Json, dotted: &str) -> Option<&'a Json> {
    dotted.split('.').try_fold(root, |node, key| node.get(key))
}

/// Compare a fresh `BENCH_decode.json` against a committed trend
/// snapshot. Determinism/bit-identity verdicts are hard gates; numeric
/// figures fail only below ¼ of the snapshot value. A verdict or figure
/// absent from the snapshot is skipped (older snapshots stay usable), a
/// verdict absent from the *current* output is an error (the bench
/// silently dropped a check).
fn check_trend(current_text: &str, trend_text: &str) -> Result<()> {
    let current = Json::parse(current_text)
        .map_err(|e| Error::InvalidParams(format!("bench output unparseable: {e}")))?;
    let trend = Json::parse(trend_text)
        .map_err(|e| Error::InvalidParams(format!("trend snapshot unparseable: {e}")))?;
    let mut failures = Vec::new();
    for path in TREND_VERDICTS {
        if json_path(&trend, path).and_then(Json::as_bool) != Some(true) {
            continue; // snapshot doesn't pin this verdict
        }
        match json_path(&current, path).and_then(Json::as_bool) {
            Some(true) => {}
            Some(false) => failures.push(format!("verdict {path} regressed to false")),
            None => failures.push(format!("verdict {path} missing from bench output")),
        }
    }
    for path in TREND_NUMERICS {
        let Some(floor) = json_path(&trend, path).and_then(Json::as_f64) else {
            continue;
        };
        let allowed = floor * TREND_NUMERIC_TOLERANCE;
        match json_path(&current, path).and_then(Json::as_f64) {
            Some(v) if v >= allowed => {}
            Some(v) => failures.push(format!(
                "{path} = {v:.3} below floor {allowed:.3} (snapshot {floor:.3} × {TREND_NUMERIC_TOLERANCE})"
            )),
            None => failures.push(format!("{path} missing from bench output")),
        }
    }
    if failures.is_empty() {
        Ok(())
    } else {
        Err(Error::InvalidParams(format!(
            "bench trend regression:\n  {}",
            failures.join("\n  ")
        )))
    }
}

/// GEMM + LU + hierarchical group scaling + per-scheme sessions.
fn bench_decode(cfg: &BenchConfig) -> Result<String> {
    let mut r = Rng::new(0xBEC);

    // --- GEMM at the decode hot shape: (k×k)·(k×n). ---
    let (k, n) = (cfg.gemm_k, cfg.gemm_n);
    let a = random_matrix(&mut r, k, k);
    let b = random_matrix(&mut r, k, n);
    let packed_s = time_min(cfg.warmup, cfg.iters, || ops::matmul(&a, &b));
    let ikj_s = time_min(cfg.warmup, cfg.iters, || ops::matmul_ikj(&a, &b));
    let gemm_speedup = ikj_s / packed_s;
    let gflops = 2.0 * (k * k * n) as f64 / packed_s / 1e9;
    println!(
        "bench gemm_decode_{k}x{k}x{n}       packed {}  ikj {}  speedup {:.2}x  ({:.2} GF/s)",
        fmt_time(packed_s),
        fmt_time(ikj_s),
        gemm_speedup,
        gflops
    );

    // --- Runtime-dispatched SIMD kernels vs forced scalar. ---
    // Same packed GEMM, same shape, serial pool both times — the only
    // variable is the kernel table, so the ratio is the microkernel
    // speedup and nothing else. GB/s counts the compulsory traffic
    // (A + B read, C written once) to place the point on a roofline.
    let serial = DecodePool::serial();
    let kern = dispatch::active();
    let simd_s = time_min(cfg.warmup, cfg.iters, || {
        ops::matmul_with_kernels(&a, &b, &serial, kern)
    });
    let kscalar_s = time_min(cfg.warmup, cfg.iters, || {
        ops::matmul_with_kernels(&a, &b, &serial, dispatch::scalar())
    });
    let simd_speedup = kscalar_s / simd_s;
    let simd_gflops = 2.0 * (k * k * n) as f64 / simd_s / 1e9;
    let simd_gbs = 8.0 * (k * k + 2 * k * n) as f64 / simd_s / 1e9;
    let simd_out = ops::matmul_with_kernels(&a, &b, &serial, kern);
    let kscalar_out = ops::matmul_with_kernels(&a, &b, &serial, dispatch::scalar());
    let simd_identical = simd_out.data() == kscalar_out.data();
    println!(
        "bench gemm_simd_{k}x{k}x{n} [{}]    {}  scalar {}  speedup {:.2}x  \
         ({:.2} GF/s, {:.2} GB/s, bit-identical: {simd_identical})",
        kern.name,
        fmt_time(simd_s),
        fmt_time(kscalar_s),
        simd_speedup,
        simd_gflops,
        simd_gbs
    );

    // --- Blocked multi-RHS solve at the same shape. ---
    let mut gm = random_matrix(&mut r, k, k);
    for i in 0..k {
        gm[(i, i)] += k as f64;
    }
    let lu = LuFactors::factorize(&gm)?;
    let rhs = random_matrix(&mut r, k, n);
    let solve_s = time_min(cfg.warmup, cfg.iters, || lu.solve_matrix(&rhs).unwrap());
    println!("bench lu_solve_{k}x{n}rhs          {}", fmt_time(solve_s));

    // --- Erasure-pattern LU memoization on repeat decodes. ---
    // An (n1, k1) MDS code decoding `cache_patterns` distinct erasure
    // patterns (one systematic shard swapped for a parity shard, so the
    // general k1×k1 path runs every time), each repeated `cache_reps`
    // times — the steady traffic a serving cluster sees. Cold decodes
    // pay factorize + solve; warm decodes are solve-only cache hits.
    let (cn, ck) = (20usize, 16usize);
    let cache_patterns = 4usize;
    let cache_reps = 20usize;
    let cache_block = (cfg.session_rows / ck).max(1);
    let cache_code = MdsCode::new(cn, ck)?;
    let cached_code = cache_code.clone().with_cache(Arc::new(LuCache::default()));
    // Pattern p: systematic shards with index p replaced by parity
    // shard ck + p. Values are synthetic (the solve never reads them).
    let cache_sets: Vec<Vec<(usize, Matrix)>> = (0..cache_patterns)
        .map(|p| {
            (0..ck)
                .map(|i| {
                    let idx = if i == p { ck + p } else { i };
                    (idx, random_matrix(&mut r, cache_block, cfg.group_batch))
                })
                .collect()
        })
        .collect();
    let mut scratch = DecodeScratch::new();
    let uncached_s = time_min(cfg.warmup, cfg.iters, || {
        for set in &cache_sets {
            cache_code.decode_stacked(set, &mut scratch).unwrap();
        }
    });
    // Warm the cache (all patterns inserted), then time pure hits.
    for set in &cache_sets {
        cached_code.decode_stacked(set, &mut scratch)?;
    }
    let cached_s = time_min(cfg.warmup, cfg.iters, || {
        for set in &cache_sets {
            cached_code.decode_stacked(set, &mut scratch).unwrap();
        }
    });
    // Steady-traffic hit rate on a fresh cache: patterns × reps
    // lookups, one miss per distinct pattern.
    let traffic_code = cache_code.clone().with_cache(Arc::new(LuCache::default()));
    let mut cache_identical = true;
    for _rep in 0..cache_reps {
        for set in &cache_sets {
            let (plain, plain_flops) = cache_code.decode_stacked(set, &mut scratch)?;
            let (memo, memo_flops) = traffic_code.decode_stacked(set, &mut scratch)?;
            // Bit-identity on every decode — cold misses and warm hits
            // alike — plus warmth-independent flop accounting.
            cache_identical &= plain.data() == memo.data() && plain_flops == memo_flops;
        }
    }
    let cache_stats = traffic_code
        .cache()
        .map(|c| c.stats())
        .unwrap_or_default();
    let cache_hit_rate = cache_stats.hit_rate();
    println!(
        "bench lu_cache_{cn}c{ck}_{cache_patterns}pat   uncached {}  cached {}  \
         speedup {:.2}x  (hit rate {:.1}%, bit-identical: {cache_identical})",
        fmt_time(uncached_s),
        fmt_time(cached_s),
        uncached_s / cached_s,
        cache_hit_rate * 100.0
    );

    // --- Hierarchical group-decode scaling. ---
    // Parity-heavy arrivals (last k1 workers of each group) force real
    // k1×k1 eliminations in every group; the k2 group decodes are the
    // §IV parallel units. Synthetic products time identically to real
    // ones (the solve never looks at the values) and skip a costly
    // encode at the 32k-row full size.
    let (n1, k1, n2, k2) = (20usize, 16usize, 5usize, 4usize);
    let rows = cfg.group_rows;
    let batch = cfg.group_batch;
    let block_rows = rows / (k1 * k2);
    let per_group: Vec<Vec<(usize, Matrix)>> = (0..n2)
        .map(|_| {
            (n1 - k1..n1)
                .map(|j| (j, random_matrix(&mut r, block_rows, batch)))
                .collect()
        })
        .collect();
    let mut scaling_s = Vec::new();
    let mut reference: Option<Vec<f64>> = None;
    let mut deterministic = true;
    for &t in &cfg.threads {
        let code = crate::coding::HierarchicalCode::homogeneous(n1, k1, n2, k2)?
            .with_pool(Arc::new(DecodePool::new(t)?));
        let s = time_min(cfg.warmup, cfg.iters, || {
            code.decode_hierarchical(&per_group).unwrap()
        });
        let out = code.decode_hierarchical(&per_group)?;
        match &reference {
            None => reference = Some(out.result.data().to_vec()),
            Some(expect) => {
                deterministic &= expect.as_slice() == out.result.data();
            }
        }
        scaling_s.push(s);
        println!(
            "bench hier_group_decode_{rows}x{batch}_t{t}   {}  ({:.2}x vs t1)",
            fmt_time(s),
            scaling_s[0] / s
        );
    }
    let speedup: Vec<f64> = scaling_s.iter().map(|&s| scaling_s[0] / s).collect();
    let efficiency: Vec<f64> = cfg
        .threads
        .iter()
        .zip(&speedup)
        .map(|(&t, &sp)| sp / t as f64)
        .collect();

    // --- Heterogeneous-topology group decode (skewed k1_g). ---
    // Distinct per-group thresholds make the fan-out's work items
    // unequal (16×16 eliminations next to 4×4 ones) — the load shape
    // heterogeneous scenarios and the allocator produce, tracked here
    // so the perf trajectory covers the non-uniform path too.
    let het_n1: [usize; 5] = [20, 20, 12, 8, 8];
    let het_k1: [usize; 5] = [16, 16, 8, 4, 4];
    let het_k2 = 4usize;
    let per_group_het: Vec<Vec<(usize, Matrix)>> = het_n1
        .iter()
        .zip(&het_k1)
        .map(|(&n1g, &k1g)| {
            let br = rows / (het_k2 * k1g);
            (n1g - k1g..n1g)
                .map(|j| (j, random_matrix(&mut r, br, batch)))
                .collect()
        })
        .collect();
    let het_code = |threads: usize| -> Result<crate::coding::HierarchicalCode> {
        Ok(crate::coding::HierarchicalCode::new(
            crate::coding::HierarchicalParams {
                n1: het_n1.to_vec(),
                k1: het_k1.to_vec(),
                n2: het_n1.len(),
                k2: het_k2,
            },
        )?
        .with_pool(Arc::new(DecodePool::new(threads)?)))
    };
    let serial_code = het_code(1)?;
    let het_serial_s = time_min(cfg.warmup, cfg.iters, || {
        serial_code.decode_hierarchical(&per_group_het).unwrap()
    });
    let max_t = *cfg.threads.last().unwrap();
    let par_code = het_code(max_t)?;
    let het_parallel_s = time_min(cfg.warmup, cfg.iters, || {
        par_code.decode_hierarchical(&per_group_het).unwrap()
    });
    let het_out_serial = serial_code.decode_hierarchical(&per_group_het)?;
    let het_out_par = par_code.decode_hierarchical(&per_group_het)?;
    let het_deterministic =
        het_out_serial.result.data() == het_out_par.result.data()
            && het_out_serial.flops == het_out_par.flops;
    println!(
        "bench hetero_group_decode_{rows}x{batch}  serial {}  t{max_t} {}  ({:.2}x, {} flops)",
        fmt_time(het_serial_s),
        fmt_time(het_parallel_s),
        het_serial_s / het_parallel_s,
        het_out_serial.flops
    );

    // --- Partial-work sub-task decode (r ∈ {1, 4}). ---
    // Same worker grid and arrival pattern (k1 full workers per
    // group), increasingly fine sub-task layering: each group's
    // elimination grows from k1×k1 to (k1·r)×(k1·r) — the decode-cost
    // side of the arXiv:1806.10250 tradeoff, with a serial-vs-pooled
    // bit-identity verdict per r.
    let pr_sweep: [usize; 2] = [1, 4];
    let (pn1, pk1, pn2, pk2) = (8usize, 4usize, 4usize, 2usize);
    let pblock = rows / (pk1 * pk2);
    let mut partial_serial = Vec::new();
    let mut partial_parallel = Vec::new();
    let mut partial_flops: Vec<usize> = Vec::new();
    let mut partial_deterministic = true;
    for &pr in &pr_sweep {
        let mut ptopo = crate::scenario::Topology::homogeneous(pn1, pk1, pn2, pk2);
        for g in &mut ptopo.groups {
            g.subtasks = pr;
        }
        let mk_code = |threads: usize| -> Result<crate::coding::HierarchicalCode> {
            let pool = Arc::new(DecodePool::new(threads)?);
            Ok(crate::coding::HierarchicalCode::from_topology(ptopo.clone())?.with_pool(pool))
        };
        // Parity-heavy full-worker products (last k1 workers of each
        // group): the total data volume is constant across r.
        let per_group_partial: Vec<Vec<(usize, Matrix)>> = (0..pn2)
            .map(|_| {
                (pn1 - pk1..pn1)
                    .map(|j| (j, random_matrix(&mut r, pblock, batch)))
                    .collect()
            })
            .collect();
        let serial_code = mk_code(1)?;
        let par_code = mk_code(max_t)?;
        let s_serial = time_min(cfg.warmup, cfg.iters, || {
            serial_code.decode_hierarchical(&per_group_partial).unwrap()
        });
        let s_par = time_min(cfg.warmup, cfg.iters, || {
            par_code.decode_hierarchical(&per_group_partial).unwrap()
        });
        let o_serial = serial_code.decode_hierarchical(&per_group_partial)?;
        let o_par = par_code.decode_hierarchical(&per_group_partial)?;
        partial_deterministic &= o_serial.result.data() == o_par.result.data()
            && o_serial.flops == o_par.flops;
        println!(
            "bench partial_decode_r{pr}_{rows}x{batch}   serial {}  t{max_t} {}  \
             ({} flops)",
            fmt_time(s_serial),
            fmt_time(s_par),
            o_serial.flops
        );
        partial_serial.push(s_serial);
        partial_parallel.push(s_par);
        partial_flops.push(o_serial.flops as usize);
    }

    // --- Streaming-session batch decode per scheme. ---
    let mut sessions = Vec::new();
    let srows = cfg.session_rows;
    for kind in SchemeKind::ALL {
        let scheme = build_scheme_with(kind, 4, 2, 4, 2, *cfg.threads.last().unwrap())?;
        let shard_rows = srows / scheme.num_data_blocks().max(1);
        let results: Vec<WorkerResult> = (2..scheme.num_workers())
            .map(|w| WorkerResult {
                shard: w,
                data: random_matrix(&mut r, shard_rows, 4),
            })
            .collect();
        let s = time_min(cfg.warmup, cfg.iters, || {
            scheme.decode(&results, srows).unwrap()
        });
        let flops = scheme.decode(&results, srows)?.flops;
        println!(
            "bench session_decode_{:<24} {}  ({flops} decode flops)",
            scheme.name(),
            fmt_time(s)
        );
        sessions.push(format!(
            "    {{\"scheme\": \"{}\", \"rows\": {srows}, \"batch\": 4, \
             \"seconds\": {}, \"decode_flops\": {flops}}}",
            scheme.name(),
            jf(s)
        ));
    }

    Ok(format!(
        "{{\n\
         \x20 \"schema\": \"hiercode-bench/decode/v1\",\n\
         \x20 \"smoke\": {},\n\
         \x20 \"gemm_decode\": {{\n\
         \x20   \"k\": {k}, \"n\": {n},\n\
         \x20   \"packed_s\": {},\n\
         \x20   \"reference_ikj_s\": {},\n\
         \x20   \"speedup_vs_reference\": {},\n\
         \x20   \"packed_gflops\": {}\n\
         \x20 }},\n\
         \x20 \"gemm_simd\": {{\n\
         \x20   \"k\": {k}, \"n\": {n}, \"kernel\": \"{}\",\n\
         \x20   \"simd_s\": {}, \"scalar_s\": {},\n\
         \x20   \"speedup_vs_scalar\": {},\n\
         \x20   \"simd_gflops\": {}, \"simd_gbs\": {},\n\
         \x20   \"bit_identical\": {simd_identical}\n\
         \x20 }},\n\
         \x20 \"lu_solve\": {{\"k\": {k}, \"rhs_cols\": {n}, \"seconds\": {}}},\n\
         \x20 \"lu_cache\": {{\n\
         \x20   \"n\": {cn}, \"k\": {ck}, \"patterns\": {cache_patterns}, \
         \"reps\": {cache_reps},\n\
         \x20   \"uncached_s\": {}, \"cached_s\": {},\n\
         \x20   \"speedup_vs_uncached\": {},\n\
         \x20   \"hits\": {}, \"misses\": {}, \"hit_rate\": {},\n\
         \x20   \"bit_identical\": {cache_identical}\n\
         \x20 }},\n\
         \x20 \"group_scaling\": {{\n\
         \x20   \"n1\": {n1}, \"k1\": {k1}, \"n2\": {n2}, \"k2\": {k2},\n\
         \x20   \"rows\": {rows}, \"batch\": {batch},\n\
         \x20   \"threads\": {},\n\
         \x20   \"seconds\": {},\n\
         \x20   \"speedup\": {},\n\
         \x20   \"efficiency_vs_ideal\": {}\n\
         \x20 }},\n\
         \x20 \"hetero_group_decode\": {{\n\
         \x20   \"n1\": {}, \"k1\": {}, \"k2\": {het_k2},\n\
         \x20   \"rows\": {rows}, \"batch\": {batch},\n\
         \x20   \"serial_s\": {}, \"parallel_s\": {}, \"threads\": {max_t},\n\
         \x20   \"speedup\": {}, \"decode_flops\": {},\n\
         \x20   \"deterministic\": {het_deterministic}\n\
         \x20 }},\n\
         \x20 \"partial_decode\": {{\n\
         \x20   \"n1\": {pn1}, \"k1\": {pk1}, \"n2\": {pn2}, \"k2\": {pk2},\n\
         \x20   \"rows\": {rows}, \"batch\": {batch}, \"threads\": {max_t},\n\
         \x20   \"r\": {}, \"serial_s\": {}, \"parallel_s\": {},\n\
         \x20   \"decode_flops\": {},\n\
         \x20   \"deterministic\": {partial_deterministic}\n\
         \x20 }},\n\
         \x20 \"session_decode\": [\n{}\n  ],\n\
         \x20 \"deterministic_across_threads\": {}\n\
         }}\n",
        cfg.smoke,
        jf(packed_s),
        jf(ikj_s),
        jf(gemm_speedup),
        jf(gflops),
        kern.name,
        jf(simd_s),
        jf(kscalar_s),
        jf(simd_speedup),
        jf(simd_gflops),
        jf(simd_gbs),
        jf(solve_s),
        jf(uncached_s),
        jf(cached_s),
        jf(uncached_s / cached_s),
        cache_stats.hits,
        cache_stats.misses,
        jf(cache_hit_rate),
        ju_list(&cfg.threads),
        jf_list(&scaling_s),
        jf_list(&speedup),
        jf_list(&efficiency),
        ju_list(&het_n1),
        ju_list(&het_k1),
        jf(het_serial_s),
        jf(het_parallel_s),
        jf(het_serial_s / het_parallel_s),
        het_out_serial.flops,
        ju_list(&pr_sweep),
        jf_list(&partial_serial),
        jf_list(&partial_parallel),
        ju_list(&partial_flops),
        sessions.join(",\n"),
        deterministic
    ))
}

/// Sharded Monte-Carlo throughput with its bit-identical check.
fn bench_sim(cfg: &BenchConfig) -> Result<String> {
    let p = SimParams {
        n1: 10,
        k1: 5,
        n2: 100,
        k2: 90,
        mu1: 10.0,
        mu2: 1.0,
    };
    let trials = cfg.sim_trials;
    let mut seconds = Vec::new();
    let mut rates = Vec::new();
    let mut reference: Option<montecarlo::Estimate> = None;
    let mut bit_identical = true;
    for &t in &cfg.threads {
        let pool = DecodePool::new(t)?;
        let s = time_min(0, 1.max(cfg.iters / 3), || {
            montecarlo::expected_latency_with(&p, trials, 42, &pool).unwrap()
        });
        let est = montecarlo::expected_latency_with(&p, trials, 42, &pool)?;
        match &reference {
            None => reference = Some(est),
            Some(e) => {
                bit_identical &= e.mean.to_bits() == est.mean.to_bits()
                    && e.ci95.to_bits() == est.ci95.to_bits();
            }
        }
        seconds.push(s);
        rates.push(trials as f64 / s);
        println!(
            "bench montecarlo_{trials}trials_t{t}   {}  ({:.0} trials/s)",
            fmt_time(s),
            trials as f64 / s
        );
    }
    let est = reference.ok_or_else(|| Error::InvalidParams("no thread configs".into()))?;
    Ok(format!(
        "{{\n\
         \x20 \"schema\": \"hiercode-bench/sim/v1\",\n\
         \x20 \"smoke\": {},\n\
         \x20 \"params\": {{\"n1\": {}, \"k1\": {}, \"n2\": {}, \"k2\": {}, \
         \"mu1\": {}, \"mu2\": {}}},\n\
         \x20 \"trials\": {trials},\n\
         \x20 \"threads\": {},\n\
         \x20 \"seconds\": {},\n\
         \x20 \"trials_per_s\": {},\n\
         \x20 \"mean\": {},\n\
         \x20 \"ci95\": {},\n\
         \x20 \"bit_identical_across_threads\": {bit_identical}\n\
         }}\n",
        cfg.smoke,
        p.n1,
        p.k1,
        p.n2,
        p.k2,
        p.mu1,
        p.mu2,
        ju_list(&cfg.threads),
        jf_list(&seconds),
        jf_list(&rates),
        jf(est.mean),
        jf(est.ci95),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_writes_json_baselines() {
        let dir = std::env::temp_dir().join("hiercode_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.to_str().unwrap().to_string();
        let args = Args::parse(&[
            "--smoke".to_string(),
            "--out".to_string(),
            out.clone(),
            "--iters".to_string(),
            "1".to_string(),
            "--threads".to_string(),
            "2".to_string(),
        ])
        .unwrap();
        run(&args).unwrap();
        for name in ["BENCH_decode.json", "BENCH_sim.json"] {
            let text = std::fs::read_to_string(dir.join(name)).unwrap();
            // Must be parseable by our own JSON parser and carry the
            // determinism verdicts.
            let v = crate::config::json::Json::parse(&text).unwrap();
            assert!(v.get("schema").is_some(), "{name} missing schema");
            assert!(text.contains("true"), "{name}: determinism check absent");
            if name == "BENCH_decode.json" {
                let simd = v.get("gemm_simd").expect("SIMD GEMM entry missing");
                assert_eq!(
                    simd.get("bit_identical").and_then(|d| d.as_bool()),
                    Some(true),
                    "dispatched kernels must be bit-identical to scalar"
                );
                assert!(
                    simd.get("kernel").and_then(|x| x.as_str()).is_some(),
                    "gemm_simd must record which kernel table ran"
                );
                let cache = v.get("lu_cache").expect("LU cache entry missing");
                assert_eq!(
                    cache.get("bit_identical").and_then(|d| d.as_bool()),
                    Some(true),
                    "cached decodes must be bit-identical to uncached"
                );
                // 4 patterns × 20 reps, one miss per pattern → 95%.
                let rate = cache
                    .get("hit_rate")
                    .and_then(|x| x.as_f64())
                    .expect("hit_rate present");
                assert!(
                    rate >= 0.9,
                    "steady-traffic hit rate {rate} below the 90% target"
                );
                let het = v
                    .get("hetero_group_decode")
                    .expect("heterogeneous decode scenario missing");
                assert_eq!(
                    het.get("deterministic").and_then(|d| d.as_bool()),
                    Some(true),
                    "hetero decode must be bit-identical across pool widths"
                );
                let partial = v
                    .get("partial_decode")
                    .expect("partial-work decode scenario missing");
                assert_eq!(
                    partial.get("deterministic").and_then(|d| d.as_bool()),
                    Some(true),
                    "partial-work decode must be bit-identical across pool widths"
                );
                let rs = partial.get("r").and_then(|x| x.as_array()).unwrap();
                assert_eq!(rs.len(), 2, "r sweep covers 1 and 4");
            }
        }
        // The freshly written output must also pass against the
        // committed trend snapshot — the exact check CI runs.
        let decode_text =
            std::fs::read_to_string(dir.join("BENCH_decode.json")).unwrap();
        let trend = r#"{
          "schema": "hiercode-bench/decode-trend/v1",
          "gemm_simd": {"speedup_vs_scalar": 1.5, "bit_identical": true},
          "lu_cache": {"hit_rate": 0.9, "bit_identical": true},
          "hetero_group_decode": {"deterministic": true},
          "partial_decode": {"deterministic": true},
          "deterministic_across_threads": true
        }"#;
        check_trend(&decode_text, trend).unwrap();
    }

    #[test]
    fn trend_check_gates_verdicts_hard_and_numerics_generously() {
        let trend = r#"{
          "gemm_simd": {"speedup_vs_scalar": 1.5, "bit_identical": true},
          "lu_cache": {"hit_rate": 0.9, "bit_identical": true},
          "hetero_group_decode": {"deterministic": true},
          "partial_decode": {"deterministic": true},
          "deterministic_across_threads": true
        }"#;
        let good = r#"{
          "gemm_simd": {"speedup_vs_scalar": 0.95, "bit_identical": true},
          "lu_cache": {"hit_rate": 0.95, "bit_identical": true},
          "hetero_group_decode": {"deterministic": true},
          "partial_decode": {"deterministic": true},
          "deterministic_across_threads": true
        }"#;
        // 0.95x "speedup" (a scalar-only host) clears the ¼ floor.
        check_trend(good, trend).unwrap();

        // A flipped bit-identity verdict is a hard failure...
        let bad_verdict = good.replace(
            r#""lu_cache": {"hit_rate": 0.95, "bit_identical": true}"#,
            r#""lu_cache": {"hit_rate": 0.95, "bit_identical": false}"#,
        );
        let err = check_trend(&bad_verdict, trend).unwrap_err().to_string();
        assert!(err.contains("lu_cache.bit_identical"), "{err}");

        // ...as is a numeric collapse far below the generous floor.
        let bad_numeric = good.replace(
            r#""speedup_vs_scalar": 0.95"#,
            r#""speedup_vs_scalar": 0.2"#,
        );
        let err = check_trend(&bad_numeric, trend).unwrap_err().to_string();
        assert!(err.contains("gemm_simd.speedup_vs_scalar"), "{err}");

        // A missing verdict in the bench output is also a failure —
        // silently dropping a check must not pass CI.
        let dropped = r#"{
          "gemm_simd": {"speedup_vs_scalar": 0.95, "bit_identical": true},
          "lu_cache": {"hit_rate": 0.95, "bit_identical": true},
          "partial_decode": {"deterministic": true},
          "deterministic_across_threads": true
        }"#;
        let err = check_trend(dropped, trend).unwrap_err().to_string();
        assert!(err.contains("hetero_group_decode.deterministic"), "{err}");

        // An empty snapshot pins nothing except the numerics it names.
        check_trend(good, "{}").unwrap();
    }
}
