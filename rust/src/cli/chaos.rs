//! `hiercode chaos` — seeded fault-injection harness for the serving
//! cluster.
//!
//! Robustness is a claim until it is measured. This harness launches a
//! live [`ClusterCore`] (demo hierarchical grid, native backend, tight
//! liveness timeouts) and replays seeded [`FaultPlan`] schedules
//! against it through the supervisor's [`FaultInjector`] surface while
//! closed-loop clients keep submitting:
//!
//! 1. **Survivable churn, twice with the same seed** — every round one
//!    worker per group (with spare redundancy) crashes and restarts.
//!    Both runs must complete 100% of their accepted jobs, and their
//!    [`ChaosReport`] event tallies must match event for event: the
//!    determinism verdict.
//! 2. **Unsurvivable severs** — `n2 − k2 + 1` uplinks go down and stay
//!    down. Probe jobs submitted afterwards must fail **fast** with
//!    [`Error::Insufficient`] (the master's failure detector sweeping
//!    them out), never by hanging until the admission deadline.
//!
//! Results go to `BENCH_chaos.json` in `--out` (default `.`):
//! per-run completion/failure tallies, recovery latencies for every
//! worker restart, the determinism verdict, and the fail-fast verdict,
//! plus the final [`MetricsSnapshot`](crate::coordinator::metrics::
//! MetricsSnapshot) of the first churn run (liveness gauges included).
//! The harness exits nonzero when any verdict fails, so CI catches
//! robustness regressions, not just crashes.
//!
//! `--smoke` shrinks everything for CI (≈2s total).

use crate::cli::args::Args;
use crate::config::schema::ClusterConfig;
use crate::coordinator::chaos::{self, ChaosReport};
use crate::coordinator::fault::FaultPlan;
use crate::coordinator::ClusterCore;
use crate::linalg::Matrix;
use crate::sync::WallClock;
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// JSON-safe float literal (same convention as `hiercode bench`).
fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9e}")
    } else {
        "null".to_string()
    }
}

/// The model every chaos run registers and hammers.
const MODEL: &str = "chaos";
/// Model shape: rows divisible by the demo grid's k2·k1 = 4.
const ROWS: usize = 16;
const COLS: usize = 4;

/// Workload knobs shared by every run.
struct ChaosLoad {
    seed: u64,
    duration_ms: u64,
    period_ms: u64,
    clients: usize,
    probe_jobs: usize,
}

/// A cluster config tuned for chaos runs: native backend, liveness on
/// with tight timeouts (detection must be much faster than the
/// admission deadline for the fail-fast verdict to mean anything).
fn chaos_config() -> ClusterConfig {
    let mut config = ClusterConfig::demo(3, 2, 3, 2);
    config.chaos.liveness = true;
    config.chaos.heartbeat_ms = 5.0;
    config.chaos.suspect_ms = 40.0;
    config.chaos.dead_ms = 120.0;
    config.serving.queue_cap = 64;
    config.serving.default_deadline_ms = 10_000.0;
    config.serving.drain_ms = 2_000.0;
    config.batching.max_wait_ms = 1.0;
    config
}

/// One survivable-churn measurement.
struct ChurnOutcome {
    completed: u64,
    failed: u64,
    shed: u64,
    busy: u64,
    wall_s: f64,
    report: ChaosReport,
    metrics_json: String,
}

impl ChurnOutcome {
    /// Every accepted job resolved successfully (Busy bounces are
    /// admission backpressure, not failures).
    fn all_jobs_completed(&self) -> bool {
        self.completed > 0 && self.failed == 0 && self.shed == 0
    }
}

/// Launch a fresh cluster, replay a survivable churn schedule against
/// it under closed-loop load, and tally the outcome.
fn run_churn(load: &ChaosLoad) -> Result<ChurnOutcome> {
    let config = chaos_config();
    let core = ClusterCore::launch(&config)?;
    let mut mr = Rng::new(load.seed);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| mr.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a)?;
    let plan = FaultPlan::survivable_churn(
        load.seed,
        &config.code.topology,
        load.duration_ms,
        load.period_ms,
    );
    let driver = chaos::spawn(core.injector(), plan, Arc::new(WallClock::new()))?;
    let t0 = Instant::now();
    // Clients outlive the schedule by one period, so the last restart's
    // recovery path serves real jobs before shutdown.
    let t_end = t0 + Duration::from_millis(load.duration_ms + load.period_ms);
    let mut joins = Vec::new();
    for t in 0..load.clients {
        let client = core.handle();
        let mut rng =
            Rng::new(load.seed ^ (t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        joins.push(std::thread::spawn(move || {
            let (mut completed, mut failed, mut shed, mut busy) = (0u64, 0u64, 0u64, 0u64);
            while Instant::now() < t_end {
                let x: Vec<f64> = (0..COLS).map(|_| rng.uniform(-1.0, 1.0)).collect();
                match client.submit_to(MODEL, x) {
                    // Bounded wait (well above the 10s admission
                    // deadline): a stuck job counts as failed instead
                    // of wedging the harness.
                    Ok(h) => match h.wait_timeout(Duration::from_secs(15)) {
                        Ok(_) => completed += 1,
                        Err(Error::DeadlineExceeded) => shed += 1,
                        Err(_) => failed += 1,
                    },
                    Err(Error::Busy { .. }) => {
                        busy += 1;
                        std::thread::yield_now();
                    }
                    Err(_) => {
                        // Never accepted (shutdown raced us): stop.
                        failed += 1;
                        break;
                    }
                }
            }
            (completed, failed, shed, busy)
        }));
    }
    let (mut completed, mut failed, mut shed, mut busy) = (0u64, 0u64, 0u64, 0u64);
    for j in joins {
        let (c, f, s, b) = j.join().expect("chaos client panicked");
        completed += c;
        failed += f;
        shed += s;
        busy += b;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = driver
        .join()
        .map_err(|_| Error::Coordinator("chaos driver panicked".into()))?;
    let metrics_json = core.metrics().to_json();
    core.shutdown();
    Ok(ChurnOutcome {
        completed,
        failed,
        shed,
        busy,
        wall_s,
        report,
        metrics_json,
    })
}

/// The unsurvivable-severs measurement: probes submitted after the
/// detector ages the severed groups out must fail fast with
/// [`Error::Insufficient`].
struct SeverOutcome {
    baseline_completed: u64,
    severs: u64,
    insufficient: u64,
    unexpected: u64,
    max_fail_ms: f64,
}

impl SeverOutcome {
    fn failed_fast(&self, probe_jobs: usize) -> bool {
        self.insufficient == probe_jobs as u64 && self.unexpected == 0
    }
}

fn run_severs(load: &ChaosLoad) -> Result<SeverOutcome> {
    let config = chaos_config();
    let core = ClusterCore::launch(&config)?;
    let mut mr = Rng::new(load.seed);
    let a = Matrix::from_fn(ROWS, COLS, |_, _| mr.uniform(-1.0, 1.0));
    core.register_model(MODEL, &a)?;
    let client = core.handle();
    // Baseline: the healthy cluster serves.
    let mut baseline_completed = 0u64;
    for _ in 0..2 {
        let x: Vec<f64> = (0..COLS).map(|_| mr.uniform(-1.0, 1.0)).collect();
        if client.submit_to(MODEL, x)?.wait().is_ok() {
            baseline_completed += 1;
        }
    }
    let sever_at = 20u64;
    let plan = FaultPlan::unsurvivable_severs(load.seed, &config.code.topology, sever_at);
    let severs = plan.len() as u64;
    let driver = chaos::spawn(core.injector(), plan, Arc::new(WallClock::new()))?;
    // Let the severs land (≤ sever_at + 40ms jitter) and the detector
    // age the quiet groups out (dead_ms), with margin.
    std::thread::sleep(Duration::from_millis(
        sever_at + 40 + config.chaos.dead_ms as u64 + 60,
    ));
    let (mut insufficient, mut unexpected) = (0u64, 0u64);
    let mut max_fail_ms = 0.0f64;
    for _ in 0..load.probe_jobs {
        let x: Vec<f64> = (0..COLS).map(|_| mr.uniform(-1.0, 1.0)).collect();
        let t = Instant::now();
        // The 5s guard is far below the 10s admission deadline: a probe
        // that needs it did NOT fail fast.
        match client.submit_to(MODEL, x)?.wait_timeout(Duration::from_secs(5)) {
            Err(Error::Insufficient { .. }) => {
                insufficient += 1;
                max_fail_ms = max_fail_ms.max(t.elapsed().as_secs_f64() * 1e3);
            }
            _ => unexpected += 1,
        }
    }
    driver
        .join()
        .map_err(|_| Error::Coordinator("chaos driver panicked".into()))?;
    core.shutdown();
    Ok(SeverOutcome {
        baseline_completed,
        severs,
        insufficient,
        unexpected,
        max_fail_ms,
    })
}

/// Mean and max over the finite recovery latencies.
fn recovery_stats(ms: &[f64]) -> (f64, f64) {
    let finite: Vec<f64> = ms.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        (f64::NAN, f64::NAN)
    } else {
        let mean = finite.iter().sum::<f64>() / finite.len() as f64;
        let max = finite.iter().fold(f64::MIN, |a, &b| a.max(b));
        (mean, max)
    }
}

fn render_churn(r: &ChurnOutcome) -> String {
    let (rec_mean, rec_max) = recovery_stats(&r.report.recovery_ms);
    let counts = r.report.event_counts();
    format!(
        "      {{\"completed\": {}, \"failed\": {}, \"deadline_shed\": {}, \
         \"busy_rejected\": {}, \"wall_s\": {}, \"event_counts\": [{}, {}, {}, {}, {}], \
         \"recovery_ms\": {{\"count\": {}, \"mean\": {}, \"max\": {}}}}}",
        r.completed,
        r.failed,
        r.shed,
        r.busy,
        jf(r.wall_s),
        counts[0],
        counts[1],
        counts[2],
        counts[3],
        counts[4],
        r.report.recovery_ms.len(),
        jf(rec_mean),
        jf(rec_max),
    )
}

/// Render the `BENCH_chaos.json` document.
fn render_json(
    smoke: bool,
    load: &ChaosLoad,
    runs: &[ChurnOutcome],
    identical: bool,
    sever: &SeverOutcome,
    pass: bool,
) -> String {
    let entries: Vec<String> = runs.iter().map(render_churn).collect();
    let all_completed = runs.iter().all(ChurnOutcome::all_jobs_completed);
    format!(
        "{{\n\
         \x20 \"schema\": \"hiercode-bench/chaos/v1\",\n\
         \x20 \"smoke\": {smoke},\n\
         \x20 \"seed\": {},\n\
         \x20 \"grid\": {{\"n1\": 3, \"k1\": 2, \"n2\": 3, \"k2\": 2}},\n\
         \x20 \"survivable\": {{\n\
         \x20   \"duration_ms\": {}, \"period_ms\": {}, \"clients\": {},\n\
         \x20   \"runs\": [\n{}\n    ],\n\
         \x20   \"all_jobs_completed\": {all_completed},\n\
         \x20   \"deterministic\": {identical}\n\
         \x20 }},\n\
         \x20 \"unsurvivable\": {{\n\
         \x20   \"baseline_completed\": {}, \"severs\": {}, \"probe_jobs\": {},\n\
         \x20   \"insufficient\": {}, \"unexpected\": {},\n\
         \x20   \"max_fail_ms\": {}, \"failed_fast\": {}\n\
         \x20 }},\n\
         \x20 \"verdict\": \"{}\",\n\
         \x20 \"metrics\": {}\n\
         }}\n",
        load.seed,
        load.duration_ms,
        load.period_ms,
        load.clients,
        entries.join(",\n"),
        sever.baseline_completed,
        sever.severs,
        load.probe_jobs,
        sever.insufficient,
        sever.unexpected,
        jf(sever.max_fail_ms),
        sever.failed_fast(load.probe_jobs),
        if pass { "pass" } else { "fail" },
        runs.first()
            .map(|r| r.metrics_json.as_str())
            .unwrap_or("null"),
    )
}

/// Run the chaos harness; writes `BENCH_chaos.json`.
pub fn run(args: &Args) -> Result<()> {
    let smoke = args.has_flag("smoke");
    let out_dir = args.get_str("out").unwrap_or(".").to_string();
    let load = ChaosLoad {
        seed: args.get_usize("seed")?.unwrap_or(42) as u64,
        duration_ms: args
            .get_usize("duration-ms")?
            .unwrap_or(if smoke { 600 } else { 3000 }) as u64,
        period_ms: args
            .get_usize("period-ms")?
            .unwrap_or(if smoke { 150 } else { 300 }) as u64,
        clients: args.get_usize("clients")?.unwrap_or(if smoke { 2 } else { 4 }),
        probe_jobs: args.get_usize("probe-jobs")?.unwrap_or(3),
    };
    if load.period_ms == 0 || load.duration_ms < 2 * load.period_ms {
        return Err(Error::InvalidParams(
            "--duration-ms must be at least 2x --period-ms (and both positive), \
             or the churn schedule is empty and the run proves nothing"
                .into(),
        ));
    }
    if load.clients == 0 || load.probe_jobs == 0 {
        return Err(Error::InvalidParams(
            "--clients and --probe-jobs must be positive".into(),
        ));
    }
    eprintln!(
        "## hiercode chaos (smoke={smoke}, seed={}, churn {}ms/{}ms, \
         {} clients, {} probes)",
        load.seed, load.duration_ms, load.period_ms, load.clients, load.probe_jobs
    );
    // Two identical seeded churn runs: the determinism verdict.
    let mut runs = Vec::new();
    for i in 0..2 {
        let r = run_churn(&load)?;
        println!(
            "chaos churn run {i}: {} ok, {} failed, {} shed, {} busy in {:.2}s \
             (events {:?})",
            r.completed,
            r.failed,
            r.shed,
            r.busy,
            r.wall_s,
            r.report.event_counts()
        );
        runs.push(r);
    }
    let identical = runs[0].report.event_counts() == runs[1].report.event_counts();
    let sever = run_severs(&load)?;
    println!(
        "chaos severs: {} baseline ok, {} severed, {}/{} probes Insufficient \
         (max fail {:.1}ms)",
        sever.baseline_completed, sever.severs, sever.insufficient, load.probe_jobs,
        sever.max_fail_ms
    );
    let pass = runs.iter().all(ChurnOutcome::all_jobs_completed)
        && identical
        && sever.baseline_completed == 2
        && sever.failed_fast(load.probe_jobs);
    let json = render_json(smoke, &load, &runs, identical, &sever, pass);
    let path = format!("{out_dir}/BENCH_chaos.json");
    std::fs::write(&path, json)?;
    println!("wrote {path}");
    if !pass {
        return Err(Error::Coordinator(format!(
            "chaos verdict FAILED (see {path}): churn complete={:?}, \
             deterministic={identical}, fail-fast={}",
            runs.iter().map(|r| r.all_jobs_completed()).collect::<Vec<_>>(),
            sever.failed_fast(load.probe_jobs)
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_chaos_writes_report_and_passes() {
        let dir = std::env::temp_dir().join("hiercode_chaos_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.to_str().unwrap().to_string();
        let args = Args::parse(&[
            "--smoke".to_string(),
            "--duration-ms".to_string(),
            "400".to_string(),
            "--period-ms".to_string(),
            "100".to_string(),
            "--probe-jobs".to_string(),
            "2".to_string(),
            "--out".to_string(),
            out,
        ])
        .unwrap();
        run(&args).unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_chaos.json")).unwrap();
        let v = crate::config::json::Json::parse(&text).unwrap();
        assert_eq!(
            v.get("schema").and_then(|s| s.as_str()),
            Some("hiercode-bench/chaos/v1")
        );
        let surv = v.get("survivable").unwrap();
        assert_eq!(surv.get("deterministic").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(
            surv.get("all_jobs_completed").and_then(|b| b.as_bool()),
            Some(true)
        );
        assert_eq!(surv.get("runs").and_then(|r| r.as_array()).unwrap().len(), 2);
        let unsurv = v.get("unsurvivable").unwrap();
        assert!(unsurv.get("insufficient").and_then(|n| n.as_usize()).unwrap() > 0);
        assert_eq!(
            unsurv.get("failed_fast").and_then(|b| b.as_bool()),
            Some(true)
        );
        assert_eq!(v.get("verdict").and_then(|s| s.as_str()), Some("pass"));
        // The embedded metrics snapshot is a real object with liveness
        // gauges, not a stringified blob.
        let metrics = v.get("metrics").unwrap();
        assert!(metrics.get("per_group").is_some());
    }

    #[test]
    fn chaos_rejects_bad_arguments() {
        for bad in [
            vec!["--duration-ms", "100", "--period-ms", "100"],
            vec!["--period-ms", "0"],
            vec!["--clients", "0"],
            vec!["--probe-jobs", "0"],
        ] {
            let argv: Vec<String> = bad.iter().map(|s| s.to_string()).collect();
            let args = Args::parse(&argv).unwrap();
            assert!(run(&args).is_err(), "must reject {bad:?}");
        }
    }
}
