//! Tiny declarative argument parser: `--key value`, `--flag`,
//! positionals.

use crate::{Error, Result};
use std::collections::HashMap;

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// `--key value` pairs.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
    /// Positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse an argv slice. A `--key` followed by a non-`--` token is an
    /// option; a `--key` followed by another `--key` (or nothing) is a
    /// flag.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    return Err(Error::InvalidParams("bare '--' not supported".into()));
                }
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    args.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    args.flags.push(key.to_string());
                    i += 1;
                }
            } else {
                args.positional.push(tok.clone());
                i += 1;
            }
        }
        Ok(args)
    }

    /// String option.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// usize option with a parse error naming the key.
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v.parse::<usize>().map(Some).map_err(|_| {
                Error::InvalidParams(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    }

    /// f64 option.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v.parse::<f64>().map(Some).map_err(|_| {
                Error::InvalidParams(format!("--{key} expects a number, got '{v}'"))
            }),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated usize list option (e.g. `--n1 10,10,8`).
    pub fn get_usize_list(&self, key: &str) -> Result<Option<Vec<usize>>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim().parse::<usize>().map_err(|_| {
                        Error::InvalidParams(format!(
                            "--{key} expects comma-separated integers, got '{v}'"
                        ))
                    })
                })
                .collect::<Result<Vec<usize>>>()
                .map(Some),
        }
    }

    /// Comma-separated f64 list option (e.g. `--mu1 10,10,0.5`).
    pub fn get_f64_list(&self, key: &str) -> Result<Option<Vec<f64>>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim().parse::<f64>().map_err(|_| {
                        Error::InvalidParams(format!(
                            "--{key} expects comma-separated numbers, got '{v}'"
                        ))
                    })
                })
                .collect::<Result<Vec<f64>>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&[
            "fig6a", "--trials", "100", "--verbose", "--mu1", "2.5", "extra",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["fig6a", "extra"]);
        assert_eq!(a.get_usize("trials").unwrap(), Some(100));
        assert_eq!(a.get_f64("mu1").unwrap(), Some(2.5));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&sv(&["--trials", "abc"])).unwrap();
        assert!(a.get_usize("trials").is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&sv(&["--no-pjrt"])).unwrap();
        assert!(a.has_flag("no-pjrt"));
    }

    #[test]
    fn list_options_parse_and_reject_garbage() {
        let a = Args::parse(&sv(&["--n1", "10,8, 6", "--mu1", "10,0.5,1e-2"])).unwrap();
        assert_eq!(a.get_usize_list("n1").unwrap(), Some(vec![10, 8, 6]));
        assert_eq!(a.get_f64_list("mu1").unwrap(), Some(vec![10.0, 0.5, 0.01]));
        assert_eq!(a.get_usize_list("absent").unwrap(), None);
        let bad = Args::parse(&sv(&["--n1", "10,x"])).unwrap();
        assert!(bad.get_usize_list("n1").is_err());
    }

    #[test]
    fn negative_number_is_value() {
        // "--mu1 -2.5" would read -2.5 as a flag (starts with --? no,
        // single dash) — ensure single-dash values are accepted.
        let a = Args::parse(&sv(&["--shift", "-2.5"])).unwrap();
        assert_eq!(a.get_f64("shift").unwrap(), Some(-2.5));
    }
}
