//! Command-line launcher (hand-rolled; `clap` is unavailable offline).
//!
//! ```text
//! hiercode figures  <fig6a|fig6b|fig7|table1|decode-scaling|allocation|partial|all>
//! hiercode sim      --k1 K1 --k2 K2 [--n1 N1] [--n2 N2] [--mu1 R] [--mu2 R] [--trials N]
//! hiercode bounds   --k1 K1 --k2 K2 [--n1 N1] [--n2 N2] [--mu1 R] [--mu2 R]
//! hiercode allocate --n1 L --k2 K2 [--mu1 L|R] [--mu2 L|R] (--recovery F | --total-k1 K)
//! hiercode serve    [--config FILE] [--scheme S] [--requests N] [--no-pjrt]
//! hiercode help
//! ```

pub mod args;
pub mod benchcmd;
pub mod chaos;
pub mod controlcmd;
pub mod loadgen;
pub mod node;
pub mod transportcmd;

use crate::sim::{bounds, markov, montecarlo, SimParams};
use args::Args;

const USAGE: &str = "\
hiercode — Hierarchical Coding for Distributed Computing (Park et al., 2018)

USAGE:
  hiercode figures <fig6a|fig6b|fig7|table1|decode-scaling|allocation|partial|all>
                   [--trials N] [--seed S]
  hiercode sim     --k1 K1 --k2 K2 [--n1 N1] [--n2 N2]
                   [--mu1 R] [--mu2 R] [--trials N] [--seed S]
  hiercode bounds  --k1 K1 --k2 K2 [--n1 N1] [--n2 N2] [--mu1 R] [--mu2 R]
  hiercode allocate --n1 N1,N1,... --k2 K2 [--mu1 R | R,R,...]
                   [--mu2 R | R,R,...] (--recovery F | --total-k1 K)
                   [--trials N] [--seed S] [--json]
  hiercode serve   [--config FILE] [--requests N] [--no-pjrt]
                   [--scheme hierarchical|mds|product|replication|polynomial]
                   [--transport uds:PATH|tcp:HOST:PORT]
                   [--admin uds:PATH|tcp:HOST:PORT] [--hold-ms T]
  hiercode compile <config.json> [--out FILE]
  hiercode admin   --connect uds:PATH|tcp:HOST:PORT
                   <status|metrics|reoptimize|rollout <FILE>|rollback>
                   [--out FILE]
  hiercode control [--smoke] [--seed S] [--inflight N] [--out DIR]
  hiercode bench   [--smoke] [--threads N] [--iters N] [--out DIR]
                   [--trend FILE]
  hiercode loadgen [--smoke] [--schemes S,S] [--clients N,N,...]
                   [--duration-s T] [--models N] [--rows R] [--cols C]
                   [--queue-cap Q] [--deadline-ms D] [--seed S] [--out DIR]
  hiercode chaos   [--smoke] [--seed S] [--duration-ms T] [--period-ms P]
                   [--clients N] [--probe-jobs N] [--out DIR]
  hiercode node    --group G --connect ADDR
                   (--config FILE | --preset NAME | --demo n1,k1,n2,k2)
                   [--seed S] [--no-pjrt] [--max-dial-ms T]
                   [--backoff-ms T] [--backoff-max-ms T]
  hiercode transport [--smoke] [--threads] [--seed S] [--jobs N]
                   [--probe-jobs N] [--max-dial-ms T] [--out DIR]
  hiercode help

`figures` regenerates the paper's evaluation artifacts (CSV on stdout).
`sim` Monte-Carlo-estimates E[T]; `bounds` prints L / Lemma 2 / Thm 2.
`allocate` searches per-group inner thresholds k1_g minimizing the §III
upper bound for a heterogeneous fleet (per-group --mu1 rates), and
reports uniform vs optimized bound and Monte-Carlo E[T].
`serve` launches the in-process cluster (any scheme via --scheme) and
runs a request workload through its streaming decode sessions.
`bench` runs the decode/GEMM/simulator benches and writes the
BENCH_decode.json / BENCH_sim.json perf baselines to --out (default .);
--trend FILE diffs the decode baseline against a committed snapshot
(hard-fails on determinism-verdict regressions, generous numeric floor).
`loadgen` drives the multi-tenant job service with closed-loop clients
round-robining across --models registered models, per scheme and
concurrency level, and writes throughput + p50/p95/p99 latency (and
busy/shed accounting) to BENCH_serving.json in --out.
`chaos` replays seeded kill/restart and link-sever schedules against a
live serving cluster under closed-loop load: two same-seed survivable
churn runs (determinism + 100% completion verdicts) and an
unsurvivable sever run (fast-fail verdict), written to BENCH_chaos.json
in --out; exits nonzero on any failed verdict.
`serve --transport uds:/tmp/hub.sock` binds a socket hub instead of the
in-memory channels and waits for one `hiercode node` process per group
to dial in before serving.
`node` runs one submaster/worker group as its own OS process: it
rebuilds the master's config (same file, preset, or demo grid — the
handshake checks the seed), dials the hub, and serves until Shutdown.
`transport` verifies the socket transport against the in-memory oracle:
bit-identical outputs and counters on the same seeded stream, reconnect
with shard re-shipping under a node kill, and fast Insufficient failures
on an unsurvivable outage, written to BENCH_transport.json in --out;
exits nonzero on any failed verdict.
`compile` turns a validated cluster config into a versioned,
checksummed `.hca` scenario artifact (default scenario.hca) that
`serve --config`, `admin rollout` and `hiercode control` consume.
`serve --admin uds:/tmp/ctl.sock` additionally exposes the framed admin
surface on a dedicated control socket; --hold-ms keeps it (and the
cluster) up after the demo workload so an operator can drive rollouts.
`admin` is that operator: status/metrics print the cluster's JSON
documents, reoptimize writes a re-allocated candidate artifact to --out
(default candidate.hca), rollout hot-swaps an artifact file in with a
generation bump and zero dropped jobs, rollback restores the previous
generation.
`control` verifies the control plane end to end through a real admin
socket: zero-drop + pre-swap bit-identity across a heavy rollout,
post-swap generation and correctness, atomic rejection of incompatible
artifacts, and rollback restoring generation 1, written to
BENCH_control.json in --out; exits nonzero on any failed verdict.
";

/// CLI entry point (called from `main.rs`).
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    });
}

/// Run a parsed command line (testable).
pub fn run(argv: &[String]) -> crate::Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "figures" => figures_cmd(&args),
        "sim" => sim_cmd(&args),
        "bounds" => bounds_cmd(&args),
        "allocate" => allocate_cmd(&args),
        "serve" => serve_cmd(&args),
        "compile" => compile_cmd(&args),
        "admin" => admin_cmd(&args),
        "control" => controlcmd::run(&args),
        "bench" => benchcmd::run(&args),
        "loadgen" => loadgen::run(&args),
        "chaos" => chaos::run(&args),
        "node" => node::run(&args),
        "transport" => transportcmd::run(&args),
        other => Err(crate::Error::InvalidParams(format!(
            "unknown command '{other}' (try `hiercode help`)"
        ))),
    }
}

fn sim_params(args: &Args) -> crate::Result<SimParams> {
    let k1 = args.get_usize("k1")?.ok_or_else(|| {
        crate::Error::InvalidParams("--k1 is required".into())
    })?;
    let k2 = args.get_usize("k2")?.ok_or_else(|| {
        crate::Error::InvalidParams("--k2 is required".into())
    })?;
    let p = SimParams {
        n1: args.get_usize("n1")?.unwrap_or(2 * k1),
        k1,
        n2: args.get_usize("n2")?.unwrap_or(10),
        k2,
        mu1: args.get_f64("mu1")?.unwrap_or(10.0),
        mu2: args.get_f64("mu2")?.unwrap_or(1.0),
    };
    p.validate()?;
    Ok(p)
}

fn figures_cmd(args: &Args) -> crate::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let trials = args.get_usize("trials")?.unwrap_or(20_000);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    match which {
        "fig6a" => {
            crate::figures::fig6::run(5, trials, seed)?;
        }
        "fig6b" => {
            crate::figures::fig6::run(300, trials, seed)?;
        }
        "fig7" => {
            crate::figures::fig7::run(trials, seed)?;
        }
        "table1" => {
            crate::figures::table1::run(trials, seed)?;
        }
        "decode-scaling" => {
            crate::figures::decode_scaling::run(seed)?;
        }
        "allocation" => {
            crate::figures::allocation::run(trials, seed)?;
        }
        "partial" => {
            crate::figures::partial::run(trials, seed)?;
        }
        "all" => {
            crate::figures::fig6::run(5, trials, seed)?;
            println!();
            crate::figures::fig6::run(300, trials, seed)?;
            println!();
            crate::figures::fig7::run(trials, seed)?;
            println!();
            crate::figures::table1::run(trials, seed)?;
            println!();
            crate::figures::decode_scaling::run(seed)?;
            println!();
            crate::figures::allocation::run(trials, seed)?;
            println!();
            crate::figures::partial::run(trials, seed)?;
        }
        other => {
            return Err(crate::Error::InvalidParams(format!(
                "unknown figure '{other}'"
            )))
        }
    }
    Ok(())
}

fn sim_cmd(args: &Args) -> crate::Result<()> {
    let p = sim_params(args)?;
    let trials = args.get_usize("trials")?.unwrap_or(100_000);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let est = montecarlo::expected_latency(&p, trials, seed)?;
    println!(
        "E[T] = {:.6} ± {:.6} (95% CI, {} trials)  [({},{})x({},{}), mu1={}, mu2={}]",
        est.mean, est.ci95, trials, p.n1, p.k1, p.n2, p.k2, p.mu1, p.mu2
    );
    Ok(())
}

fn bounds_cmd(args: &Args) -> crate::Result<()> {
    let p = sim_params(args)?;
    println!("lower bound L (Thm 1 / Lemma 1): {:.6}", markov::lower_bound(&p)?);
    println!("upper bound (Lemma 2):           {:.6}", bounds::lemma2_upper(&p)?);
    match bounds::theorem2_upper(&p) {
        Ok(u) => println!("upper bound (Thm 2, asymptotic): {u:.6}"),
        Err(_) => println!("upper bound (Thm 2): n/a (needs n1 > k1)"),
    }
    Ok(())
}

fn allocate_cmd(args: &Args) -> crate::Result<()> {
    use crate::sim::allocate::{self, AllocationProblem};

    let n1 = args.get_usize_list("n1")?.ok_or_else(|| {
        crate::Error::InvalidParams(
            "--n1 is required (comma-separated workers per group, e.g. 10,10,8)".into(),
        )
    })?;
    let n2 = n1.len();
    let k2 = args.get_usize("k2")?.ok_or_else(|| {
        crate::Error::InvalidParams("--k2 is required".into())
    })?;
    // Rates: a single value broadcasts, a list is per-group.
    let broadcast = |list: Option<Vec<f64>>, default: f64| -> crate::Result<Vec<f64>> {
        match list {
            None => Ok(vec![default; n2]),
            Some(v) if v.len() == 1 => Ok(vec![v[0]; n2]),
            Some(v) if v.len() == n2 => Ok(v),
            Some(v) => Err(crate::Error::InvalidParams(format!(
                "rate list has {} entries for {n2} groups",
                v.len()
            ))),
        }
    };
    let mu1 = broadcast(args.get_f64_list("mu1")?, crate::scenario::DEFAULT_MU1)?;
    let mu2 = broadcast(args.get_f64_list("mu2")?, crate::scenario::DEFAULT_MU2)?;
    let problem = match (args.get_usize("total-k1")?, args.get_f64("recovery")?) {
        (Some(total_k1), None) => {
            let p = AllocationProblem {
                n1,
                k2,
                mu1,
                mu2,
                total_k1,
            };
            p.validate()?;
            p
        }
        (None, Some(recovery)) => {
            AllocationProblem::with_recovery_fraction(n1, k2, mu1, mu2, recovery)?
        }
        (None, None) => {
            return Err(crate::Error::InvalidParams(
                "one of --total-k1 or --recovery is required".into(),
            ))
        }
        (Some(_), Some(_)) => {
            return Err(crate::Error::InvalidParams(
                "--total-k1 and --recovery are mutually exclusive".into(),
            ))
        }
    };
    let alloc = allocate::optimize(&problem)?;
    let trials = args.get_usize("trials")?.unwrap_or(50_000);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let pool = crate::parallel::DecodePool::serial();
    let uni = montecarlo::expected_latency_topology(
        &problem.topology(&alloc.uniform_k1),
        trials,
        seed,
        &pool,
    )?;
    let opt = montecarlo::expected_latency_topology(
        &alloc.topology(&problem),
        trials,
        seed,
        &pool,
    )?;
    if args.has_flag("json") {
        // Machine-readable form (stable schema, consumed by tooling
        // that feeds `hiercode compile`d scenario configs).
        let jnum = |v: f64| {
            if v.is_finite() {
                format!("{v:.9e}")
            } else {
                "null".to_string()
            }
        };
        let jlist = |v: &[usize]| {
            let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
            format!("[{}]", items.join(", "))
        };
        println!(
            "{{\n\
             \x20 \"schema\": \"hiercode-allocate/v1\",\n\
             \x20 \"groups\": {}, \"k2\": {}, \"total_k1\": {},\n\
             \x20 \"n1\": {},\n\
             \x20 \"uniform\": {{\"k1\": {}, \"bound\": {}, \"latency_mean\": {}, \
             \"latency_ci95\": {}}},\n\
             \x20 \"optimized\": {{\"k1\": {}, \"bound\": {}, \"latency_mean\": {}, \
             \"latency_ci95\": {}, \"moves\": {}}},\n\
             \x20 \"bound_improvement_pct\": {}\n\
             }}",
            problem.n1.len(),
            problem.k2,
            problem.total_k1,
            jlist(&problem.n1),
            jlist(&alloc.uniform_k1),
            jnum(alloc.uniform_bound),
            jnum(uni.mean),
            jnum(uni.ci95),
            jlist(&alloc.k1),
            jnum(alloc.bound),
            jnum(opt.mean),
            jnum(opt.ci95),
            alloc.moves,
            jnum((1.0 - alloc.bound / alloc.uniform_bound) * 100.0)
        );
        return Ok(());
    }
    println!(
        "allocate: {} groups, k2={}, total k1={}",
        problem.n1.len(),
        problem.k2,
        problem.total_k1
    );
    println!(
        "uniform   k1={:?}  bound={:.6}  E[T]={:.6} ± {:.6}",
        alloc.uniform_k1, alloc.uniform_bound, uni.mean, uni.ci95
    );
    println!(
        "optimized k1={:?}  bound={:.6}  E[T]={:.6} ± {:.6}  ({} moves)",
        alloc.k1, alloc.bound, opt.mean, opt.ci95, alloc.moves
    );
    println!(
        "bound improvement: {:.2}%",
        (1.0 - alloc.bound / alloc.uniform_bound) * 100.0
    );
    Ok(())
}

fn compile_cmd(args: &Args) -> crate::Result<()> {
    use crate::config::schema::ClusterConfig;

    let path = args
        .positional
        .first()
        .map(|s| s.as_str())
        .or_else(|| args.get_str("config"))
        .ok_or_else(|| {
            crate::Error::InvalidParams(
                "compile needs a config file (positional or --config)".into(),
            )
        })?;
    let config = ClusterConfig::from_file(path)?;
    let bytes = crate::controlplane::compile(&config)?;
    // Round-trip through the decoder: what we print is what a loading
    // cluster will actually see.
    let artifact = crate::controlplane::decode(&bytes)?;
    let out = args.get_str("out").unwrap_or("scenario.hca");
    std::fs::write(out, &bytes)?;
    let m = &artifact.manifest;
    println!(
        "compiled {path} -> {out}: {} bytes, artifact v{}, compiler v{}, \
         topology digest {:#010x}, seed {}",
        bytes.len(),
        m.artifact_version,
        m.compiler_version,
        m.topology_digest,
        m.seed
    );
    Ok(())
}

fn admin_cmd(args: &Args) -> crate::Result<()> {
    use crate::controlplane::admin::{self, AdminRequest};

    let addr_str = args.get_str("connect").ok_or_else(|| {
        crate::Error::InvalidParams(
            "--connect uds:PATH|tcp:HOST:PORT is required (the cluster's \
             `serve --admin` address)"
                .into(),
        )
    })?;
    let addr = crate::transport::TransportAddr::parse(addr_str)?;
    let verb = args.positional.first().map(|s| s.as_str()).ok_or_else(|| {
        crate::Error::InvalidParams(
            "admin needs a subcommand: status|metrics|reoptimize|rollout <FILE>|rollback"
                .into(),
        )
    })?;
    match verb {
        "status" | "metrics" => {
            let req = if verb == "status" {
                AdminRequest::Status
            } else {
                AdminRequest::Metrics
            };
            let payload = admin::request(&addr, &req)?.into_payload()?;
            println!("{}", String::from_utf8_lossy(&payload));
        }
        "reoptimize" => {
            let payload = admin::request(&addr, &AdminRequest::Reoptimize)?.into_payload()?;
            let out = args.get_str("out").unwrap_or("candidate.hca");
            std::fs::write(out, &payload)?;
            let m = crate::controlplane::decode(&payload)?.manifest;
            println!(
                "candidate artifact -> {out}: {} bytes, topology digest {:#010x} \
                 (inspect, then `hiercode admin --connect {addr_str} rollout {out}`)",
                payload.len(),
                m.topology_digest
            );
        }
        "rollout" => {
            let file = args.positional.get(1).ok_or_else(|| {
                crate::Error::InvalidParams(
                    "rollout needs an artifact file (from `hiercode compile` or \
                     `admin reoptimize`)"
                        .into(),
                )
            })?;
            let bytes = std::fs::read(file)?;
            let payload = admin::request(&addr, &AdminRequest::Rollout(bytes))?.into_payload()?;
            println!(
                "rolled out {file}: generation {}",
                admin::generation_from_payload(&payload)?
            );
        }
        "rollback" => {
            let payload = admin::request(&addr, &AdminRequest::Rollback)?.into_payload()?;
            println!(
                "rolled back: generation {}",
                admin::generation_from_payload(&payload)?
            );
        }
        other => {
            return Err(crate::Error::InvalidParams(format!(
                "unknown admin subcommand '{other}' (expected status, metrics, \
                 reoptimize, rollout or rollback)"
            )))
        }
    }
    Ok(())
}

fn serve_cmd(args: &Args) -> crate::Result<()> {
    use crate::config::schema::ClusterConfig;
    use crate::coordinator::{ClusterCore, DEFAULT_MODEL};
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    let mut config = match args.get_str("config") {
        Some(path) => ClusterConfig::from_file(path)?,
        None => ClusterConfig::demo(4, 2, 4, 2),
    };
    if args.has_flag("no-pjrt") {
        config.runtime.use_pjrt = false;
    }
    if let Some(name) = args.get_str("scheme") {
        config.code.scheme = crate::coding::SchemeKind::parse(name)?;
        config.code.validate()?;
    }
    if let Some(addr) = args.get_str("transport") {
        // Fail on a malformed address here, before launch binds anything.
        crate::transport::TransportAddr::parse(addr)?;
        config.transport.mode = crate::config::schema::TransportMode::Socket;
        config.transport.listen = addr.to_string();
    }
    let requests = args.get_usize("requests")?.unwrap_or(32);
    // The demo floods its whole workload up front (open loop), so size
    // the admission queue to hold it — `loadgen` is the tool that
    // exercises Busy backpressure deliberately.
    config.serving.queue_cap = config.serving.queue_cap.max(requests);
    // Demo matrix sized to the code and the AOT'd shard shapes:
    // m = 1024, d = 128 → shard 256×128 (worker_matvec_r256_d128_*).
    let (m, d) = (1024, 128);
    let mut rng = Rng::new(config.seed);
    let a = Matrix::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0));
    // The core is launched behind an `Arc` so the optional admin server
    // can share it; `Cluster`'s single-tenant facade cannot be shared.
    let core = Arc::new(ClusterCore::launch(&config)?);
    core.register_model(DEFAULT_MODEL, &a)?;
    let client = core.handle();
    let mut admin = match args.get_str("admin") {
        Some(spec) => {
            let addr = crate::transport::TransportAddr::parse(spec)?;
            let server = crate::controlplane::AdminServer::spawn(
                addr,
                Arc::clone(&core) as Arc<dyn crate::controlplane::AdminControl>,
            )?;
            println!("admin surface on {spec} (try `hiercode admin --connect {spec} status`)");
            Some(server)
        }
        None => None,
    };
    if config.transport.mode == crate::config::schema::TransportMode::Socket {
        let wait_ms = config.transport.connect_wait_ms as u64;
        println!(
            "socket hub on {} — waiting up to {wait_ms}ms for {} node \
             process(es) (hiercode node --group G --connect {})",
            config.transport.listen,
            config.code.topology.n2(),
            config.transport.listen
        );
        if !core.wait_connected(wait_ms) {
            if let Some(server) = admin.as_mut() {
                server.stop();
            }
            return Err(crate::Error::Coordinator(format!(
                "not every node group connected within {wait_ms}ms"
            )));
        }
    }
    let shape = if config.code.topology.is_uniform_code() {
        format!(
            "({},{})x({},{})",
            config.code.n1, config.code.k1, config.code.n2, config.code.k2
        )
    } else {
        // Heterogeneous: print the real per-group specs, not the
        // group-0 uniform view.
        let groups: Vec<String> = config
            .code
            .topology
            .groups
            .iter()
            .map(|g| format!("({},{})", g.n1, g.k1))
            .collect();
        format!("groups [{}] k2={}", groups.join(" "), config.code.k2)
    };
    println!(
        "cluster up: {} on {shape}, matrix {m}x{d}, pjrt={}",
        core.scheme().name(),
        config.runtime.use_pjrt
    );
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
            client.submit(x).expect("submit")
        })
        .collect();
    let mut ok = 0;
    for h in handles {
        if h.wait().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("{ok}/{requests} requests ok in {wall:.3}s ({:.1} req/s)", requests as f64 / wall);
    println!("{}", core.metrics());
    // With an admin surface up, optionally linger so an operator can
    // drive rollouts against the live cluster after the demo workload.
    let hold_ms = args.get_usize("hold-ms")?.unwrap_or(0) as u64;
    if hold_ms > 0 {
        println!("holding cluster + admin surface for {hold_ms}ms");
        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
    }
    if let Some(server) = admin.as_mut() {
        server.stop();
    }
    drop(admin);
    drop(client);
    if let Ok(core) = Arc::try_unwrap(core) {
        core.shutdown();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn help_runs() {
        run(&sv(&["help"])).unwrap();
        run(&[]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn sim_requires_k1_k2() {
        assert!(run(&sv(&["sim"])).is_err());
        assert!(run(&sv(&["sim", "--k1", "2"])).is_err());
        run(&sv(&["sim", "--k1", "2", "--k2", "2", "--trials", "500"])).unwrap();
    }

    #[test]
    fn bounds_works() {
        run(&sv(&["bounds", "--k1", "5", "--k2", "5"])).unwrap();
    }

    #[test]
    fn figures_rejects_unknown() {
        assert!(run(&sv(&["figures", "fig9"])).is_err());
    }

    #[test]
    fn serve_native_smoke() {
        run(&sv(&["serve", "--no-pjrt", "--requests", "4"])).unwrap();
        // Malformed hub address fails before anything binds.
        assert!(run(&sv(&["serve", "--no-pjrt", "--transport", "carrier:/x"])).is_err());
    }

    #[test]
    fn allocate_smoke_and_validation() {
        // Skewed rates, explicit budget.
        run(&sv(&[
            "allocate", "--n1", "8,8,8,8", "--k2", "3", "--mu1", "1,1,1,0.05",
            "--total-k1", "16", "--trials", "2000",
        ]))
        .unwrap();
        // Recovery-fraction form with broadcast rates.
        run(&sv(&[
            "allocate", "--n1", "6,6", "--k2", "1", "--mu1", "2", "--recovery",
            "0.5", "--trials", "1000",
        ]))
        .unwrap();
        // Missing required args / contradictory forms rejected.
        assert!(run(&sv(&["allocate", "--k2", "2"])).is_err());
        assert!(run(&sv(&["allocate", "--n1", "4,4"])).is_err());
        // A budget flag is required — no silent default.
        assert!(run(&sv(&["allocate", "--n1", "4,4", "--k2", "1"])).is_err());
        assert!(run(&sv(&[
            "allocate", "--n1", "4,4", "--k2", "1", "--total-k1", "4",
            "--recovery", "0.5",
        ]))
        .is_err());
        // Rate list with the wrong length.
        assert!(run(&sv(&[
            "allocate", "--n1", "4,4", "--k2", "1", "--mu1", "1,2,3",
            "--total-k1", "4",
        ]))
        .is_err());
    }

    #[test]
    fn allocate_json_smoke() {
        run(&sv(&[
            "allocate", "--n1", "6,6", "--k2", "1", "--mu1", "2", "--recovery",
            "0.5", "--trials", "1000", "--json",
        ]))
        .unwrap();
    }

    #[test]
    fn compile_round_trips_a_config_file() {
        let dir = std::env::temp_dir().join("hiercode_compile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let config_path = dir.join("scenario.json");
        std::fs::write(
            &config_path,
            r#"{"code": {"n1": 4, "k1": 2, "n2": 3, "k2": 2}, "seed": 11}"#,
        )
        .unwrap();
        let out_path = dir.join("scenario.hca");
        run(&sv(&[
            "compile",
            config_path.to_str().unwrap(),
            "--out",
            out_path.to_str().unwrap(),
        ]))
        .unwrap();
        let bytes = std::fs::read(&out_path).unwrap();
        let artifact = crate::controlplane::decode(&bytes).unwrap();
        assert_eq!(artifact.manifest.seed, 11);
        assert_eq!(artifact.config.code.n1, 4);
        // No config at all is a usage error, not a panic.
        assert!(run(&sv(&["compile"])).is_err());
        assert!(run(&sv(&["compile", "/nonexistent/config.json"])).is_err());
    }

    #[test]
    fn admin_requires_connect_and_known_subcommand() {
        assert!(run(&sv(&["admin", "status"])).is_err());
        // A dead control socket is a typed connection error, not a hang.
        let dead = format!(
            "uds:{}",
            std::env::temp_dir()
                .join("hiercode-admin-cli-dead.sock")
                .display()
        );
        assert!(run(&sv(&["admin", "--connect", &dead, "status"])).is_err());
        assert!(run(&sv(&["admin", "--connect", &dead, "frobnicate"])).is_err());
        assert!(run(&sv(&["admin", "--connect", &dead])).is_err());
    }

    #[test]
    fn serve_admin_surface_smoke() {
        let sock = format!(
            "uds:{}",
            std::env::temp_dir()
                .join(format!("hiercode-serve-admin-{}.sock", std::process::id()))
                .display()
        );
        run(&sv(&[
            "serve", "--no-pjrt", "--requests", "2", "--admin", &sock,
        ]))
        .unwrap();
        // Malformed admin address fails before anything binds.
        assert!(run(&sv(&["serve", "--no-pjrt", "--admin", "carrier:/x"])).is_err());
    }

    #[test]
    fn serve_every_scheme_smoke() {
        for scheme in ["hierarchical", "mds", "product", "replication", "polynomial"] {
            run(&sv(&[
                "serve", "--no-pjrt", "--requests", "2", "--scheme", scheme,
            ]))
            .unwrap_or_else(|e| panic!("serve --scheme {scheme} failed: {e}"));
        }
        assert!(run(&sv(&["serve", "--no-pjrt", "--scheme", "raptor"])).is_err());
    }
}
