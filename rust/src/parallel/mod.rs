//! Dependency-free parallel execution layer for the decode hot path.
//!
//! The paper's §IV claim is that hierarchical coding "enables efficient
//! parallel decoding" — the `n2` intra-group eliminations are
//! independent, and inside each elimination the multi-RHS triangular
//! solves and the GEMM row sweeps are embarrassingly parallel over
//! disjoint output panels. [`DecodePool`] is the one primitive all of
//! those fan out through: a **scoped** work pool over [`std::thread`]
//! (no `'static` bounds, so tasks borrow the decoder's buffers
//! directly), with **deterministic result ordering** — outputs land in
//! input order no matter how the OS schedules the workers, which is
//! what makes `parallel decode == serial decode` bit-for-bit testable.
//!
//! Ownership model (see DESIGN.md §Threading model): a `DecodePool` is
//! configuration, not threads. Each parallel region spawns its workers
//! inside [`std::thread::scope`] and joins them before returning, so
//! the pool holds no OS resources, needs no shutdown protocol, and can
//! be shared freely behind an `Arc` by every scheme and decoder
//! session. Regions are short (one decode, one GEMM tile sweep, one
//! Monte-Carlo run), so spawn cost is amortized by construction: every
//! call site gates on `size() > 1` and falls back to an inline serial
//! loop when there is nothing to fan out.

use crate::sync::Mutex;
use crate::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Hard ceiling on the configured thread count: anything larger is a
/// config typo, not a machine (`decode_threads` validation rejects it).
pub const MAX_THREADS: usize = 1024;

/// The machine's available parallelism, resolved **once** per process.
/// `DecodePool::new(0)` used to re-query the OS on every call; on
/// systems where the affinity mask can change under us (cgroup resizes,
/// taskset) that made two "auto" pools disagree on width mid-run. One
/// cached resolution keeps every auto-width pool — and therefore every
/// pooled decode's panel split — consistent for the process lifetime.
fn auto_threads() -> usize {
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// A scoped work pool of a fixed logical width.
///
/// * `new(0)` resolves to the machine's available parallelism — the
///   `config.runtime.decode_threads = 0` convention.
/// * [`DecodePool::map`] distributes tasks over a work-stealing atomic
///   counter (good load balance when group decodes differ in size) and
///   returns results **in input order**, so callers are deterministic
///   at any thread count.
#[derive(Clone, Debug)]
pub struct DecodePool {
    threads: usize,
}

impl DecodePool {
    /// Build a pool of `threads` workers; `0` means "all available
    /// cores". Rejects absurd values (> [`MAX_THREADS`]).
    pub fn new(threads: usize) -> Result<Self> {
        if threads > MAX_THREADS {
            return Err(Error::InvalidParams(format!(
                "decode_threads {threads} exceeds the {MAX_THREADS} ceiling \
                 (use 0 for all available cores)"
            )));
        }
        let threads = if threads == 0 {
            auto_threads()
        } else {
            threads
        };
        Ok(Self { threads })
    }

    /// The serial pool: every `map` runs inline on the caller's thread.
    /// This is the default for all schemes, so nothing pays for
    /// parallelism it did not ask for.
    pub fn serial() -> Self {
        Self { threads: 1 }
    }

    /// Logical width of the pool.
    pub fn size(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item, fanning across up to `size()` scoped
    /// threads, and return the results **in input order**.
    ///
    /// Tasks are claimed from an atomic counter (work stealing), so
    /// uneven task costs still balance; each result is slotted by its
    /// input index, so the output is deterministic regardless of
    /// scheduling. Items and the closure may borrow caller state — no
    /// `'static` bound — which is what lets decoder sessions fan out
    /// over their own scratch without cloning inputs.
    ///
    /// A panic in `f` propagates to the caller once all workers have
    /// been joined (the guarantee [`std::thread::scope`] provides).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            return items.into_iter().map(f).collect();
        }
        // Each item is handed out exactly once via its own mutex slot;
        // the atomic counter is the work queue.
        let slots: Vec<Mutex<Option<T>>> =
            items.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let next = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
        std::thread::scope(|s| {
            for w in 0..workers {
                // Captures only shared references, so the closure is
                // `Copy` — the named-spawn attempt below can consume a
                // copy and still fall back to an anonymous spawn.
                let work = || {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // Invariant: the atomic counter hands each
                        // index out once, so the slot is still full
                        // (allowlisted; masking a double-claim would
                        // silently drop a task).
                        let item = slots[i].lock().take().expect("item claimed twice");
                        local.push((i, f(item)));
                    }
                    done.lock().extend(local);
                };
                // Named threads so profiles and thread dumps attribute
                // decode time to the pool instead of `<unnamed>`.
                if std::thread::Builder::new()
                    .name(format!("hc-decode-{w}"))
                    .spawn_scoped(s, work)
                    .is_err()
                {
                    s.spawn(work);
                }
            }
        });
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        for (i, r) in done.into_inner() {
            out[i] = Some(r);
        }
        // Invariant: every index < n was claimed exactly once and its
        // worker pushed a result before exiting (allowlisted; a hole
        // here is a lost task, not a recoverable condition).
        out.into_iter()
            .map(|r| r.expect("every task produces a result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_resolves_to_available_parallelism() {
        let p = DecodePool::new(0).unwrap();
        assert!(p.size() >= 1);
    }

    #[test]
    fn auto_width_is_resolved_once_and_stable() {
        // Repeated auto pools must agree: the width is resolved once
        // per process, not re-queried from the OS per construction.
        let first = DecodePool::new(0).unwrap().size();
        for _ in 0..8 {
            assert_eq!(DecodePool::new(0).unwrap().size(), first);
        }
        // Explicit widths are untouched by the cache.
        assert_eq!(DecodePool::new(3).unwrap().size(), 3);
    }

    #[test]
    fn pool_threads_are_named() {
        let pool = DecodePool::new(2).unwrap();
        let names = pool.map(vec![(), ()], |()| {
            // Hold both workers briefly so each claims one task and we
            // observe two distinct pool threads, not one fast worker.
            std::thread::sleep(std::time::Duration::from_millis(20));
            std::thread::current().name().map(str::to_string)
        });
        for name in names {
            let name = name.unwrap_or_default();
            assert!(
                name.starts_with("hc-decode-"),
                "pool thread named {name:?}"
            );
        }
    }

    #[test]
    fn absurd_thread_count_rejected() {
        assert!(DecodePool::new(MAX_THREADS + 1).is_err());
        assert!(DecodePool::new(MAX_THREADS).is_ok());
    }

    #[test]
    fn map_preserves_input_order() {
        for threads in [1, 2, 3, 8] {
            let pool = DecodePool::new(threads).unwrap();
            let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * x);
            assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_empty_and_single() {
        let pool = DecodePool::new(4).unwrap();
        assert!(pool.map(Vec::<usize>::new(), |x| x).is_empty());
        assert_eq!(pool.map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn tasks_may_borrow_caller_state() {
        // The scoped pool's whole point: no 'static bound.
        let data = vec![1.0f64; 64];
        let pool = DecodePool::new(4).unwrap();
        let sums = pool.map(
            data.chunks(16).collect::<Vec<&[f64]>>(),
            |c| c.iter().sum::<f64>(),
        );
        assert_eq!(sums, vec![16.0; 4]);
    }

    #[test]
    fn tasks_may_mutate_disjoint_chunks() {
        let mut data = vec![0.0f64; 32];
        let pool = DecodePool::new(4).unwrap();
        let tasks: Vec<(usize, &mut [f64])> =
            data.chunks_mut(8).enumerate().collect();
        pool.map(tasks, |(i, chunk)| {
            for v in chunk.iter_mut() {
                *v = i as f64;
            }
        });
        for (i, c) in data.chunks(8).enumerate() {
            assert!(c.iter().all(|&v| v == i as f64));
        }
    }

    #[test]
    fn uneven_task_costs_balance() {
        let pool = DecodePool::new(4).unwrap();
        let out = pool.map((0..40usize).collect(), |i| {
            // Task cost varies by ~100x; result must still be ordered.
            let mut acc = 0u64;
            for j in 0..(i * 100 + 1) {
                acc = acc.wrapping_add(j as u64);
            }
            (i, acc)
        });
        for (i, (j, _)) in out.iter().enumerate() {
            assert_eq!(i, *j);
        }
    }
}
