//! Rollout compatibility gate: decides whether a candidate config can
//! hot-swap into a running cluster, and how invasive the swap is.
//!
//! A rollout is **atomic**: either the candidate passes this gate and
//! the whole swap applies, or it is rejected with
//! [`crate::Error::Incompatible`] and nothing changes. The gate is
//! deliberately conservative — anything that would change the *shape*
//! of the running tree (scheme, group count, per-group worker counts,
//! straggler/chaos/transport/runtime sections, seed) is rejected,
//! because the spawned threads/processes and their delay schedules
//! cannot be rebuilt without a restart.
//!
//! What remains is classified into two tiers:
//!
//! - [`RolloutKind::Light`] — model table, serving limits
//!   (`queue_cap`, `default_deadline_ms`, `drain_ms`) and batching
//!   knobs. Applied live without quiescing: admission caps and
//!   deadlines are atomics, and model registration already ships
//!   shards to idle workers between jobs.
//! - [`RolloutKind::Heavy`] — a changed per-group `k1_g` plan (the
//!   allocator's output). Every registered model must be re-encoded
//!   under the new inner code and every worker's shard replaced, which
//!   requires draining in-flight jobs first (mixed-encoding partials
//!   would decode garbage). The cluster layer runs the quiesce → cut
//!   over → resume sequence.
//!
//! This module is pure (config in, verdict out) so the gate is
//! unit-testable without a cluster and usable by `hiercode compile`
//! tooling to pre-check a candidate against a running config.

use crate::config::schema::ClusterConfig;
use crate::{Error, Result};

/// How invasive a compatible rollout is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RolloutKind {
    /// Model table / serving limits / batching only: applied live,
    /// no drain required.
    Light,
    /// The per-group `k1_g` plan changed: every model re-encodes and
    /// every shard re-ships, so in-flight jobs must drain first.
    Heavy,
}

/// One named compatibility check; returns the offending field on
/// mismatch so the error names what to fix.
fn require(ok: bool, what: &str) -> Result<()> {
    if ok {
        Ok(())
    } else {
        Err(Error::Incompatible(format!("candidate changes {what}")))
    }
}

/// Gate a candidate config against the running one. `Ok(kind)` means
/// the swap may proceed (light or heavy); `Err(Incompatible)` names the
/// first field that cannot change without a restart.
pub fn classify(current: &ClusterConfig, candidate: &ClusterConfig) -> Result<RolloutKind> {
    require(current.code.scheme == candidate.code.scheme, "code.scheme")?;
    require(
        current.code.topology.k2 == candidate.code.topology.k2,
        "code.k2",
    )?;
    require(
        current.code.topology.groups.len() == candidate.code.topology.groups.len(),
        "the number of groups",
    )?;
    for (g, (a, b)) in current
        .code
        .topology
        .groups
        .iter()
        .zip(&candidate.code.topology.groups)
        .enumerate()
    {
        require(a.n1 == b.n1, &format!("groups[{g}].n1 (worker count)"))?;
        require(a.subtasks == b.subtasks, &format!("groups[{g}].subtasks"))?;
        require(a.worker == b.worker, &format!("groups[{g}] worker profile"))?;
        require(a.link == b.link, &format!("groups[{g}] link profile"))?;
        require(a.scale == b.scale, &format!("groups[{g}].scale"))?;
        require(
            a.dead_workers == b.dead_workers,
            &format!("groups[{g}].dead_workers"),
        )?;
    }
    require(current.straggler == candidate.straggler, "the straggler section")?;
    require(current.runtime == candidate.runtime, "the runtime section")?;
    require(current.chaos == candidate.chaos, "the chaos section")?;
    require(current.transport == candidate.transport, "the transport section")?;
    require(current.seed == candidate.seed, "the seed")?;

    let k1_changed = current
        .code
        .topology
        .groups
        .iter()
        .zip(&candidate.code.topology.groups)
        .any(|(a, b)| a.k1 != b.k1);
    Ok(if k1_changed {
        RolloutKind::Heavy
    } else {
        RolloutKind::Light
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ModelSpec;

    fn base() -> ClusterConfig {
        ClusterConfig::demo(4, 2, 3, 2)
    }

    #[test]
    fn identical_configs_are_a_light_rollout() {
        assert_eq!(classify(&base(), &base()).unwrap(), RolloutKind::Light);
    }

    #[test]
    fn model_and_serving_changes_stay_light() {
        let mut cand = base();
        cand.serving.queue_cap = 128;
        cand.serving.default_deadline_ms = 500.0;
        cand.batching.max_batch = 2;
        cand.batching.max_wait_ms = 1.0;
        cand.serving.models.push(ModelSpec {
            name: "fresh".into(),
            rows: 12,
            cols: 4,
            seed: 7,
        });
        assert_eq!(classify(&base(), &cand).unwrap(), RolloutKind::Light);
    }

    #[test]
    fn k1_plan_change_is_heavy() {
        let mut cand = base();
        cand.code.topology.groups[0].k1 = 3;
        cand.code.topology.groups[1].k1 = 1;
        cand.code.k1 = 3;
        assert_eq!(classify(&base(), &cand).unwrap(), RolloutKind::Heavy);
    }

    #[test]
    fn shape_changes_are_rejected_with_the_field_named() {
        let cases: Vec<(&str, Box<dyn Fn(&mut ClusterConfig)>)> = vec![
            ("scheme", Box::new(|c| c.code.scheme = crate::coding::SchemeKind::Mds)),
            ("k2", Box::new(|c| c.code.topology.k2 = 1)),
            ("n1", Box::new(|c| c.code.topology.groups[0].n1 = 5)),
            ("groups", Box::new(|c| {
                let g = c.code.topology.groups[0].clone();
                c.code.topology.groups.push(g);
            })),
            ("seed", Box::new(|c| c.seed = 7)),
            ("runtime", Box::new(|c| c.runtime.decode_threads = 1)),
            ("chaos", Box::new(|c| c.chaos.liveness = !c.chaos.liveness)),
            ("straggler", Box::new(|c| c.straggler.scale *= 2.0)),
            ("transport", Box::new(|c| {
                c.transport.connect_wait_ms += 1.0;
            })),
            ("subtasks", Box::new(|c| c.code.topology.groups[0].subtasks = 2)),
        ];
        for (what, mutate) in cases {
            let mut cand = base();
            mutate(&mut cand);
            let err = classify(&base(), &cand).unwrap_err();
            assert!(
                matches!(err, Error::Incompatible(_)),
                "{what}: expected Incompatible, got {err:?}"
            );
            assert!(
                format!("{err}").contains("nothing applied"),
                "{what}: error must promise atomicity"
            );
        }
    }

    #[test]
    fn gate_is_symmetric_for_light_and_detects_either_direction() {
        let mut cand = base();
        cand.serving.queue_cap += 1;
        assert_eq!(classify(&base(), &cand).unwrap(), RolloutKind::Light);
        assert_eq!(classify(&cand, &base()).unwrap(), RolloutKind::Light);
    }
}
