//! The admin surface: a framed request/response protocol on a
//! dedicated control socket (UDS or TCP), never the data lanes.
//!
//! `hiercode admin status|metrics|reoptimize|rollout <artifact>|
//! rollback` connects here. The protocol reuses the wire conventions of
//! `transport::wire` — a 16-byte header (magic, version, kind, length,
//! CRC-32) followed by the payload — but with its own magic (`"hct1"`)
//! so a control frame can never be confused with a data frame, and its
//! own request/response kinds. One connection carries exactly one
//! request and one response; the client dials per command, which keeps
//! the server loop trivially serial and free of per-connection state.
//!
//! The server is transport-agnostic behind [`AdminControl`]: the
//! cluster implements the trait, the server owns only framing and the
//! accept loop. Everything here is panic-free (this module is in the
//! `no_panic` lint scope): malformed frames, oversized payloads and
//! checksum mismatches surface as typed errors on the offending
//! connection and never take the server down.

use crate::transport::wire::{self, Reader};
use crate::transport::{Listener, Stream, TransportAddr};
use crate::util::manifest::crc32;
use crate::{Error, Result};
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Control-frame magic: `"hct1"` as a little-endian u32 — distinct from
/// both the data wire (`"hcw1"`) and the artifact file (`"hca1"`).
pub const MAGIC: u32 = u32::from_le_bytes(*b"hct1");
/// Admin protocol version; version skew is rejected explicitly.
pub const VERSION: u16 = 1;
/// Frame header size in bytes.
pub const HEADER_LEN: usize = 16;
/// Maximum accepted payload (shared with the data wire: an artifact is
/// the largest thing that ever crosses this socket).
pub const MAX_PAYLOAD: usize = wire::MAX_PAYLOAD;
/// Per-connection read guard: an admin peer that stalls longer than
/// this mid-frame is dropped so the serial accept loop stays live.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Request kinds (client → server).
const REQ_STATUS: u8 = 0;
const REQ_METRICS: u8 = 1;
const REQ_REOPTIMIZE: u8 = 2;
const REQ_ROLLOUT: u8 = 3;
const REQ_ROLLBACK: u8 = 4;
/// Response kinds (server → client).
const RESP_OK: u8 = 0x80;
const RESP_ERR: u8 = 0x81;

/// One admin request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdminRequest {
    /// Cluster identity + generation summary (JSON text reply).
    Status,
    /// Full metrics snapshot (JSON text reply).
    Metrics,
    /// Run the allocator against the live topology; the reply payload
    /// is a candidate `.hca` artifact (not applied).
    Reoptimize,
    /// Hot-swap to the carried artifact bytes; the reply payload is the
    /// new generation (little-endian u64).
    Rollout(Vec<u8>),
    /// Restore the previous generation; the reply payload is the
    /// restored generation (little-endian u64).
    Rollback,
}

/// One admin response: the request-specific payload, or a typed
/// failure message (the server never closes the connection without
/// answering a well-formed request).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdminResponse {
    /// Success; payload meaning depends on the request.
    Ok(Vec<u8>),
    /// Failure, with the server-side error rendered as text.
    Err(String),
}

impl AdminResponse {
    /// Unwrap into the success payload or a typed coordinator error.
    pub fn into_payload(self) -> Result<Vec<u8>> {
        match self {
            Self::Ok(p) => Ok(p),
            Self::Err(m) => Err(Error::Coordinator(format!("admin request failed: {m}"))),
        }
    }
}

/// What the admin server needs from a running cluster. `ClusterCore`
/// implements this; tests substitute mocks.
pub trait AdminControl: Send + Sync {
    /// Identity + generation summary as a JSON document.
    fn status_json(&self) -> String;
    /// Full metrics snapshot as a JSON document.
    fn metrics_json(&self) -> String;
    /// Run the allocator against live liveness/latency; returns a
    /// candidate artifact (compiled, not applied).
    fn reoptimize(&self) -> Result<Vec<u8>>;
    /// Hot-swap to the given artifact; returns the new generation.
    fn rollout(&self, artifact: &[u8]) -> Result<u64>;
    /// Restore the previous generation; returns the restored one.
    fn rollback(&self) -> Result<u64>;
}

/// Serialize one frame (either direction).
fn encode_frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind);
    out.push(0); // reserved
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Write one frame to a stream.
fn write_frame(stream: &mut Stream, kind: u8, payload: &[u8]) -> Result<()> {
    stream.write_all(&encode_frame(kind, payload))?;
    stream.flush()?;
    Ok(())
}

/// Read one frame, verifying magic, version, size cap and checksum.
fn read_frame(stream: &mut Stream) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; HEADER_LEN];
    stream.read_exact(&mut header)?;
    let magic = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    if magic != MAGIC {
        return Err(Error::Coordinator("admin frame: bad magic".into()));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        return Err(Error::Coordinator(format!(
            "admin frame: version {version} (this build speaks {VERSION})"
        )));
    }
    let kind = header[6];
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(Error::Coordinator(format!(
            "admin frame: payload length {len} exceeds the {MAX_PAYLOAD}-byte cap"
        )));
    }
    let crc = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    if crc32(&payload) != crc {
        return Err(Error::Coordinator("admin frame: checksum mismatch".into()));
    }
    Ok((kind, payload))
}

/// Decode a request frame.
fn decode_request(kind: u8, payload: Vec<u8>) -> Result<AdminRequest> {
    let req = match kind {
        REQ_STATUS => AdminRequest::Status,
        REQ_METRICS => AdminRequest::Metrics,
        REQ_REOPTIMIZE => AdminRequest::Reoptimize,
        REQ_ROLLOUT => return Ok(AdminRequest::Rollout(payload)),
        REQ_ROLLBACK => AdminRequest::Rollback,
        other => {
            return Err(Error::Coordinator(format!(
                "admin frame: unknown request kind {other}"
            )))
        }
    };
    if !payload.is_empty() {
        return Err(Error::Coordinator(
            "admin frame: unexpected payload on a bare request".into(),
        ));
    }
    Ok(req)
}

/// Dispatch one decoded request against the control implementation.
fn dispatch(ctrl: &dyn AdminControl, req: AdminRequest) -> AdminResponse {
    let result: Result<Vec<u8>> = match req {
        AdminRequest::Status => Ok(ctrl.status_json().into_bytes()),
        AdminRequest::Metrics => Ok(ctrl.metrics_json().into_bytes()),
        AdminRequest::Reoptimize => ctrl.reoptimize(),
        AdminRequest::Rollout(bytes) => {
            ctrl.rollout(&bytes).map(|g| g.to_le_bytes().to_vec())
        }
        AdminRequest::Rollback => ctrl.rollback().map(|g| g.to_le_bytes().to_vec()),
    };
    match result {
        Ok(payload) => AdminResponse::Ok(payload),
        Err(e) => AdminResponse::Err(format!("{e}")),
    }
}

/// The admin server: one accept loop on a dedicated control socket,
/// one request per connection, handled serially (an admin surface has
/// no concurrency requirements, and serial handling means a rollout
/// can never race another rollout at the framing layer).
pub struct AdminServer {
    addr: TransportAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl AdminServer {
    /// Bind `addr` and serve until [`AdminServer::stop`].
    pub fn spawn(addr: TransportAddr, ctrl: Arc<dyn AdminControl>) -> Result<AdminServer> {
        let listener = Listener::bind(&addr).map_err(|e| {
            Error::Coordinator(format!("admin server: cannot bind {addr}: {e}"))
        })?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("hiercode-admin".into())
            .spawn(move || {
                while let Ok(mut stream) = listener.accept() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    // A stalled or malformed peer only loses its own
                    // connection; the loop serves the next one.
                    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
                    let _ = serve_one(&mut stream, ctrl.as_ref());
                }
            })
            .map_err(|e| Error::Coordinator(format!("admin server: spawn failed: {e}")))?;
        Ok(AdminServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound control address.
    pub fn addr(&self) -> &TransportAddr {
        &self.addr
    }

    /// Stop the accept loop and join the server thread. Idempotent.
    pub fn stop(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the accept with a dummy dial; if the dial fails
            // the listener is already gone and the join returns anyway.
            if let Ok(s) = Stream::connect(&self.addr) {
                s.shutdown();
            }
            let _ = handle.join();
        }
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve exactly one request on an accepted connection.
fn serve_one(stream: &mut Stream, ctrl: &dyn AdminControl) -> Result<()> {
    let (kind, payload) = read_frame(stream)?;
    let resp = match decode_request(kind, payload) {
        Ok(req) => dispatch(ctrl, req),
        Err(e) => AdminResponse::Err(format!("{e}")),
    };
    match resp {
        AdminResponse::Ok(p) => write_frame(stream, RESP_OK, &p),
        AdminResponse::Err(m) => write_frame(stream, RESP_ERR, m.as_bytes()),
    }
}

/// Client side: dial, send one request, read the response.
pub fn request(addr: &TransportAddr, req: &AdminRequest) -> Result<AdminResponse> {
    let mut stream = Stream::connect(addr).map_err(|e| {
        Error::Coordinator(format!("admin client: cannot connect {addr}: {e}"))
    })?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let (kind, payload): (u8, &[u8]) = match req {
        AdminRequest::Status => (REQ_STATUS, &[]),
        AdminRequest::Metrics => (REQ_METRICS, &[]),
        AdminRequest::Reoptimize => (REQ_REOPTIMIZE, &[]),
        AdminRequest::Rollout(bytes) => (REQ_ROLLOUT, bytes),
        AdminRequest::Rollback => (REQ_ROLLBACK, &[]),
    };
    write_frame(&mut stream, kind, payload)?;
    let (kind, payload) = read_frame(&mut stream)?;
    match kind {
        RESP_OK => Ok(AdminResponse::Ok(payload)),
        RESP_ERR => Ok(AdminResponse::Err(
            String::from_utf8_lossy(&payload).into_owned(),
        )),
        other => Err(Error::Coordinator(format!(
            "admin client: unknown response kind {other}"
        ))),
    }
}

/// Decode a generation reply (`rollout` / `rollback` success payload).
pub fn generation_from_payload(payload: &[u8]) -> Result<u64> {
    let mut r = Reader {
        buf: payload,
        pos: 0,
    };
    let generation = r
        .u64()
        .map_err(|_| Error::Coordinator("admin client: short generation reply".into()))?;
    if r.pos != payload.len() {
        return Err(Error::Coordinator(
            "admin client: trailing bytes in generation reply".into(),
        ));
    }
    Ok(generation)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    struct MockControl {
        rollouts: AtomicU64,
        rollbacks: AtomicU64,
    }

    impl MockControl {
        fn new() -> Self {
            Self {
                rollouts: AtomicU64::new(0),
                rollbacks: AtomicU64::new(0),
            }
        }
    }

    impl AdminControl for MockControl {
        fn status_json(&self) -> String {
            "{\"generation\": 1}".into()
        }
        fn metrics_json(&self) -> String {
            "{\"jobs\": 0}".into()
        }
        fn reoptimize(&self) -> Result<Vec<u8>> {
            Ok(vec![1, 2, 3])
        }
        fn rollout(&self, artifact: &[u8]) -> Result<u64> {
            if artifact.is_empty() {
                return Err(Error::Incompatible("empty artifact".into()));
            }
            Ok(2 + self.rollouts.fetch_add(1, Ordering::SeqCst))
        }
        fn rollback(&self) -> Result<u64> {
            self.rollbacks.fetch_add(1, Ordering::SeqCst);
            Ok(1)
        }
    }

    fn fresh_addr(tag: &str) -> TransportAddr {
        let path = std::env::temp_dir().join(format!(
            "hiercode-admin-{tag}-{}.sock",
            std::process::id()
        ));
        TransportAddr::Uds(path)
    }

    #[test]
    fn round_trips_every_request_kind() {
        let ctrl = Arc::new(MockControl::new());
        let mut server = AdminServer::spawn(fresh_addr("rt"), Arc::clone(&ctrl) as _).unwrap();
        let addr = server.addr().clone();

        let status = request(&addr, &AdminRequest::Status).unwrap().into_payload().unwrap();
        assert_eq!(String::from_utf8(status).unwrap(), "{\"generation\": 1}");
        let metrics = request(&addr, &AdminRequest::Metrics).unwrap().into_payload().unwrap();
        assert_eq!(String::from_utf8(metrics).unwrap(), "{\"jobs\": 0}");
        let cand = request(&addr, &AdminRequest::Reoptimize).unwrap().into_payload().unwrap();
        assert_eq!(cand, vec![1, 2, 3]);
        let gen = request(&addr, &AdminRequest::Rollout(vec![9; 8]))
            .unwrap()
            .into_payload()
            .unwrap();
        assert_eq!(generation_from_payload(&gen).unwrap(), 2);
        let gen = request(&addr, &AdminRequest::Rollback).unwrap().into_payload().unwrap();
        assert_eq!(generation_from_payload(&gen).unwrap(), 1);
        assert_eq!(ctrl.rollouts.load(Ordering::SeqCst), 1);
        assert_eq!(ctrl.rollbacks.load(Ordering::SeqCst), 1);
        server.stop();
    }

    #[test]
    fn server_side_errors_come_back_typed_not_as_hangs() {
        let ctrl = Arc::new(MockControl::new());
        let mut server = AdminServer::spawn(fresh_addr("err"), ctrl as _).unwrap();
        let addr = server.addr().clone();
        let resp = request(&addr, &AdminRequest::Rollout(Vec::new())).unwrap();
        let err = resp.into_payload().unwrap_err();
        assert!(format!("{err}").contains("incompatible"), "got {err}");
        // The server survives a failed request and serves the next one.
        assert!(request(&addr, &AdminRequest::Status).is_ok());
        server.stop();
    }

    #[test]
    fn malformed_frames_lose_only_their_connection() {
        let ctrl = Arc::new(MockControl::new());
        let mut server = AdminServer::spawn(fresh_addr("bad"), ctrl as _).unwrap();
        let addr = server.addr().clone();
        // Garbage bytes: the server drops the connection without reply.
        let mut s = Stream::connect(&addr).unwrap();
        s.write_all(b"not a control frame at all....").unwrap();
        s.flush().unwrap();
        s.shutdown();
        // A correct client still gets served afterwards.
        assert!(request(&addr, &AdminRequest::Status).is_ok());
        // Unknown request kind gets a typed error reply.
        let mut s = Stream::connect(&addr).unwrap();
        s.write_all(&encode_frame(0x42, &[])).unwrap();
        s.flush().unwrap();
        let (kind, payload) = read_frame(&mut s).unwrap();
        assert_eq!(kind, RESP_ERR);
        assert!(String::from_utf8_lossy(&payload).contains("unknown request kind"));
        server.stop();
    }

    #[test]
    fn stop_is_idempotent_and_joins() {
        let ctrl = Arc::new(MockControl::new());
        let mut server = AdminServer::spawn(fresh_addr("stop"), ctrl as _).unwrap();
        server.stop();
        server.stop();
    }

    #[test]
    fn generation_payload_rejects_malformed_replies() {
        assert!(generation_from_payload(&[1, 2, 3]).is_err());
        assert!(generation_from_payload(&[0; 9]).is_err());
        assert_eq!(generation_from_payload(&7u64.to_le_bytes()).unwrap(), 7);
    }
}
