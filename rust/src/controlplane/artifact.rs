//! The compiled scenario artifact: `hiercode compile` turns a
//! validated [`ClusterConfig`] into a versioned, CRC32-checksummed
//! binary (`.hca`) that *is* the runtime configuration — "your spec is
//! your gateway". Loading is a pure integrity + compatibility check:
//! all semantic validation happened at compile time.
//!
//! # Format
//!
//! ```text
//! offset  size  field
//!      0     4  magic             "hca1" (little-endian u32)
//!      4     2  artifact version  (little-endian u16)
//!      6     2  compiler version  (little-endian u16)
//!      8     4  payload len       (little-endian u32)
//!     12     4  payload crc       CRC-32 (IEEE) of the payload
//!     16   len  payload           sections, in ascending kind order
//! ```
//!
//! The payload is a sequence of sections, each framed as
//! `kind: u8, len: u32, crc: u32, bytes` — the same conventions as the
//! socket wire format (`transport::wire`): little-endian fixed-width
//! integers, length-prefixed UTF-8 strings, floats as IEEE-754 bit
//! patterns (`f64::to_bits`), so a decoded artifact re-serializes
//! **bit-identically**. Section 0 is the manifest: topology digest,
//! seed, and a `(kind, crc)` table covering every following section,
//! so per-section integrity is checked twice (section header and
//! manifest) and a spliced artifact cannot pass.
//!
//! Every malformed input surfaces a typed [`ArtifactError`] — never a
//! panic: this codec is in the `no_panic` lint scope, and the
//! rejection tests in `tests/control_plane.rs` drive corruption,
//! truncation and version skew through it.

use crate::coding::SchemeKind;
use crate::config::schema::{
    BatchConfig, ChaosConfig, ClusterConfig, CodeConfig, ModelSpec, RuntimeConfig,
    ServingConfig, StragglerConfig, TransportConfig, TransportMode,
};
use crate::scenario::{GroupSpec, Topology};
use crate::sim::straggler::StragglerModel;
use crate::transport::wire::{self, Reader, WireError};
use crate::util::manifest::crc32;

/// Artifact magic: `"hca1"` as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"hca1");
/// Artifact format version. Bumped on any layout change; load rejects
/// skew explicitly.
pub const ARTIFACT_VERSION: u16 = 1;
/// Compiler version, recorded in the header for provenance (newer
/// compilers emitting the same artifact version stay loadable).
pub const COMPILER_VERSION: u16 = 1;
/// Header size in bytes.
pub const HEADER_LEN: usize = 16;
/// Maximum accepted payload — shared with the wire format.
pub const MAX_PAYLOAD: usize = wire::MAX_PAYLOAD;

/// Section discriminants, in payload order.
const SEC_MANIFEST: u8 = 0;
const SEC_CODE: u8 = 1;
const SEC_STRAGGLER: u8 = 2;
const SEC_RUNTIME: u8 = 3;
const SEC_BATCHING: u8 = 4;
const SEC_SERVING: u8 = 5;
const SEC_CHAOS: u8 = 6;
const SEC_TRANSPORT: u8 = 7;
/// Every non-manifest section, in the order they are emitted.
const SECTIONS: [u8; 7] = [
    SEC_CODE,
    SEC_STRAGGLER,
    SEC_RUNTIME,
    SEC_BATCHING,
    SEC_SERVING,
    SEC_CHAOS,
    SEC_TRANSPORT,
];

fn section_name(kind: u8) -> &'static str {
    match kind {
        SEC_MANIFEST => "manifest",
        SEC_CODE => "code",
        SEC_STRAGGLER => "straggler",
        SEC_RUNTIME => "runtime",
        SEC_BATCHING => "batching",
        SEC_SERVING => "serving",
        SEC_CHAOS => "chaos",
        SEC_TRANSPORT => "transport",
        _ => "unknown",
    }
}

/// Typed artifact failure. Every variant is a distinct, observable way
/// an artifact can be wrong; none of them panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactError {
    /// Fewer bytes than the header or a declared length.
    Truncated,
    /// The first four bytes are not the artifact magic.
    BadMagic,
    /// The artifact was written by a different format version.
    BadVersion {
        /// Version in the artifact header.
        got: u16,
        /// Version this build speaks.
        want: u16,
    },
    /// A checksum mismatch, naming the section (or "payload").
    BadChecksum(&'static str),
    /// Unknown, duplicate or out-of-order section discriminant.
    BadSection(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversize(usize),
    /// Structurally invalid payload (bad UTF-8, bad tags, trailing
    /// bytes).
    Malformed(&'static str),
    /// The decoded config fails semantic validation — a hand-crafted
    /// artifact that never went through `compile`.
    Invalid(String),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated => write!(f, "truncated artifact"),
            Self::BadMagic => write!(f, "bad artifact magic (not a .hca file)"),
            Self::BadVersion { got, want } => {
                write!(f, "artifact version {got} (this build speaks {want})")
            }
            Self::BadChecksum(what) => write!(f, "{what}: checksum mismatch"),
            Self::BadSection(k) => write!(f, "bad section discriminant {k}"),
            Self::Oversize(n) => {
                write!(f, "payload length {n} exceeds the {MAX_PAYLOAD}-byte cap")
            }
            Self::Malformed(why) => write!(f, "malformed artifact: {why}"),
            Self::Invalid(why) => write!(f, "invalid compiled config: {why}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

impl From<ArtifactError> for crate::Error {
    fn from(e: ArtifactError) -> Self {
        crate::Error::Config(format!("scenario artifact: {e}"))
    }
}

impl From<WireError> for ArtifactError {
    fn from(e: WireError) -> Self {
        match e {
            WireError::Truncated => Self::Truncated,
            WireError::Malformed(why) => Self::Malformed(why),
            // The remaining wire variants concern frame headers, which
            // the artifact codec parses itself; a Reader can only
            // surface the two above.
            _ => Self::Malformed("unexpected wire-level failure"),
        }
    }
}

/// The manifest section: provenance and integrity metadata.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScenarioManifest {
    /// Artifact format version (from the header).
    pub artifact_version: u16,
    /// Compiler version that emitted the artifact (from the header).
    pub compiler_version: u16,
    /// Digest of the compatibility-relevant topology shape: scheme,
    /// `k2`, per-group `(n1, k1, subtasks)`. Two artifacts with equal
    /// digests are swap-compatible at the group-structure level.
    pub topology_digest: u32,
    /// The scenario seed (also the transport cluster id).
    pub seed: u64,
    /// Per-section `(kind, crc32)` table for every following section.
    pub section_crcs: Vec<(u8, u32)>,
}

/// A loaded, integrity-checked scenario artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioArtifact {
    /// Provenance + integrity metadata.
    pub manifest: ScenarioManifest,
    /// The full compiled configuration.
    pub config: ClusterConfig,
}

impl ScenarioArtifact {
    /// Load and decode an artifact file.
    pub fn load(path: &str) -> crate::Result<Self> {
        let bytes = std::fs::read(path).map_err(|e| {
            crate::Error::Config(format!("cannot read artifact {path}: {e}"))
        })?;
        Ok(decode(&bytes)?)
    }
}

/// Digest of the compatibility-relevant topology shape (see
/// [`ScenarioManifest::topology_digest`]).
pub fn topology_digest(scheme: SchemeKind, topology: &Topology) -> u32 {
    let mut buf = Vec::new();
    buf.push(scheme_tag(scheme));
    wire::put_u32(&mut buf, topology.k2 as u32);
    wire::put_u32(&mut buf, topology.groups.len() as u32);
    for g in &topology.groups {
        wire::put_u32(&mut buf, g.n1 as u32);
        wire::put_u32(&mut buf, g.k1 as u32);
        wire::put_u32(&mut buf, g.subtasks as u32);
    }
    crc32(&buf)
}

/// Compile a validated config into artifact bytes. All semantic
/// validation happens here — loading the result is a pure integrity
/// check. Compilation is deterministic: the same config always
/// produces the same bytes, and `decode` → `compile` is bit-identical.
pub fn compile(config: &ClusterConfig) -> crate::Result<Vec<u8>> {
    config.code.validate()?;
    let digest = topology_digest(config.code.scheme, &config.code.topology);

    let bodies: Vec<(u8, Vec<u8>)> = vec![
        (SEC_CODE, encode_code(&config.code)),
        (SEC_STRAGGLER, encode_straggler(&config.straggler)),
        (SEC_RUNTIME, encode_runtime(&config.runtime)),
        (SEC_BATCHING, encode_batching(&config.batching)),
        (SEC_SERVING, encode_serving(&config.serving)),
        (SEC_CHAOS, encode_chaos(&config.chaos)),
        (SEC_TRANSPORT, encode_transport(&config.transport)),
    ];

    // Manifest first: digest, seed, and the (kind, crc) table.
    let mut manifest = Vec::new();
    wire::put_u32(&mut manifest, digest);
    wire::put_u64(&mut manifest, config.seed);
    manifest.push(bodies.len() as u8);
    for (kind, body) in &bodies {
        manifest.push(*kind);
        wire::put_u32(&mut manifest, crc32(body));
    }

    let mut payload = Vec::new();
    push_section(&mut payload, SEC_MANIFEST, &manifest);
    for (kind, body) in &bodies {
        push_section(&mut payload, *kind, body);
    }

    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&ARTIFACT_VERSION.to_le_bytes());
    out.extend_from_slice(&COMPILER_VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Decode artifact bytes: integrity (magic, version, payload and
/// per-section checksums, manifest cross-check) plus a final semantic
/// guard for hand-crafted inputs.
pub fn decode(bytes: &[u8]) -> Result<ScenarioArtifact, ArtifactError> {
    let header = bytes.get(..HEADER_LEN).ok_or(ArtifactError::Truncated)?;
    let magic = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    if magic != MAGIC {
        return Err(ArtifactError::BadMagic);
    }
    let artifact_version = u16::from_le_bytes([header[4], header[5]]);
    if artifact_version != ARTIFACT_VERSION {
        return Err(ArtifactError::BadVersion {
            got: artifact_version,
            want: ARTIFACT_VERSION,
        });
    }
    let compiler_version = u16::from_le_bytes([header[6], header[7]]);
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(ArtifactError::Oversize(len));
    }
    let payload = bytes
        .get(HEADER_LEN..HEADER_LEN + len)
        .ok_or(ArtifactError::Truncated)?;
    if bytes.len() != HEADER_LEN + len {
        return Err(ArtifactError::Malformed("trailing bytes after payload"));
    }
    let crc = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
    if crc32(payload) != crc {
        return Err(ArtifactError::BadChecksum("payload"));
    }

    // Walk the sections: manifest first, then each body in order, each
    // checked against its own crc and the manifest table.
    let mut sections: Vec<(u8, &[u8])> = Vec::new();
    let mut pos = 0usize;
    while pos < payload.len() {
        let head = payload
            .get(pos..pos + 9)
            .ok_or(ArtifactError::Truncated)?;
        let kind = head[0];
        let slen = u32::from_le_bytes([head[1], head[2], head[3], head[4]]) as usize;
        let scrc = u32::from_le_bytes([head[5], head[6], head[7], head[8]]);
        let body = payload
            .get(pos + 9..pos + 9 + slen)
            .ok_or(ArtifactError::Truncated)?;
        if crc32(body) != scrc {
            return Err(ArtifactError::BadChecksum(section_name(kind)));
        }
        sections.push((kind, body));
        pos += 9 + slen;
    }

    let (first_kind, manifest_body) = *sections
        .first()
        .ok_or(ArtifactError::Malformed("empty payload"))?;
    if first_kind != SEC_MANIFEST {
        return Err(ArtifactError::Malformed("manifest section must come first"));
    }
    let (digest, seed, table) = decode_manifest(manifest_body)?;

    // The manifest table and the actual sections must agree exactly.
    let rest = &sections[1..];
    if rest.len() != table.len() || rest.len() != SECTIONS.len() {
        return Err(ArtifactError::Malformed("section table mismatch"));
    }
    for (i, (kind, body)) in rest.iter().enumerate() {
        if SECTIONS[i] != *kind {
            return Err(ArtifactError::BadSection(*kind));
        }
        let (tkind, tcrc) = table[i];
        if tkind != *kind || crc32(body) != tcrc {
            return Err(ArtifactError::BadChecksum(section_name(*kind)));
        }
    }

    let code = decode_code(rest[0].1)?;
    let straggler = decode_straggler(rest[1].1)?;
    let runtime = decode_runtime(rest[2].1)?;
    let batching = decode_batching(rest[3].1)?;
    let serving = decode_serving(rest[4].1)?;
    let chaos = decode_chaos(rest[5].1)?;
    let transport = decode_transport(rest[6].1)?;

    if topology_digest(code.scheme, &code.topology) != digest {
        return Err(ArtifactError::Malformed(
            "topology digest does not match the code section",
        ));
    }
    let config = ClusterConfig {
        code,
        straggler,
        runtime,
        batching,
        serving,
        chaos,
        transport,
        seed,
    };
    // Final semantic guard: `compile` validated, so this only fires on
    // hand-crafted artifacts whose checksums are internally consistent.
    config
        .code
        .validate()
        .map_err(|e| ArtifactError::Invalid(format!("{e}")))?;
    Ok(ScenarioArtifact {
        manifest: ScenarioManifest {
            artifact_version,
            compiler_version,
            topology_digest: digest,
            seed,
            section_crcs: table,
        },
        config,
    })
}

fn push_section(out: &mut Vec<u8>, kind: u8, body: &[u8]) {
    out.push(kind);
    wire::put_u32(out, body.len() as u32);
    wire::put_u32(out, crc32(body));
    out.extend_from_slice(body);
}

fn decode_manifest(body: &[u8]) -> Result<(u32, u64, Vec<(u8, u32)>), ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let digest = r.u32()?;
    let seed = r.u64()?;
    let count = r.u8()? as usize;
    let mut table = Vec::with_capacity(count);
    for _ in 0..count {
        let kind = r.u8()?;
        let crc = r.u32()?;
        table.push((kind, crc));
    }
    finish(&r, body, "manifest")?;
    Ok((digest, seed, table))
}

/// Reject trailing bytes after a fully-decoded section.
fn finish(r: &Reader<'_>, body: &[u8], _what: &'static str) -> Result<(), ArtifactError> {
    if r.pos != body.len() {
        return Err(ArtifactError::Malformed("trailing bytes in section"));
    }
    Ok(())
}

fn scheme_tag(s: SchemeKind) -> u8 {
    match s {
        SchemeKind::Hierarchical => 0,
        SchemeKind::Mds => 1,
        SchemeKind::Product => 2,
        SchemeKind::Replication => 3,
        SchemeKind::Polynomial => 4,
    }
}

fn scheme_from_tag(t: u8) -> Result<SchemeKind, ArtifactError> {
    Ok(match t {
        0 => SchemeKind::Hierarchical,
        1 => SchemeKind::Mds,
        2 => SchemeKind::Product,
        3 => SchemeKind::Replication,
        4 => SchemeKind::Polynomial,
        _ => return Err(ArtifactError::Malformed("unknown scheme tag")),
    })
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    wire::put_u64(out, v.to_bits());
}

fn read_f64(r: &mut Reader<'_>) -> Result<f64, ArtifactError> {
    Ok(f64::from_bits(r.u64()?))
}

fn read_usize(r: &mut Reader<'_>) -> Result<usize, ArtifactError> {
    Ok(r.u32()? as usize)
}

fn encode_model(out: &mut Vec<u8>, m: &StragglerModel) {
    match m {
        StragglerModel::Exponential { mu } => {
            out.push(0);
            put_f64(out, *mu);
        }
        StragglerModel::ShiftedExponential { shift, mu } => {
            out.push(1);
            put_f64(out, *shift);
            put_f64(out, *mu);
        }
        StragglerModel::Weibull { shape, scale } => {
            out.push(2);
            put_f64(out, *shape);
            put_f64(out, *scale);
        }
        StragglerModel::Deterministic { value } => {
            out.push(3);
            put_f64(out, *value);
        }
    }
}

fn decode_model(r: &mut Reader<'_>) -> Result<StragglerModel, ArtifactError> {
    Ok(match r.u8()? {
        0 => StragglerModel::Exponential { mu: read_f64(r)? },
        1 => StragglerModel::ShiftedExponential {
            shift: read_f64(r)?,
            mu: read_f64(r)?,
        },
        2 => StragglerModel::Weibull {
            shape: read_f64(r)?,
            scale: read_f64(r)?,
        },
        3 => StragglerModel::Deterministic { value: read_f64(r)? },
        _ => return Err(ArtifactError::Malformed("unknown straggler-model tag")),
    })
}

fn encode_code(c: &CodeConfig) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(scheme_tag(c.scheme));
    for v in [c.n1, c.k1, c.n2, c.k2, c.topology.k2, c.topology.groups.len()] {
        wire::put_u32(&mut out, v as u32);
    }
    for g in &c.topology.groups {
        wire::put_u32(&mut out, g.n1 as u32);
        wire::put_u32(&mut out, g.k1 as u32);
        wire::put_u32(&mut out, g.subtasks as u32);
        encode_model(&mut out, &g.worker);
        encode_model(&mut out, &g.link);
        match g.scale {
            Some(s) => {
                out.push(1);
                put_f64(&mut out, s);
            }
            None => out.push(0),
        }
        wire::put_u32(&mut out, g.dead_workers.len() as u32);
        for &d in &g.dead_workers {
            wire::put_u32(&mut out, d as u32);
        }
    }
    out
}

fn decode_code(body: &[u8]) -> Result<CodeConfig, ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let scheme = scheme_from_tag(r.u8()?)?;
    let n1 = read_usize(&mut r)?;
    let k1 = read_usize(&mut r)?;
    let n2 = read_usize(&mut r)?;
    let k2 = read_usize(&mut r)?;
    let topo_k2 = read_usize(&mut r)?;
    let count = read_usize(&mut r)?;
    // A corrupt count cannot ask for gigabytes: the vectors below grow
    // as bytes are actually consumed, so a huge declared count dies on
    // `Truncated` after at most one over-read, never a giant alloc.
    if count > body.len() {
        return Err(ArtifactError::Truncated);
    }
    let mut groups = Vec::new();
    for _ in 0..count {
        let gn1 = read_usize(&mut r)?;
        let gk1 = read_usize(&mut r)?;
        let subtasks = read_usize(&mut r)?;
        let worker = decode_model(&mut r)?;
        let link = decode_model(&mut r)?;
        let scale = match r.u8()? {
            0 => None,
            1 => Some(read_f64(&mut r)?),
            _ => return Err(ArtifactError::Malformed("bad scale flag")),
        };
        let dead_count = read_usize(&mut r)?;
        if dead_count > body.len() {
            return Err(ArtifactError::Truncated);
        }
        let mut dead_workers = Vec::new();
        for _ in 0..dead_count {
            dead_workers.push(read_usize(&mut r)?);
        }
        groups.push(GroupSpec {
            n1: gn1,
            k1: gk1,
            worker,
            link,
            scale,
            dead_workers,
            subtasks,
        });
    }
    finish(&r, body, "code")?;
    Ok(CodeConfig {
        scheme,
        n1,
        k1,
        n2,
        k2,
        topology: Topology { groups, k2: topo_k2 },
    })
}

fn encode_straggler(s: &StragglerConfig) -> Vec<u8> {
    let mut out = Vec::new();
    encode_model(&mut out, &s.worker);
    encode_model(&mut out, &s.link);
    put_f64(&mut out, s.scale);
    out.push(u8::from(s.enabled));
    out
}

fn decode_straggler(body: &[u8]) -> Result<StragglerConfig, ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let c = StragglerConfig {
        worker: decode_model(&mut r)?,
        link: decode_model(&mut r)?,
        scale: read_f64(&mut r)?,
        enabled: r.u8()? != 0,
    };
    finish(&r, body, "straggler")?;
    Ok(c)
}

fn encode_runtime(c: &RuntimeConfig) -> Vec<u8> {
    let mut out = Vec::new();
    wire::put_str(&mut out, &c.artifact_dir);
    out.push(u8::from(c.use_pjrt));
    wire::put_u32(&mut out, c.decode_threads as u32);
    out
}

fn decode_runtime(body: &[u8]) -> Result<RuntimeConfig, ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let c = RuntimeConfig {
        artifact_dir: r.string()?,
        use_pjrt: r.u8()? != 0,
        decode_threads: read_usize(&mut r)?,
    };
    finish(&r, body, "runtime")?;
    Ok(c)
}

fn encode_batching(c: &BatchConfig) -> Vec<u8> {
    let mut out = Vec::new();
    wire::put_u32(&mut out, c.max_batch as u32);
    put_f64(&mut out, c.max_wait_ms);
    out
}

fn decode_batching(body: &[u8]) -> Result<BatchConfig, ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let c = BatchConfig {
        max_batch: read_usize(&mut r)?,
        max_wait_ms: read_f64(&mut r)?,
    };
    finish(&r, body, "batching")?;
    Ok(c)
}

fn encode_serving(c: &ServingConfig) -> Vec<u8> {
    let mut out = Vec::new();
    wire::put_u32(&mut out, c.queue_cap as u32);
    put_f64(&mut out, c.default_deadline_ms);
    put_f64(&mut out, c.drain_ms);
    wire::put_u32(&mut out, c.models.len() as u32);
    for m in &c.models {
        wire::put_str(&mut out, &m.name);
        wire::put_u64(&mut out, m.rows as u64);
        wire::put_u64(&mut out, m.cols as u64);
        wire::put_u64(&mut out, m.seed);
    }
    out
}

fn decode_serving(body: &[u8]) -> Result<ServingConfig, ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let queue_cap = read_usize(&mut r)?;
    let default_deadline_ms = read_f64(&mut r)?;
    let drain_ms = read_f64(&mut r)?;
    let count = read_usize(&mut r)?;
    if count > body.len() {
        return Err(ArtifactError::Truncated);
    }
    let mut models = Vec::new();
    for _ in 0..count {
        let name = r.string()?;
        let rows = usize::try_from(r.u64()?)
            .map_err(|_| ArtifactError::Malformed("model rows overflow"))?;
        let cols = usize::try_from(r.u64()?)
            .map_err(|_| ArtifactError::Malformed("model cols overflow"))?;
        let seed = r.u64()?;
        models.push(ModelSpec {
            name,
            rows,
            cols,
            seed,
        });
    }
    finish(&r, body, "serving")?;
    Ok(ServingConfig {
        queue_cap,
        default_deadline_ms,
        drain_ms,
        models,
    })
}

fn encode_chaos(c: &ChaosConfig) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(u8::from(c.liveness));
    put_f64(&mut out, c.heartbeat_ms);
    put_f64(&mut out, c.suspect_ms);
    put_f64(&mut out, c.dead_ms);
    out
}

fn decode_chaos(body: &[u8]) -> Result<ChaosConfig, ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let c = ChaosConfig {
        liveness: r.u8()? != 0,
        heartbeat_ms: read_f64(&mut r)?,
        suspect_ms: read_f64(&mut r)?,
        dead_ms: read_f64(&mut r)?,
    };
    finish(&r, body, "chaos")?;
    Ok(c)
}

fn encode_transport(c: &TransportConfig) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(match c.mode {
        TransportMode::Memory => 0,
        TransportMode::Socket => 1,
    });
    wire::put_str(&mut out, &c.listen);
    put_f64(&mut out, c.connect_wait_ms);
    put_f64(&mut out, c.dial_backoff_ms);
    put_f64(&mut out, c.dial_backoff_max_ms);
    out
}

fn decode_transport(body: &[u8]) -> Result<TransportConfig, ArtifactError> {
    let mut r = Reader { buf: body, pos: 0 };
    let mode = match r.u8()? {
        0 => TransportMode::Memory,
        1 => TransportMode::Socket,
        _ => return Err(ArtifactError::Malformed("unknown transport-mode tag")),
    };
    let c = TransportConfig {
        mode,
        listen: r.string()?,
        connect_wait_ms: read_f64(&mut r)?,
        dial_backoff_ms: read_f64(&mut r)?,
        dial_backoff_max_ms: read_f64(&mut r)?,
    };
    finish(&r, body, "transport")?;
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_config() -> ClusterConfig {
        let mut c = ClusterConfig::demo(3, 2, 3, 2);
        c.serving.models = vec![
            ModelSpec {
                name: "alpha".into(),
                rows: 12,
                cols: 8,
                seed: 5,
            },
            ModelSpec {
                name: "β-model".into(),
                rows: 24,
                cols: 4,
                seed: 9,
            },
        ];
        c.code.topology.groups[1].worker = StragglerModel::Weibull {
            shape: 0.7,
            scale: 2.0,
        };
        c.code.topology.groups[1].scale = Some(1.5);
        c.code.topology.groups[2].dead_workers = vec![1];
        c
    }

    #[test]
    fn compile_decode_recompile_is_bit_identical() {
        let config = demo_config();
        let bytes = compile(&config).unwrap();
        let art = decode(&bytes).unwrap();
        assert_eq!(art.config, config, "decode returns the compiled config");
        assert_eq!(art.manifest.artifact_version, ARTIFACT_VERSION);
        assert_eq!(art.manifest.seed, config.seed);
        let again = compile(&art.config).unwrap();
        assert_eq!(bytes, again, "compile is deterministic and lossless");
    }

    #[test]
    fn digest_tracks_compatibility_shape_only() {
        let a = demo_config();
        let mut b = demo_config();
        b.serving.queue_cap += 1;
        b.batching.max_batch += 1;
        assert_eq!(
            decode(&compile(&a).unwrap()).unwrap().manifest.topology_digest,
            decode(&compile(&b).unwrap()).unwrap().manifest.topology_digest,
            "serving/batching changes keep the digest"
        );
        let mut c = demo_config();
        c.code.topology.groups[0].k1 = 3;
        c.code.topology.groups[0].n1 = 4;
        assert_ne!(
            topology_digest(a.code.scheme, &a.code.topology),
            topology_digest(c.code.scheme, &c.code.topology),
            "k1 plan changes move the digest"
        );
    }

    #[test]
    fn truncation_rejects_at_every_prefix_length() {
        let bytes = compile(&demo_config()).unwrap();
        for cut in 0..bytes.len() {
            let err = decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, ArtifactError::Truncated | ArtifactError::BadChecksum(_)),
                "prefix of {cut} bytes gave {err:?}"
            );
        }
    }

    #[test]
    fn corruption_rejects_never_panics() {
        let bytes = compile(&demo_config()).unwrap();
        for at in 0..bytes.len() {
            if at == 6 || at == 7 {
                // Compiler version is provenance, not integrity: newer
                // compilers emitting the same format stay loadable.
                continue;
            }
            let mut bad = bytes.clone();
            bad[at] ^= 0x5A;
            assert!(decode(&bad).is_err(), "flipped byte {at} went undetected");
        }
    }

    #[test]
    fn version_skew_rejected_with_both_versions() {
        let mut bytes = compile(&demo_config()).unwrap();
        bytes[4..6].copy_from_slice(&(ARTIFACT_VERSION + 1).to_le_bytes());
        assert_eq!(
            decode(&bytes).unwrap_err(),
            ArtifactError::BadVersion {
                got: ARTIFACT_VERSION + 1,
                want: ARTIFACT_VERSION,
            }
        );
    }

    #[test]
    fn bad_magic_and_trailing_bytes_rejected() {
        let mut bytes = compile(&demo_config()).unwrap();
        bytes[0] ^= 0xFF;
        assert_eq!(decode(&bytes).unwrap_err(), ArtifactError::BadMagic);
        let mut bytes = compile(&demo_config()).unwrap();
        bytes.push(0);
        assert!(matches!(
            decode(&bytes).unwrap_err(),
            ArtifactError::Malformed(_)
        ));
    }

    #[test]
    fn invalid_config_rejected_at_compile_time() {
        let mut c = demo_config();
        c.code.topology.groups[0].k1 = 99; // k1 > n1
        assert!(compile(&c).is_err(), "compile validates semantics");
    }

    #[test]
    fn artifact_error_maps_to_typed_crate_error() {
        let e: crate::Error = ArtifactError::BadChecksum("payload").into();
        assert!(matches!(e, crate::Error::Config(_)));
        assert!(format!("{e}").contains("checksum"));
    }
}
