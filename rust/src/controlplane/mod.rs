//! Control plane: compiled scenario artifacts, zero-drop hot reload,
//! and the framed admin surface.
//!
//! The data plane (coordinator, transport, coding) answers "how do we
//! serve a job"; this module answers "how does an operator *change*
//! what is being served without dropping anything". Three pieces:
//!
//! - [`artifact`] — `hiercode compile` turns a validated
//!   [`crate::config::schema::ClusterConfig`] into a versioned,
//!   CRC32-checksummed `.hca` binary. All semantic validation happens
//!   at compile time; loading is a pure integrity + compatibility
//!   check, so a cluster can trust any artifact that decodes.
//! - [`rollout`] — the compatibility gate and light/heavy
//!   classification for hot reload. A candidate artifact either swaps
//!   in atomically (generation-stamped, in-flight jobs drained first,
//!   shards re-shipped) or is rejected with
//!   [`crate::Error::Incompatible`] and *nothing* is applied.
//! - [`admin`] — a framed request/response protocol on a dedicated
//!   control socket (never the data lanes) behind `hiercode admin
//!   status|metrics|reoptimize|rollout|rollback`.
//!
//! The live swap itself lives in
//! `coordinator::cluster::ClusterCore::load_artifact`, which drives the
//! drain machinery, the per-seat shard re-ship and the
//! generation bump; this module owns everything that can be decided
//! *without* a running cluster.

pub mod admin;
pub mod artifact;
pub mod rollout;

pub use admin::{AdminControl, AdminRequest, AdminResponse, AdminServer};
pub use artifact::{
    compile, decode, topology_digest, ArtifactError, ScenarioArtifact, ScenarioManifest,
};
pub use rollout::{classify, RolloutKind};
