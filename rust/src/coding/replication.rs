//! Uncoded replication baseline.
//!
//! `A` is split into `k` blocks, each replicated `n/k` times. A block is
//! recovered as soon as *any* of its replicas responds; decoding is a
//! reshuffle (0 flops) — which is why Table I gives replication
//! `T_dec = 0` and why it wins Fig. 7's high-`α` regime despite the
//! worst computing time `k·H_k/(n·µ2)`.

use crate::coding::{
    CodedScheme, DecodeOutput, DecodeProgress, Decoder, WorkerResult,
};
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::time::Instant;

/// `(n, k)` replication: `n/k` replicas of each of `k` blocks.
#[derive(Clone, Debug)]
pub struct ReplicationCode {
    n: usize,
    k: usize,
}

impl ReplicationCode {
    /// Construct; requires `k | n` so every block gets the same number
    /// of replicas.
    pub fn new(n: usize, k: usize) -> Result<Self> {
        if k == 0 || k > n {
            return Err(Error::InvalidParams(format!(
                "replication: need 1 <= k <= n, got ({n}, {k})"
            )));
        }
        if n % k != 0 {
            return Err(Error::InvalidParams(format!(
                "replication: k={k} must divide n={n}"
            )));
        }
        Ok(Self { n, k })
    }

    /// Replication factor `n/k`.
    pub fn replicas(&self) -> usize {
        self.n / self.k
    }

    /// Which data block worker `i` holds.
    pub fn block_of(&self, worker: usize) -> usize {
        worker / self.replicas()
    }
}

impl CodedScheme for ReplicationCode {
    fn name(&self) -> String {
        format!("rep({},{})", self.n, self.k)
    }

    fn num_workers(&self) -> usize {
        self.n
    }

    fn num_data_blocks(&self) -> usize {
        self.k
    }

    fn row_divisor(&self) -> usize {
        self.k
    }

    fn encode(&self, a: &Matrix) -> Result<Vec<Matrix>> {
        let blocks = a.split_rows(self.k)?;
        let r = self.replicas();
        let mut shards = Vec::with_capacity(self.n);
        for b in &blocks {
            for _ in 0..r {
                shards.push(b.clone());
            }
        }
        Ok(shards)
    }

    fn can_decode(&self, present: &[usize]) -> bool {
        let mut covered = vec![false; self.k];
        for &w in present {
            if w < self.n {
                covered[self.block_of(w)] = true;
            }
        }
        covered.iter().all(|&c| c)
    }

    fn decoder(&self, out_rows: usize, _batch: usize) -> Box<dyn Decoder> {
        Box::new(ReplicationDecoder {
            code: self.clone(),
            out_rows,
            slots: vec![None; self.k],
            covered: 0,
            seconds: 0.0,
            finished: false,
        })
    }
}

/// Streaming session for replication: a block is recovered by its
/// first-arriving replica; ready once every block is covered. Decode is
/// a reshuffle — 0 flops (Table I's `T_dec = 0`).
pub struct ReplicationDecoder {
    code: ReplicationCode,
    out_rows: usize,
    slots: Vec<Option<Matrix>>,
    covered: usize,
    seconds: f64,
    finished: bool,
}

impl Decoder for ReplicationDecoder {
    fn push(&mut self, result: WorkerResult) -> Result<DecodeProgress> {
        let t0 = Instant::now();
        if result.shard >= self.code.n {
            return Err(Error::InvalidParams(format!(
                "worker {} out of n={}",
                result.shard, self.code.n
            )));
        }
        let b = self.code.block_of(result.shard);
        if self.slots[b].is_none() {
            self.slots[b] = Some(result.data);
            self.covered += 1;
        }
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(self.progress())
    }

    fn progress(&self) -> DecodeProgress {
        if self.covered >= self.code.k {
            DecodeProgress::Ready
        } else {
            DecodeProgress::NeedMore {
                still_needed: self.code.k - self.covered,
            }
        }
    }

    fn finish(&mut self) -> Result<DecodeOutput> {
        let t0 = Instant::now();
        if self.finished {
            return Err(Error::InvalidParams(
                "decode session already finished".into(),
            ));
        }
        if self.covered < self.code.k {
            return Err(Error::Insufficient {
                needed: self.code.k,
                got: self.covered,
            });
        }
        let blocks: Vec<Matrix> = self
            .slots
            .iter_mut()
            .map(|s| s.take().expect("covered"))
            .collect();
        let result = Matrix::vstack(&blocks)?;
        if result.rows() != self.out_rows {
            return Err(Error::InvalidParams(format!(
                "decoded {} rows, expected {}",
                result.rows(),
                self.out_rows
            )));
        }
        self.finished = true;
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(DecodeOutput {
            result,
            flops: 0, // replication decodes for free (Table I)
            seconds: self.seconds,
        })
    }

    fn flops_so_far(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{compute_all_products, select_results};
    use crate::linalg::ops;
    use crate::util::rng::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn params_validated() {
        assert!(ReplicationCode::new(6, 3).is_ok());
        assert!(ReplicationCode::new(5, 3).is_err()); // 3 ∤ 5
        assert!(ReplicationCode::new(3, 0).is_err());
        assert!(ReplicationCode::new(3, 4).is_err());
    }

    #[test]
    fn one_replica_per_block_suffices() {
        let code = ReplicationCode::new(6, 3).unwrap();
        let mut r = Rng::new(1);
        let a = random_matrix(&mut r, 9, 4);
        let x = random_matrix(&mut r, 4, 1);
        let expect = ops::matmul(&a, &x);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Second replica of each block: workers 1, 3, 5.
        let out = code.decode(&select_results(&all, &[1, 3, 5]), 9).unwrap();
        assert_eq!(out.flops, 0);
        assert!(out.result.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn missing_block_rejected() {
        let code = ReplicationCode::new(6, 3).unwrap();
        let mut r = Rng::new(2);
        let a = random_matrix(&mut r, 6, 2);
        let x = random_matrix(&mut r, 2, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Both replicas of block 0 and one of block 1 — block 2 missing.
        let err = code.decode(&select_results(&all, &[0, 1, 2]), 6);
        assert!(matches!(err, Err(Error::Insufficient { needed: 3, got: 2 })));
        assert!(!code.can_decode(&[0, 1, 2]));
        assert!(code.can_decode(&[0, 2, 4]));
    }

    #[test]
    fn any_k_distinct_blocks_not_enough_unless_covering() {
        // Unlike MDS, k responses don't suffice unless they cover all
        // blocks — the defining weakness replication trades for T_dec=0.
        let code = ReplicationCode::new(4, 2).unwrap();
        assert!(!code.can_decode(&[0, 1])); // both replicas of block 0
        assert!(code.can_decode(&[1, 2]));
    }
}
