//! Product code baseline (Lee, Suh, Ramchandran — ISIT'17).
//!
//! Workers form an `n2 × n1` grid. The data is a `k2 × k1` grid of
//! blocks `D[r][c]`; each grid row is a codeword of an `(n1, k1)` MDS
//! code and each grid column a codeword of an `(n2, k2)` MDS code
//! (tensor-product structure). Decoding is **iterative peeling**: any
//! row with ≥ k1 known entries is row-decoded, any column with ≥ k2
//! known entries is column-decoded, repeating until the data positions
//! are filled or no progress is possible.
//!
//! Under the hierarchical (rack) topology the product code's decode
//! cannot be split between submasters and master the way the
//! hierarchical code's can — rows and columns interleave — so its cost
//! `O(k1·k2^β + k2·k1^β)` lands entirely on the master, which is the
//! §IV comparison the paper draws.

use crate::coding::{
    CodedScheme, DecodeOutput, DecodeProgress, Decoder, MdsCode, WorkerResult,
};
use crate::linalg::Matrix;
use crate::parallel::DecodePool;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Instant;

/// `(n1, k1) × (n2, k2)` product code on an `n2 × n1` worker grid.
#[derive(Clone, Debug)]
pub struct ProductCode {
    n1: usize,
    k1: usize,
    n2: usize,
    k2: usize,
    row_code: MdsCode,
    col_code: MdsCode,
    /// Pool the peeling decoder fans each pass's independent row /
    /// column eliminations across (serial by default).
    pool: Arc<DecodePool>,
}

impl ProductCode {
    /// Construct with the same parameters as the hierarchical code for
    /// apples-to-apples comparison (`n = n1·n2`, `k = k1·k2`).
    pub fn new(n1: usize, k1: usize, n2: usize, k2: usize) -> Result<Self> {
        Ok(Self {
            n1,
            k1,
            n2,
            k2,
            row_code: MdsCode::new(n1, k1)?,
            col_code: MdsCode::new(n2, k2)?,
            pool: Arc::new(DecodePool::serial()),
        })
    }

    /// Attach a decode pool: within each peeling pass, the eligible
    /// rows (resp. columns) are decoded concurrently — they are
    /// independent by construction, since a row decode only fills
    /// entries of its own row. Fills are applied in index order
    /// afterwards, so results and flop counts are bit-identical to the
    /// serial peel.
    pub fn with_pool(mut self, pool: Arc<DecodePool>) -> Self {
        self.pool = pool;
        self
    }

    /// Grid position of flat worker `w`: `(row i ∈ [n2], col j ∈ [n1])`.
    pub fn grid_pos(&self, w: usize) -> (usize, usize) {
        (w / self.n1, w % self.n1)
    }

    /// Flat index of grid position `(i, j)`.
    pub fn flat_index(&self, i: usize, j: usize) -> usize {
        i * self.n1 + j
    }

    /// Peeling feasibility on a boolean mask (no data): returns true if
    /// iterative row/column decoding can recover all data positions.
    pub fn peel_mask(&self, mut known: Vec<Vec<bool>>) -> bool {
        loop {
            let mut progress = false;
            for i in 0..self.n2 {
                let cnt = known[i].iter().filter(|&&b| b).count();
                if cnt >= self.k1 && cnt < self.n1 {
                    known[i].iter_mut().for_each(|b| *b = true);
                    progress = true;
                }
            }
            for j in 0..self.n1 {
                let cnt = (0..self.n2).filter(|&i| known[i][j]).count();
                if cnt >= self.k2 && cnt < self.n2 {
                    (0..self.n2).for_each(|i| known[i][j] = true);
                    progress = true;
                }
            }
            if !progress {
                break;
            }
        }
        (0..self.k2).all(|r| (0..self.k1).all(|c| known[r][c]))
    }
}

impl CodedScheme for ProductCode {
    fn name(&self) -> String {
        format!("prod({},{})x({},{})", self.n1, self.k1, self.n2, self.k2)
    }

    fn num_workers(&self) -> usize {
        self.n1 * self.n2
    }

    fn num_data_blocks(&self) -> usize {
        self.k1 * self.k2
    }

    fn row_divisor(&self) -> usize {
        self.k1 * self.k2
    }

    fn encode(&self, a: &Matrix) -> Result<Vec<Matrix>> {
        // Split A into k2 row-groups, each into k1 sub-blocks — the same
        // data layout the hierarchical code uses, so results compare
        // directly.
        let outer = a.split_rows(self.k2)?;
        let mut data = Vec::with_capacity(self.k2);
        for block in &outer {
            data.push(block.split_rows(self.k1)?);
        }
        // Column-encode each data column c: k2 blocks → n2.
        let mut col_encoded: Vec<Vec<Matrix>> = vec![Vec::new(); self.n2];
        for c in 0..self.k1 {
            let col: Vec<Matrix> = (0..self.k2).map(|r| data[r][c].clone()).collect();
            let coded = self.col_code.encode_blocks(&col)?;
            for (i, m) in coded.into_iter().enumerate() {
                col_encoded[i].push(m);
            }
        }
        // Row-encode each grid row i: k1 blocks → n1.
        let mut shards = Vec::with_capacity(self.n1 * self.n2);
        for row in col_encoded {
            let coded = self.row_code.encode_blocks(&row)?;
            shards.extend(coded);
        }
        Ok(shards)
    }

    fn can_decode(&self, present: &[usize]) -> bool {
        let mut known = vec![vec![false; self.n1]; self.n2];
        for &w in present {
            if w < self.num_workers() {
                let (i, j) = self.grid_pos(w);
                known[i][j] = true;
            }
        }
        self.peel_mask(known)
    }

    fn decoder(&self, out_rows: usize, _batch: usize) -> Box<dyn Decoder> {
        Box::new(ProductDecoder::new(self.clone(), out_rows))
    }

    fn topology(&self) -> crate::scenario::Topology {
        // Grid rows map onto racks, but the product code's decode cannot
        // be split between submasters and master (rows and columns
        // interleave), so the submasters are relays — §IV's contrast.
        crate::scenario::Topology::homogeneous(self.n1, self.k1, self.n2, self.k2)
    }
}

/// Streaming session for the product code: **peeling-as-you-go**. Each
/// pushed result is placed on the grid and peeling passes run
/// immediately, so row/column eliminations happen as results arrive
/// instead of after collection. Eager peeling may spend more total
/// flops than an offline peel of the final subset (a row is decoded at
/// its `k1`-th arrival even if more of it was still in flight) — that
/// is the streaming tradeoff: work moves off the tail.
pub struct ProductDecoder {
    code: ProductCode,
    out_rows: usize,
    grid: Vec<Vec<Option<Matrix>>>,
    /// Known entries per grid row / column (so peel passes check a
    /// counter instead of cloning blocks to find out nothing decodes —
    /// the common case on a streaming push).
    row_count: Vec<usize>,
    col_count: Vec<usize>,
    /// Distinct results pushed (for the `still_needed` info bound).
    pushed: Vec<Vec<bool>>,
    received: usize,
    flops: u64,
    seconds: f64,
    ready: bool,
    finished: bool,
}

impl ProductDecoder {
    fn new(code: ProductCode, out_rows: usize) -> Self {
        let (n1, n2) = (code.n1, code.n2);
        Self {
            code,
            out_rows,
            grid: vec![vec![None; n1]; n2],
            row_count: vec![0; n2],
            col_count: vec![0; n1],
            pushed: vec![vec![false; n1]; n2],
            received: 0,
            flops: 0,
            seconds: 0.0,
            ready: false,
            finished: false,
        }
    }

    fn data_complete(&self) -> bool {
        (0..self.code.k2).all(|r| (0..self.code.k1).all(|c| self.grid[r][c].is_some()))
    }

    /// Decode one eligible grid line (row if `is_row`, else column):
    /// MDS-decode its known entries, re-encode, and return the fills
    /// for the line's missing entries plus the flops spent (decode +
    /// re-encode cost of non-systematic fills). Read-only on the grid,
    /// which is what makes a pass's lines safe to fan out.
    fn decode_line(&self, line: usize, is_row: bool) -> Result<LineFill> {
        let (code, span, k) = if is_row {
            (&self.code.row_code, self.code.n1, self.code.k1)
        } else {
            (&self.code.col_code, self.code.n2, self.code.k2)
        };
        let have: Vec<(usize, Matrix)> = (0..span)
            .filter_map(|o| {
                let (i, j) = if is_row { (line, o) } else { (o, line) };
                self.grid[i][j].as_ref().map(|m| (o, m.clone()))
            })
            .collect();
        let (blocks, f) = code.decode_blocks(&have)?;
        let mut flops = f;
        let re = code.encode_blocks(&blocks)?;
        let mut fills = Vec::new();
        for (o, m) in re.into_iter().enumerate() {
            let (i, j) = if is_row { (line, o) } else { (o, line) };
            if self.grid[i][j].is_none() {
                // Re-encode cost: 2·k·elems per non-systematic entry.
                if o >= k {
                    flops += 2 * k as u64 * m.data().len() as u64;
                }
                fills.push((o, m));
            }
        }
        Ok(LineFill { line, fills, flops })
    }

    /// Place a pass's fills on the grid, in line order — the serial
    /// peel's exact placement and flop-accumulation order, whatever
    /// order the pool produced them in.
    fn apply_fills(&mut self, fills: Vec<LineFill>, is_row: bool) {
        for lf in fills {
            self.flops += lf.flops;
            for (o, m) in lf.fills {
                let (i, j) = if is_row { (lf.line, o) } else { (o, lf.line) };
                debug_assert!(self.grid[i][j].is_none(), "fill conflict at ({i},{j})");
                self.grid[i][j] = Some(m);
                self.row_count[i] += 1;
                self.col_count[j] += 1;
            }
        }
    }

    /// Run row/column peeling passes until no progress (or the data
    /// positions are complete). Identical elimination and flop
    /// accounting to the serial peel; within one pass the eligible
    /// lines are independent (a row decode fills only its own row, so
    /// it cannot change another row's eligibility or inputs; columns
    /// symmetrically), which lets each pass fan across the code's pool
    /// with bit-identical results.
    fn peel(&mut self) -> Result<()> {
        let (n1, k1, n2, k2) = (self.code.n1, self.code.k1, self.code.n2, self.code.k2);
        loop {
            let mut progress = false;
            for is_row in [true, false] {
                let (span, lo, hi) = if is_row { (n2, k1, n1) } else { (n1, k2, n2) };
                let count = |line: usize| {
                    if is_row {
                        self.row_count[line]
                    } else {
                        self.col_count[line]
                    }
                };
                let eligible: Vec<usize> = (0..span)
                    .filter(|&l| count(l) >= lo && count(l) < hi)
                    .collect();
                if eligible.is_empty() {
                    continue;
                }
                progress = true;
                let decoded: Vec<Result<LineFill>> =
                    if self.code.pool.size() > 1 && eligible.len() > 1 {
                        self.code.pool.map(eligible, |l| self.decode_line(l, is_row))
                    } else {
                        eligible
                            .into_iter()
                            .map(|l| self.decode_line(l, is_row))
                            .collect()
                    };
                let decoded = decoded.into_iter().collect::<Result<Vec<_>>>()?;
                self.apply_fills(decoded, is_row);
            }
            if self.data_complete() || !progress {
                return Ok(());
            }
        }
    }
}

/// One peeled line's output: fills for its missing entries (keyed by
/// the in-line index) and the flops the elimination cost.
struct LineFill {
    line: usize,
    fills: Vec<(usize, Matrix)>,
    flops: u64,
}

impl Decoder for ProductDecoder {
    fn push(&mut self, result: WorkerResult) -> Result<DecodeProgress> {
        let t0 = Instant::now();
        if result.shard >= self.code.num_workers() {
            return Err(Error::InvalidParams(format!(
                "worker {} out of {}",
                result.shard,
                self.code.num_workers()
            )));
        }
        let (i, j) = self.code.grid_pos(result.shard);
        if !self.ready && !self.pushed[i][j] {
            self.pushed[i][j] = true;
            self.received += 1;
            if self.grid[i][j].is_none() {
                self.grid[i][j] = Some(result.data);
                self.row_count[i] += 1;
                self.col_count[j] += 1;
            }
            if self.data_complete() {
                self.ready = true;
            } else {
                self.peel()?;
                if self.data_complete() {
                    self.ready = true;
                }
            }
        }
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(self.progress())
    }

    fn progress(&self) -> DecodeProgress {
        if self.ready {
            DecodeProgress::Ready
        } else {
            // Info-theoretic bound: any decode needs ≥ k1·k2 received
            // coded symbols in total.
            let k = self.code.k1 * self.code.k2;
            DecodeProgress::NeedMore {
                still_needed: k.saturating_sub(self.received).max(1),
            }
        }
    }

    fn finish(&mut self) -> Result<DecodeOutput> {
        let t0 = Instant::now();
        if self.finished {
            return Err(Error::InvalidParams(
                "decode session already finished".into(),
            ));
        }
        if !self.ready {
            let got = self
                .grid
                .iter()
                .flat_map(|row| row.iter())
                .filter(|e| e.is_some())
                .count();
            return Err(Error::Insufficient {
                needed: self.code.num_data_blocks(),
                got,
            });
        }
        // Assemble A·x from the systematic grid positions.
        let mut blocks = Vec::with_capacity(self.code.k1 * self.code.k2);
        for r in 0..self.code.k2 {
            for c in 0..self.code.k1 {
                blocks.push(self.grid[r][c].take().expect("peeled"));
            }
        }
        let result = Matrix::vstack(&blocks)?;
        if result.rows() != self.out_rows {
            return Err(Error::InvalidParams(format!(
                "decoded {} rows, expected {}",
                result.rows(),
                self.out_rows
            )));
        }
        self.finished = true;
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(DecodeOutput {
            result,
            flops: self.flops,
            seconds: self.seconds,
        })
    }

    fn flops_so_far(&self) -> u64 {
        self.flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{compute_all_products, select_results};
    use crate::linalg::ops;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn all_workers_decode_trivially() {
        let code = ProductCode::new(3, 2, 3, 2).unwrap();
        let mut r = Rng::new(1);
        let a = random_matrix(&mut r, 8, 3);
        let x = random_matrix(&mut r, 3, 1);
        let expect = ops::matmul(&a, &x);
        let shards = code.encode(&a).unwrap();
        assert_eq!(shards.len(), 9);
        let all = compute_all_products(&shards, &x);
        let out = code.decode(&all, 8).unwrap();
        assert!(out.result.max_abs_diff(&expect) < 1e-8);
    }

    #[test]
    fn peeling_recovers_nontrivial_pattern() {
        // 3x3 grid, (3,2)x(3,2): erase two entries of row 0. The row
        // itself is stuck (1 < k1 = 2 known), but columns 0 and 1 each
        // still have 2 ≥ k2 entries, so column decoding peels row 0 back.
        let code = ProductCode::new(3, 2, 3, 2).unwrap();
        let mut r = Rng::new(2);
        let a = random_matrix(&mut r, 8, 3);
        let x = random_matrix(&mut r, 3, 2);
        let expect = ops::matmul(&a, &x);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let missing = [code.flat_index(0, 0), code.flat_index(0, 1)];
        let present: Vec<usize> = (0..9).filter(|w| !missing.contains(w)).collect();
        assert!(code.can_decode(&present));
        let out = code.decode(&select_results(&all, &present), 8).unwrap();
        assert!(out.result.max_abs_diff(&expect) < 1e-8);
        assert!(out.flops > 0);
    }

    #[test]
    fn square_corner_erasure_is_stuck_even_small() {
        // The 2x2 systematic-corner erasure defeats peeling in a
        // (3,2)x(3,2) product code: every affected row and column has
        // only 1 surviving entry among the erased coordinates. (The
        // hierarchical code fails on this pattern too — two groups each
        // lost 2 of 3 workers; its advantage is decode *cost*, §IV, not
        // erasure-pattern coverage.)
        let prod = ProductCode::new(3, 2, 3, 2).unwrap();
        let missing = [
            prod.flat_index(0, 0),
            prod.flat_index(0, 1),
            prod.flat_index(1, 0),
            prod.flat_index(1, 1),
        ];
        let present: Vec<usize> = (0..9).filter(|w| !missing.contains(w)).collect();
        assert!(!prod.can_decode(&present));
        use crate::coding::HierarchicalCode;
        let hier = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        assert!(!hier.can_decode(&present));
    }

    #[test]
    fn stuck_pattern_detected() {
        // (3,2)x(3,2): a 2x2 erased square spanning parity row+col can
        // still peel, but erasing a full row + a full column minus
        // nothing... craft a genuinely stuck pattern: erase 2 entries in
        // each of rows 0,1 and cols 0,1 such that every row and column
        // has exactly 1 known entry among the first two — use the
        // diagonal pattern on a (2,1)x(2,1)... simpler: (4,3)x(4,3) with
        // a 2x2 erased block: rows with 2 erasures have only 2 < 3
        // known... wait n1=4, erasing 2 leaves 2 < k1=3. Columns same.
        let code = ProductCode::new(4, 3, 4, 3).unwrap();
        let mut present: Vec<usize> = (0..16).collect();
        // Erase the 2x2 block at rows {0,1} x cols {0,1}.
        present.retain(|&w| {
            let (i, j) = code.grid_pos(w);
            !(i < 2 && j < 2)
        });
        assert!(
            !code.can_decode(&present),
            "2x2 erasure in a (4,3)x(4,3) product code must be stuck"
        );
        // But an MDS code with the same n, k could decode 12 ≥ 9 shards —
        // the classic product-code deficiency.
        let mds = MdsCode::new(16, 9).unwrap();
        assert!(mds.can_decode(&present));
    }

    #[test]
    fn insufficient_errors_cleanly() {
        let code = ProductCode::new(3, 2, 3, 2).unwrap();
        let mut r = Rng::new(3);
        let a = random_matrix(&mut r, 4, 2);
        let x = random_matrix(&mut r, 2, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let present = [
            code.flat_index(0, 0),
            code.flat_index(1, 1),
            code.flat_index(2, 2),
        ];
        let err = code.decode(&select_results(&all, &present), 4);
        assert!(matches!(err, Err(Error::Insufficient { .. })));
    }

    #[test]
    fn matches_hierarchical_data_layout() {
        // Product and hierarchical codes use the same A block layout, so
        // they must agree on A·x exactly.
        use crate::coding::HierarchicalCode;
        let mut r = Rng::new(4);
        let a = random_matrix(&mut r, 12, 4);
        let x = random_matrix(&mut r, 4, 1);
        let prod = ProductCode::new(3, 2, 3, 2).unwrap();
        let hier = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        let ps = prod.encode(&a).unwrap();
        let hs = hier.encode(&a).unwrap();
        let pall = compute_all_products(&ps, &x);
        let hall = compute_all_products(&hs, &x);
        let po = prod.decode(&pall, 12).unwrap();
        let ho = hier.decode(&hall, 12).unwrap();
        assert!(po.result.max_abs_diff(&ho.result) < 1e-8);
    }

    #[test]
    fn property_random_erasures() {
        check("product peeling correct when feasible", 15, |g| {
            let mut r = Rng::new(g.usize_in(0..1 << 30) as u64);
            let code = ProductCode::new(3, 2, 3, 2).unwrap();
            let a = random_matrix(&mut r, 8, 2);
            let x = random_matrix(&mut r, 2, 1);
            let expect = ops::matmul(&a, &x);
            let shards = code.encode(&a).unwrap();
            let all = compute_all_products(&shards, &x);
            let keep = g.usize_in(4..10);
            let present = g.subset(9, keep);
            if code.can_decode(&present) {
                let out = code.decode(&select_results(&all, &present), 8).unwrap();
                assert!(out.result.max_abs_diff(&expect) < 1e-7);
            } else {
                assert!(code.decode(&select_results(&all, &present), 8).is_err());
            }
        });
    }
}
