//! §IV / Table I cost models: decoding cost `T_dec` and computing time
//! `T_comp` for every scheme, plus the total-execution-time tradeoff
//! `T_exec = T_comp + α · T_dec` behind Fig. 7.
//!
//! The decoding-cost models treat the `O(·)` expressions of Table I as
//! exact (unit constant) — matching how the paper evaluates Fig. 7 —
//! while [`measured`] computes real flop counts from the implemented
//! decoders so the *shape* of the model (who is cheaper, by what order)
//! can be validated empirically (bench `decode_scaling`).

use crate::util::harmonic::{expected_kth_of_n_exponential, harmonic};

/// The four schemes of Table I.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// `(n, k)` replication (uncoded).
    Replication,
    /// The paper's `(n1,k1)×(n2,k2)` hierarchical code.
    Hierarchical,
    /// `(n1,k1)×(n2,k2)` product code.
    Product,
    /// `(n, k)` polynomial code.
    Polynomial,
}

impl Scheme {
    /// All schemes, Fig. 7's display order.
    pub const ALL: [Scheme; 4] = [
        Scheme::Replication,
        Scheme::Hierarchical,
        Scheme::Product,
        Scheme::Polynomial,
    ];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Replication => "replication",
            Scheme::Hierarchical => "hierarchical",
            Scheme::Product => "product",
            Scheme::Polynomial => "polynomial",
        }
    }
}

/// Decoding cost `T_dec` per Table I (unit-constant `O(·)`):
///
/// * replication: `0`
/// * hierarchical: `k1^β + k1·k2^β` — one inner decode's worth
///   (intra-group decodes run in parallel on the submasters, so only one
///   `k1^β` is on the critical path) plus the outer decode over `k1`
///   result sub-blocks.
/// * product: `k1·k2^β + k2·k1^β` — all row and column decodes land on
///   the master.
/// * polynomial: `k^β = (k1·k2)^β = k1^β·k2^β`.
pub fn decoding_cost(scheme: Scheme, k1: f64, k2: f64, beta: f64) -> f64 {
    match scheme {
        Scheme::Replication => 0.0,
        Scheme::Hierarchical => k1.powf(beta) + k1 * k2.powf(beta),
        Scheme::Product => k1 * k2.powf(beta) + k2 * k1.powf(beta),
        Scheme::Polynomial => (k1 * k2).powf(beta),
    }
}

/// Computing time `T_comp` per Table I for the **non-hierarchical**
/// schemes, where every worker ships its result to the master over a
/// cross-rack (ToR) link of rate `mu2`:
///
/// * replication: `k·H_k / (n·µ2)` — each block completes at the min of
///   its `n/k` replicas (`Exp(n·µ2/k)`), all `k` blocks must finish.
/// * product: `(1/µ2)·log( (√(n/k) + ⁴√(n/k)) / (√(n/k) − 1) )`
///   (Lee–Suh–Ramchandran's asymptotic for the `n/k → const` regime).
/// * polynomial: `(H_n − H_{n−k}) / µ2` — k-th order statistic of n.
///
/// The hierarchical scheme's `T_comp = E[T]` has no closed form; obtain
/// it from [`crate::sim::montecarlo`] or bound it via
/// [`crate::sim::markov`] / [`crate::sim::bounds`].
pub fn computing_time(scheme: Scheme, n: usize, k: usize, mu2: f64) -> Option<f64> {
    assert!(k >= 1 && k <= n && mu2 > 0.0);
    match scheme {
        Scheme::Replication => {
            Some(k as f64 * harmonic(k) / (n as f64 * mu2))
        }
        Scheme::Product => {
            let ratio = n as f64 / k as f64;
            if ratio <= 1.0 {
                return None; // formula requires redundancy n > k
            }
            let s = ratio.sqrt();
            let q = ratio.powf(0.25);
            Some((1.0 / mu2) * ((s + q) / (s - 1.0)).ln())
        }
        Scheme::Polynomial => Some(expected_kth_of_n_exponential(k, n, mu2)),
        Scheme::Hierarchical => None, // needs simulation / bounds
    }
}

/// Total execution time `T_exec = T_comp + α·T_dec` (§IV). `t_comp` for
/// the hierarchical scheme comes from simulation; for the others from
/// [`computing_time`].
pub fn execution_time(t_comp: f64, alpha: f64, t_dec: f64) -> f64 {
    t_comp + alpha * t_dec
}

/// Measured decode flops from the real implementations (used by the
/// `decode_scaling` bench to validate the §IV models).
pub mod measured {
    use crate::coding::{
        compute_all_products, CodedScheme, HierarchicalCode, PolynomialCode, ProductCode,
    };
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;
    use crate::Result;

    /// Decode-flops for one full decode of each scheme at parameters
    /// `(n1, k1, n2, k2)` with `rows × 1` data, erasing all parity-free
    /// shortcuts by dropping the first `drop` workers.
    pub fn decode_flops(
        n1: usize,
        k1: usize,
        n2: usize,
        k2: usize,
        rows: usize,
        drop: usize,
        seed: u64,
    ) -> Result<(u64, u64, u64)> {
        let mut r = Rng::new(seed);
        let a = Matrix::from_fn(rows, 4, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(4, 1, |_, _| r.uniform(-1.0, 1.0));

        let hier = HierarchicalCode::homogeneous(n1, k1, n2, k2)?;
        let prod = ProductCode::new(n1, k1, n2, k2)?;
        let poly = PolynomialCode::new(n1 * n2, k1 * k2)?;

        let run = |code: &dyn CodedScheme| -> Result<u64> {
            let shards = code.encode(&a)?;
            let all = compute_all_products(&shards, &x);
            // Drop the first `drop` workers (forces parity decodes).
            let subset: Vec<_> = all.into_iter().skip(drop).collect();
            Ok(code.decode(&subset, rows)?.flops)
        };
        Ok((run(&hier)?, run(&prod)?, run(&poly)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replication_decodes_free() {
        assert_eq!(decoding_cost(Scheme::Replication, 400.0, 20.0, 2.0), 0.0);
    }

    #[test]
    fn table1_ordering_at_paper_params() {
        // (n1,k1)=(800,400), (n2,k2)=(40,20), β=2 — §IV's Fig. 7 setting.
        let (k1, k2, beta) = (400.0, 20.0, 2.0);
        let h = decoding_cost(Scheme::Hierarchical, k1, k2, beta);
        let p = decoding_cost(Scheme::Product, k1, k2, beta);
        let y = decoding_cost(Scheme::Polynomial, k1, k2, beta);
        assert!(h < p, "hier {h} must beat product {p}");
        assert!(p < y, "product {p} must beat polynomial {y}");
        // Hier = k1² + k1·k2² = 160000 + 160000 = 320000.
        assert!((h - 320_000.0).abs() < 1e-6);
        // Product = k1·k2² + k2·k1² = 160000 + 3.2e6.
        assert!((p - 3_360_000.0).abs() < 1e-6);
        // Poly = (k1·k2)² = 64e6.
        assert!((y - 64_000_000.0).abs() < 1e-3);
    }

    #[test]
    fn sec4_gain_grows_with_p() {
        // §IV: with k1 = k2^p, hier/product gain increases in p.
        let beta = 2.0;
        let k2: f64 = 4.0;
        let mut prev_gain = 0.0;
        for p in [1.0, 1.5, 2.0, 2.5] {
            let k1 = k2.powf(p);
            let h = decoding_cost(Scheme::Hierarchical, k1, k2, beta);
            let pr = decoding_cost(Scheme::Product, k1, k2, beta);
            let gain = pr / h;
            assert!(
                gain > prev_gain,
                "gain must grow with p: p={p} gain={gain} prev={prev_gain}"
            );
            prev_gain = gain;
        }
    }

    #[test]
    fn sec4_example_orders() {
        // β=2, k1=k2²: hier O(k2⁴) vs product O(k2⁵).
        let beta = 2.0;
        for k2 in [4.0, 8.0, 16.0] {
            let k1 = k2 * k2;
            let h = decoding_cost(Scheme::Hierarchical, k1, k2, beta);
            let p = decoding_cost(Scheme::Product, k1, k2, beta);
            // h = k2⁴ + k2⁴ = 2·k2⁴; p = k2⁴ + k2⁵.
            assert!((h - 2.0 * k2.powi(4)).abs() < 1e-6);
            assert!((p - (k2.powi(4) + k2.powi(5))).abs() < 1e-6);
        }
    }

    #[test]
    fn computing_time_formulas() {
        let (n, k, mu2) = (8000, 8000 / 2, 1.0);
        // Replication.
        let rep = computing_time(Scheme::Replication, n, k, mu2).unwrap();
        assert!(rep > 0.0 && rep.is_finite());
        // Polynomial = (H_n - H_{n-k})/mu2 ≈ log(n/(n-k)) = log 2.
        let poly = computing_time(Scheme::Polynomial, n, k, mu2).unwrap();
        assert!((poly - (2.0f64).ln()).abs() < 1e-3, "poly {poly}");
        // Product formula finite for n > k.
        let prod = computing_time(Scheme::Product, n, k, mu2).unwrap();
        assert!(prod > 0.0 && prod.is_finite());
        // Hierarchical has no closed form.
        assert!(computing_time(Scheme::Hierarchical, n, k, mu2).is_none());
        // Product undefined at n == k.
        assert!(computing_time(Scheme::Product, 10, 10, 1.0).is_none());
    }

    #[test]
    fn measured_flops_respect_model_ordering() {
        // Small but parity-forcing decode: hier < product < polynomial.
        let (h, p, y) = measured::decode_flops(6, 3, 4, 2, 24, 3, 7).unwrap();
        assert!(h > 0 && p > 0 && y > 0);
        assert!(h < y, "hier {h} must beat polynomial {y}");
        assert!(p < y, "product {p} must beat polynomial {y}");
    }

    #[test]
    fn execution_time_linear_in_alpha() {
        let t = execution_time(2.0, 0.5, 10.0);
        assert!((t - 7.0).abs() < 1e-12);
    }
}
