//! Erasure-coded computation schemes.
//!
//! All schemes share one task model (§II-A of the paper): compute
//! `y = A·x` (or batched `Y = A·X`) for `A ∈ R^{m×d}` by assigning each
//! worker a coded shard `Â` of `A`; the worker computes `Â·x` and the
//! decoder reconstructs `A·x` from a sufficient subset of results.
//!
//! * [`mds`] — flat `(n, k)` systematic MDS coded computation
//!   (Lee et al., 2017), the building block;
//! * [`hierarchical`] — **the paper's contribution**: an inner
//!   `(n1, k1)` code per group concatenated with an outer `(n2, k2)`
//!   code across groups, decoded in parallel (§II-A, §IV);
//! * [`replication`] — uncoded `(n/k)`-way replication baseline;
//! * [`product`] — the product code of Lee–Suh–Ramchandran (ISIT'17)
//!   with an iterative peeling decoder;
//! * [`polynomial`] — the polynomial code of Yu–Maddah-Ali–Avestimehr
//!   (NIPS'17), decoded by (Vandermonde) interpolation;
//! * [`cost`] — the §IV / Table I decoding-cost models `O(k^β)` and the
//!   measured-flop accounting used to validate them.

pub mod cost;
pub mod hierarchical;
pub mod mds;
pub mod polynomial;
pub mod product;
pub mod replication;

pub use hierarchical::{HierarchicalCode, HierarchicalParams};
pub use mds::MdsCode;
pub use polynomial::PolynomialCode;
pub use product::ProductCode;
pub use replication::ReplicationCode;

use crate::linalg::Matrix;
use crate::Result;

/// A worker's computed result: `shard_index` identifies which coded
/// shard it holds, `data` is `Â_shard · X` (`rows × batch` matrix).
#[derive(Clone, Debug)]
pub struct WorkerResult {
    /// Global shard/worker index in `[0, num_workers)`.
    pub shard: usize,
    /// The shard-local product.
    pub data: Matrix,
}

/// Output of a decode: the reconstructed `A·X` plus the exact flop
/// count spent decoding (the paper's `T_dec` is proportional to this).
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Reconstructed product, `m × batch`.
    pub result: Matrix,
    /// Flops spent in the decode itself (not the workers' products).
    pub flops: u64,
    /// Wall-clock decode time in seconds (single measurement).
    pub seconds: f64,
}

/// A coded-computation scheme: how to shard/encode `A`, which worker
/// subsets suffice, and how to decode their results.
pub trait CodedScheme: Send + Sync {
    /// Human-readable name (used in figures and CSV output).
    fn name(&self) -> String;

    /// Total number of workers/shards `n`.
    fn num_workers(&self) -> usize;

    /// Number of data blocks `k` (the recovery threshold for MDS-type
    /// schemes; pattern-dependent schemes may need more).
    fn num_data_blocks(&self) -> usize;

    /// Rows of `A` must be divisible by this for equal sharding.
    fn row_divisor(&self) -> usize;

    /// Encode `A` into one shard per worker.
    fn encode(&self, a: &Matrix) -> Result<Vec<Matrix>>;

    /// Can the scheme decode from exactly this set of worker indices?
    fn can_decode(&self, present: &[usize]) -> bool;

    /// Decode `A·X` (`m = out_rows` rows) from worker results.
    fn decode(&self, results: &[WorkerResult], out_rows: usize) -> Result<DecodeOutput>;
}

/// Compute every worker's product for a given encode — the "all workers
/// finished" reference path used by tests and benches.
pub fn compute_all_products(shards: &[Matrix], x: &Matrix) -> Vec<WorkerResult> {
    shards
        .iter()
        .enumerate()
        .map(|(i, s)| WorkerResult {
            shard: i,
            data: crate::linalg::ops::matmul(s, x),
        })
        .collect()
}

/// Select a subset of results by worker index.
pub fn select_results(all: &[WorkerResult], idx: &[usize]) -> Vec<WorkerResult> {
    idx.iter().map(|&i| all[i].clone()).collect()
}
