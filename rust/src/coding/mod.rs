//! Erasure-coded computation schemes.
//!
//! All schemes share one task model (§II-A of the paper): compute
//! `y = A·x` (or batched `Y = A·X`) for `A ∈ R^{m×d}` by assigning each
//! worker a coded shard `Â` of `A`; the worker computes `Â·x` and the
//! decoder reconstructs `A·x` from a sufficient subset of results.
//!
//! * [`mds`] — flat `(n, k)` systematic MDS coded computation
//!   (Lee et al., 2017), the building block;
//! * [`hierarchical`] — **the paper's contribution**: an inner
//!   `(n1, k1)` code per group concatenated with an outer `(n2, k2)`
//!   code across groups, decoded in parallel (§II-A, §IV);
//! * [`replication`] — uncoded `(n/k)`-way replication baseline;
//! * [`product`] — the product code of Lee–Suh–Ramchandran (ISIT'17)
//!   with an iterative peeling decoder;
//! * [`polynomial`] — the polynomial code of Yu–Maddah-Ali–Avestimehr
//!   (NIPS'17), decoded by (Vandermonde) interpolation;
//! * [`cost`] — the §IV / Table I decoding-cost models `O(k^β)` and the
//!   measured-flop accounting used to validate them.
//!
//! # Streaming decode sessions
//!
//! The paper's headline result (§IV, Table I) is that hierarchical
//! coding wins because decode work can start *incrementally* — each
//! group is eliminated the instant its `k1`-th result lands, instead of
//! after all results are collected. The public decode API is therefore a
//! stateful session: [`CodedScheme::decoder`] opens a [`Decoder`],
//! results are fed one at a time with [`Decoder::push`] (which reports
//! [`DecodeProgress`]), and [`Decoder::finish`] produces the
//! [`DecodeOutput`] once the session is ready. Batch
//! [`CodedScheme::decode`] is a provided method that *replays* the
//! result slice through a session, so the batch path, the live
//! coordinator, the simulator and the figures all account decode work
//! through the same code — they cannot drift apart.

pub mod cost;
pub mod hierarchical;
pub mod mds;
pub mod polynomial;
pub mod product;
pub mod replication;

pub use hierarchical::{HierarchicalCode, HierarchicalParams};
pub use mds::{MdsCode, MdsDecoder};
pub use polynomial::PolynomialCode;
pub use product::ProductCode;
pub use replication::ReplicationCode;

use crate::linalg::Matrix;
use crate::parallel::DecodePool;
use crate::scenario::Topology;
use crate::{Error, Result};
use std::sync::Arc;

/// Reusable scratch for decode sessions: the `k×k` generator submatrix,
/// the gathered right-hand sides, the solve's panel buffer and the
/// index workspace. A session owns one and threads it through every
/// `push`/`finish` elimination, so a decoder that sees the same shapes
/// every job (the steady state of a serving cluster) performs no
/// allocations beyond its output matrix.
#[derive(Debug)]
pub struct DecodeScratch {
    /// Generator submatrix of the responding workers.
    pub(crate) gsub: Matrix,
    /// Stacked right-hand sides (`k × block_elems`).
    pub(crate) rhs: Matrix,
    /// Panel buffer for [`crate::linalg::LuFactors::solve_matrix_with`].
    pub(crate) solve_buf: Vec<f64>,
    /// Sorted surviving-index workspace — doubles as the erasure-pattern
    /// cache key (see [`crate::linalg::LuCache`]).
    pub(crate) idx: Vec<usize>,
    /// Canonical-order permutation: `perm[bi]` is the arrival slot whose
    /// shard index ranks `bi`-th ascending.
    pub(crate) perm: Vec<usize>,
}

impl DecodeScratch {
    /// Fresh, empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        Self {
            gsub: Matrix::zeros(0, 0),
            rhs: Matrix::zeros(0, 0),
            solve_buf: Vec::new(),
            idx: Vec::new(),
            perm: Vec::new(),
        }
    }
}

impl Default for DecodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A worker's computed result: `shard_index` identifies which coded
/// shard it holds, `data` is `Â_shard · X` (`rows × batch` matrix).
#[derive(Clone, Debug)]
pub struct WorkerResult {
    /// Global shard/worker index in `[0, num_workers)`.
    pub shard: usize,
    /// The shard-local product.
    pub data: Matrix,
}

/// Output of a decode: the reconstructed `A·X` plus the exact flop
/// count spent decoding (the paper's `T_dec` is proportional to this).
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Reconstructed product, `m × batch`.
    pub result: Matrix,
    /// Flops spent in the decode itself (not the workers' products),
    /// across the whole session (`push` calls and `finish`).
    pub flops: u64,
    /// Wall-clock seconds spent inside the decode session (summed over
    /// `push` calls and `finish`).
    pub seconds: f64,
}

/// Progress of a streaming decode session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeProgress {
    /// Not decodable yet. `still_needed` is a lower bound on how many
    /// further (distinct) results must arrive before the session can
    /// become ready.
    NeedMore {
        /// Lower bound on further results needed.
        still_needed: usize,
    },
    /// The session can produce the output now; call [`Decoder::finish`].
    Ready,
}

impl DecodeProgress {
    /// True once the session is decodable.
    pub fn is_ready(&self) -> bool {
        matches!(self, DecodeProgress::Ready)
    }
}

/// A stateful streaming decode session (see the module docs).
///
/// Contract:
/// * `push` is idempotent per shard index — duplicates are ignored —
///   and results arriving after `Ready` are ignored (the "fastest `k`"
///   semantics of the paper: late stragglers are discarded).
/// * Incremental schemes do real elimination work *inside* `push`
///   (e.g. the hierarchical code decodes a group at its `k1`-th
///   arrival), so the work left for `finish` — the post-last-arrival
///   latency — is minimal.
/// * `finish` is single-shot: it consumes the session's state and
///   returns the reconstructed product with total session flops and
///   wall-clock seconds. Calling it before `Ready` yields
///   [`Error::Insufficient`].
pub trait Decoder: Send {
    /// Feed one worker result.
    fn push(&mut self, result: WorkerResult) -> Result<DecodeProgress>;

    /// Current progress, without feeding anything.
    fn progress(&self) -> DecodeProgress;

    /// Complete the decode and return the output (single-shot).
    fn finish(&mut self) -> Result<DecodeOutput>;

    /// Decode flops already spent inside `push` calls — the work the
    /// streaming session has taken off the critical path.
    fn flops_so_far(&self) -> u64;
}

/// A coded-computation scheme: how to shard/encode `A`, which worker
/// subsets suffice, and how to decode their results.
pub trait CodedScheme: Send + Sync {
    /// Human-readable name (used in figures and CSV output).
    fn name(&self) -> String;

    /// Total number of workers/shards `n`.
    fn num_workers(&self) -> usize;

    /// Number of data blocks `k` (the recovery threshold for MDS-type
    /// schemes; pattern-dependent schemes may need more).
    fn num_data_blocks(&self) -> usize;

    /// Rows of `A` must be divisible by this for equal sharding.
    fn row_divisor(&self) -> usize;

    /// Encode `A` into one shard per worker.
    fn encode(&self, a: &Matrix) -> Result<Vec<Matrix>>;

    /// Can the scheme decode from exactly this set of worker indices?
    fn can_decode(&self, present: &[usize]) -> bool;

    /// Open a streaming decode session producing the `out_rows × batch`
    /// product. `batch` is the number of columns of `X` (a sizing hint;
    /// sessions accept whatever column count the first result carries).
    fn decoder(&self, out_rows: usize, batch: usize) -> Box<dyn Decoder>;

    /// Batch decode, defined as a *replay* of the streaming session:
    /// results are pushed in slice order until the session is ready
    /// (later entries are the discarded stragglers), then finished.
    /// This is a provided method so batch and streaming paths cannot
    /// disagree on result or flop accounting.
    fn decode(&self, results: &[WorkerResult], out_rows: usize) -> Result<DecodeOutput> {
        let batch = results.first().map(|r| r.data.cols()).unwrap_or(1);
        let mut session = self.decoder(out_rows, batch);
        for r in results {
            if session.push(r.clone())?.is_ready() {
                break;
            }
        }
        session.finish()
    }

    /// Two-tier cluster topology: the full scenario-layer
    /// [`Topology`] — per-group worker counts, recovery thresholds and
    /// straggler profiles, in flat-index order. Defaults to one relay
    /// group holding every worker with recovery threshold `k` and the
    /// paper's default straggler profile; schemes built from a custom
    /// scenario return that scenario verbatim, so the coordinator and
    /// the simulator run the exact same value.
    fn topology(&self) -> Topology {
        Topology::single_group(self.num_workers(), self.num_data_blocks())
    }

    /// Group-local decode session for submaster `group`, or `None` if
    /// this scheme's decode cannot be split across submasters (the
    /// submaster then relays raw results to the master — the §IV
    /// contrast with the hierarchical code). Sessions consume results
    /// indexed by *in-group* worker index and produce that group's
    /// share of the output. `out_rows` is the full output height.
    fn group_decoder(
        &self,
        _group: usize,
        _out_rows: usize,
        _batch: usize,
    ) -> Option<Box<dyn Decoder>> {
        None
    }

    /// Master-side decode session. For schemes with group decoding the
    /// session consumes group partials (`shard` = group index); for the
    /// rest it consumes raw worker results (`shard` = flat worker
    /// index) and defaults to [`CodedScheme::decoder`].
    fn master_decoder(&self, out_rows: usize, batch: usize) -> Box<dyn Decoder> {
        self.decoder(out_rows, batch)
    }

    /// Every erasure-pattern LU cache this scheme's decoders consult
    /// (one per constituent code for the hierarchical scheme). Empty for
    /// schemes built without caches or whose decode has no `k×k` solve
    /// to memoize (replication, product peeling). The coordinator uses
    /// this to aggregate hit/miss metrics and to invalidate on model
    /// re-registration or shard re-shipping.
    fn decode_caches(&self) -> Vec<Arc<crate::linalg::LuCache>> {
        Vec::new()
    }
}

/// The five scheme families the crate implements, as a parseable enum —
/// the registry behind `config.code.scheme` and the CLI `--scheme`
/// flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// The paper's `(n1,k1)×(n2,k2)` hierarchical code.
    Hierarchical,
    /// Flat `(n1·n2, k1·k2)` systematic MDS code.
    Mds,
    /// `(n1,k1)×(n2,k2)` product code.
    Product,
    /// `(n1·n2, k1·k2)` replication.
    Replication,
    /// `(n1·n2, k1·k2)` polynomial code.
    Polynomial,
}

impl SchemeKind {
    /// Every scheme, in the paper's comparison order.
    pub const ALL: [SchemeKind; 5] = [
        SchemeKind::Hierarchical,
        SchemeKind::Mds,
        SchemeKind::Product,
        SchemeKind::Replication,
        SchemeKind::Polynomial,
    ];

    /// Canonical lower-case name.
    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Hierarchical => "hierarchical",
            SchemeKind::Mds => "mds",
            SchemeKind::Product => "product",
            SchemeKind::Replication => "replication",
            SchemeKind::Polynomial => "polynomial",
        }
    }

    /// Parse a scheme name (as used in config files and `--scheme`).
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "hierarchical" | "hier" => Ok(SchemeKind::Hierarchical),
            "mds" => Ok(SchemeKind::Mds),
            "product" | "prod" => Ok(SchemeKind::Product),
            "replication" | "rep" => Ok(SchemeKind::Replication),
            "polynomial" | "poly" => Ok(SchemeKind::Polynomial),
            other => Err(Error::InvalidParams(format!(
                "unknown scheme '{other}' \
                 (expected hierarchical|mds|product|replication|polynomial)"
            ))),
        }
    }
}

impl std::fmt::Display for SchemeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Build a scheme from the common `(n1,k1)×(n2,k2)` grid parameters.
/// Grid schemes use them directly; flat schemes use `n = n1·n2`,
/// `k = k1·k2` — the same worker count and recovery threshold, so the
/// comparison is apples-to-apples (§IV). Decoders run serially; use
/// [`build_scheme_with`] to attach a decode pool.
pub fn build_scheme(
    kind: SchemeKind,
    n1: usize,
    k1: usize,
    n2: usize,
    k2: usize,
) -> Result<Arc<dyn CodedScheme>> {
    build_scheme_with(kind, n1, k1, n2, k2, 1)
}

/// [`build_scheme`] with `decode_threads` wired through: every decoder
/// session the scheme opens (group, master, or standalone) fans its
/// eliminations across a [`DecodePool`] of this width (`0` = all
/// available cores). Parallel decode output is bit-identical to serial
/// — the pool only changes wall-clock, never results (the determinism
/// suite in `tests/parallel_determinism.rs` enforces this).
pub fn build_scheme_with(
    kind: SchemeKind,
    n1: usize,
    k1: usize,
    n2: usize,
    k2: usize,
    decode_threads: usize,
) -> Result<Arc<dyn CodedScheme>> {
    build_scheme_topology(kind, &Topology::homogeneous(n1, k1, n2, k2), decode_threads)
}

/// Build a scheme from a scenario-layer [`Topology`] — the one
/// construction path `ClusterConfig::build_scheme` uses, so the
/// expanded per-group view drives every layer. The hierarchical code
/// consumes the topology directly (per-group generators and decoder
/// sessions sized by `k1_g`); the flat and grid baselines require a
/// uniform code (`groups` with distinct `(n1_g, k1_g)` only make sense
/// for the scheme whose decode is per-group).
pub fn build_scheme_topology(
    kind: SchemeKind,
    topo: &Topology,
    decode_threads: usize,
) -> Result<Arc<dyn CodedScheme>> {
    topo.validate()?;
    let pool = Arc::new(DecodePool::new(decode_threads)?);
    if kind != SchemeKind::Hierarchical && !topo.is_uniform_code() {
        return Err(Error::InvalidParams(format!(
            "{kind}: heterogeneous per-group (n1,k1) specs require the \
             hierarchical scheme"
        )));
    }
    if kind != SchemeKind::Hierarchical && topo.groups.iter().any(|g| g.subtasks > 1) {
        // Same never-silently-dropped rule as heterogeneous specs: the
        // flat schemes have no per-group inner code to layer sub-tasks
        // on, so accepting the topology would discard its partial-work
        // profile.
        return Err(Error::InvalidParams(format!(
            "{kind}: partial-work sub-tasks (subtasks > 1) require the \
             hierarchical scheme"
        )));
    }
    let (n1, k1) = (topo.groups[0].n1, topo.groups[0].k1);
    let (n2, k2) = (topo.n2(), topo.k2);
    // This is the serving construction path (cluster, simulator, CLI),
    // so schemes with a k×k solve get an erasure-pattern LU cache —
    // repeat straggler patterns then skip refactorization. Bare
    // `MdsCode::new`-style constructors stay uncached.
    Ok(match kind {
        SchemeKind::Hierarchical => Arc::new(
            HierarchicalCode::from_topology(topo.clone())?
                .with_pool(pool)
                .with_decode_caches(),
        ),
        SchemeKind::Mds => Arc::new(
            MdsCode::new(n1 * n2, k1 * k2)?
                .with_pool(pool)
                .with_cache(Arc::new(crate::linalg::LuCache::default())),
        ),
        SchemeKind::Product => Arc::new(ProductCode::new(n1, k1, n2, k2)?.with_pool(pool)),
        SchemeKind::Replication => Arc::new(ReplicationCode::new(n1 * n2, k1 * k2)?),
        SchemeKind::Polynomial => Arc::new(
            PolynomialCode::new(n1 * n2, k1 * k2)?
                .with_pool(pool)
                .with_cache(Arc::new(crate::linalg::LuCache::default())),
        ),
    })
}

/// Shared collect-any-`k`-distinct core for MDS-type sessions: tracks
/// the first `k` distinct shard indices pushed, ignoring duplicates and
/// everything after the `k`-th (the discarded stragglers).
pub(crate) struct GatherK {
    n: usize,
    k: usize,
    pub(crate) got: Vec<(usize, Matrix)>,
    seen: Vec<bool>,
}

impl GatherK {
    pub(crate) fn new(n: usize, k: usize) -> Self {
        Self {
            n,
            k,
            got: Vec::with_capacity(k),
            seen: vec![false; n],
        }
    }

    pub(crate) fn push(&mut self, shard: usize, data: Matrix) -> Result<DecodeProgress> {
        if shard >= self.n {
            return Err(Error::InvalidParams(format!(
                "shard index {shard} out of n={}",
                self.n
            )));
        }
        if self.got.len() < self.k && !self.seen[shard] {
            self.seen[shard] = true;
            self.got.push((shard, data));
        }
        Ok(self.progress())
    }

    pub(crate) fn progress(&self) -> DecodeProgress {
        if self.got.len() >= self.k {
            DecodeProgress::Ready
        } else {
            DecodeProgress::NeedMore {
                still_needed: self.k - self.got.len(),
            }
        }
    }
}

/// Compute every worker's product for a given encode — the "all workers
/// finished" reference path used by tests and benches.
pub fn compute_all_products(shards: &[Matrix], x: &Matrix) -> Vec<WorkerResult> {
    shards
        .iter()
        .enumerate()
        .map(|(i, s)| WorkerResult {
            shard: i,
            data: crate::linalg::ops::matmul(s, x),
        })
        .collect()
}

/// Select a subset of results by worker index.
pub fn select_results(all: &[WorkerResult], idx: &[usize]) -> Vec<WorkerResult> {
    idx.iter().map(|&i| all[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_kind_parses_names_and_aliases() {
        for kind in SchemeKind::ALL {
            assert_eq!(SchemeKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(SchemeKind::parse("hier").unwrap(), SchemeKind::Hierarchical);
        assert_eq!(SchemeKind::parse("POLY").unwrap(), SchemeKind::Polynomial);
        assert!(SchemeKind::parse("raptor").is_err());
    }

    #[test]
    fn build_scheme_matches_grid_parameters() {
        for kind in SchemeKind::ALL {
            let s = build_scheme(kind, 4, 2, 4, 2).unwrap();
            assert_eq!(s.num_workers(), 16, "{}", s.name());
        }
        // Replication needs k | n: 3·3 = 9 workers, k = 4 does not divide.
        assert!(build_scheme(SchemeKind::Replication, 3, 2, 3, 2).is_err());
    }

    #[test]
    fn build_scheme_topology_rejects_subtasks_for_flat_schemes() {
        // Partial-work layering is per-group: a flat scheme accepting a
        // multi-round topology would silently drop its profile.
        let mut topo = Topology::homogeneous(4, 2, 4, 2);
        topo.groups[0].subtasks = 2;
        for kind in SchemeKind::ALL {
            let built = build_scheme_topology(kind, &topo, 1);
            if kind == SchemeKind::Hierarchical {
                assert!(built.is_ok(), "{kind}");
            } else {
                assert!(built.is_err(), "{kind} must reject sub-tasks");
            }
        }
    }

    #[test]
    fn gather_k_ignores_duplicates_and_extras() {
        let mut g = GatherK::new(5, 2);
        let m = Matrix::zeros(1, 1);
        assert_eq!(
            g.push(3, m.clone()).unwrap(),
            DecodeProgress::NeedMore { still_needed: 1 }
        );
        assert_eq!(g.push(3, m.clone()).unwrap(), DecodeProgress::NeedMore {
            still_needed: 1
        });
        assert_eq!(g.push(0, m.clone()).unwrap(), DecodeProgress::Ready);
        // Extras after ready are ignored.
        assert_eq!(g.push(4, m).unwrap(), DecodeProgress::Ready);
        assert_eq!(g.got.len(), 2);
        assert!(g.push(9, Matrix::zeros(1, 1)).is_err());
    }
}
