//! Flat `(n, k)` systematic MDS coded computation (Lee et al., 2017).
//!
//! `A` is split into `k` equal row-blocks, encoded into `n` coded blocks
//! by a systematic MDS generator; worker `i` computes `Â_i·x`; any `k`
//! results decode via a `k×k` solve. This is both a baseline scheme and
//! the building block the hierarchical code composes at two levels.

use crate::coding::{
    CodedScheme, DecodeOutput, DecodeProgress, DecodeScratch, Decoder, GatherK, WorkerResult,
};
use crate::linalg::{lu::LuFactors, ops, vandermonde, LuCache, Matrix};
use crate::parallel::DecodePool;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Instant;

/// Systematic `(n, k)` MDS code over the reals.
#[derive(Clone, Debug)]
pub struct MdsCode {
    n: usize,
    k: usize,
    /// `n × k` systematic generator `[I; C]`.
    generator: Matrix,
    /// Pool the decode solve fans its column panels across.
    pool: Arc<DecodePool>,
    /// Optional erasure-pattern factor memo (see [`LuCache`]): attached
    /// by the serving construction path, absent on bare codes so unit
    /// semantics (flop accounting per decode) stay warmth-independent.
    cache: Option<Arc<LuCache>>,
}

impl MdsCode {
    /// Construct an `(n, k)` code, `1 <= k <= n`; decodes run serially.
    pub fn new(n: usize, k: usize) -> Result<Self> {
        let generator = vandermonde::systematic_mds(n, k)?;
        Ok(Self {
            n,
            k,
            generator,
            pool: Arc::new(DecodePool::serial()),
            cache: None,
        })
    }

    /// Attach a decode pool: the `k×k` solve's column panels then run
    /// in parallel (bit-identical results, see `parallel`).
    pub fn with_pool(mut self, pool: Arc<DecodePool>) -> Self {
        self.pool = pool;
        self
    }

    /// Attach an erasure-pattern LU cache: repeat surviving-index sets
    /// skip `LuFactors::factorize` entirely. The cache must be private
    /// to this code (factors are generator-specific); sessions cloned
    /// from this code share it, which is exactly what serving wants.
    /// Results are bit-identical with or without the cache — a hit
    /// returns the same factors the canonical sorted-order
    /// factorization would recompute.
    pub fn with_cache(mut self, cache: Arc<LuCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The attached erasure-pattern cache, if any.
    pub fn cache(&self) -> Option<&Arc<LuCache>> {
        self.cache.as_ref()
    }

    /// Code length `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Code dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The `n × k` generator matrix.
    pub fn generator(&self) -> &Matrix {
        &self.generator
    }

    /// Encode `k` equal-shaped blocks into `n` coded blocks:
    /// coded_i = Σ_j G[i][j] · block_j.
    pub fn encode_blocks(&self, blocks: &[Matrix]) -> Result<Vec<Matrix>> {
        if blocks.len() != self.k {
            return Err(Error::InvalidParams(format!(
                "encode_blocks: got {} blocks, code k={}",
                blocks.len(),
                self.k
            )));
        }
        let refs: Vec<&Matrix> = blocks.iter().collect();
        Ok((0..self.n)
            .map(|i| {
                if i < self.k {
                    // Systematic prefix: the block itself (free).
                    blocks[i].clone()
                } else {
                    ops::lincomb(self.generator.row(i), &refs)
                }
            })
            .collect())
    }

    /// Decode the original `k` stacked blocks from any `k` coded blocks
    /// given as `(index, block)` pairs. Returns the blocks and the
    /// flops spent. Convenience wrapper over [`MdsCode::decode_stacked`]
    /// (one-shot scratch, serial solve) for tests and composite codes.
    pub fn decode_blocks(&self, coded: &[(usize, Matrix)]) -> Result<(Vec<Matrix>, u64)> {
        let mut scratch = DecodeScratch::new();
        let (stacked, flops) =
            self.decode_stacked_with(coded, &mut scratch, &DecodePool::serial())?;
        Ok((stacked.split_rows(self.k)?, flops))
    }

    /// Decode straight to the stacked `(k·block_rows) × cols` result
    /// through the code's own pool — the session hot path.
    pub fn decode_stacked(
        &self,
        coded: &[(usize, Matrix)],
        scratch: &mut DecodeScratch,
    ) -> Result<(Matrix, u64)> {
        self.decode_stacked_with(coded, scratch, &self.pool)
    }

    /// Decode core: recover the stacked data from any `k` coded blocks.
    ///
    /// Fast path: if all `k` present indices are systematic, decoding is
    /// a pure reshuffle (0 flops) — this matters for Fig. 7's `α`
    /// tradeoff, where decode cost is the differentiator.
    ///
    /// General path: one `k×k` LU solve whose right-hand side stacks the
    /// coded blocks row-per-block; the solved matrix's row-major storage
    /// *is* the stacked result, so the output needs no per-block copies
    /// or `vstack`. The used blocks are first put into canonical
    /// (ascending shard index) order, so the assembled system — and
    /// every output bit — depends only on *which* shards survived,
    /// never on arrival order; that invariance is what makes the sorted
    /// index list a sound [`LuCache`] key. All intermediates (generator
    /// submatrix, gathered RHS, solve panels) live in `scratch`, reused
    /// across pushes — a session decoding the same shapes every job
    /// allocates nothing but its output. The solve's column panels fan
    /// across `pool`.
    pub fn decode_stacked_with(
        &self,
        coded: &[(usize, Matrix)],
        scratch: &mut DecodeScratch,
        pool: &DecodePool,
    ) -> Result<(Matrix, u64)> {
        if coded.len() < self.k {
            return Err(Error::Insufficient {
                needed: self.k,
                got: coded.len(),
            });
        }
        let use_set = &coded[..self.k];
        for &(idx, _) in use_set {
            if idx >= self.n {
                return Err(Error::InvalidParams(format!(
                    "coded block index {idx} out of n={}",
                    self.n
                )));
            }
        }
        let block_rows = use_set[0].1.rows();
        let cols = use_set[0].1.cols();
        for (_, block) in use_set {
            if block.rows() != block_rows || block.cols() != cols {
                return Err(Error::InvalidParams(
                    "inconsistent coded block shapes".into(),
                ));
            }
        }
        // Systematic fast path: all indices < k and distinct — a pure
        // reshuffle into index order.
        if use_set.iter().all(|&(idx, _)| idx < self.k) {
            scratch.idx.clear();
            scratch.idx.extend(use_set.iter().map(|&(i, _)| i));
            scratch.idx.sort_unstable();
            scratch.idx.dedup();
            if scratch.idx.len() == self.k {
                let mut out = Matrix::zeros(self.k * block_rows, cols);
                for (idx, block) in use_set {
                    out.data_mut()[idx * block_rows * cols..(idx + 1) * block_rows * cols]
                        .copy_from_slice(block.data());
                }
                return Ok((out, 0));
            }
        }
        // General path: solve G_S · D = Y for the k stacked data blocks,
        // assembled in canonical (ascending shard index) order.
        scratch.perm.clear();
        scratch.perm.extend(0..self.k);
        scratch.perm.sort_unstable_by_key(|&slot| use_set[slot].0);
        scratch.idx.clear();
        scratch
            .idx
            .extend(scratch.perm.iter().map(|&slot| use_set[slot].0));
        if scratch.idx.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::InvalidParams(format!(
                "duplicate coded block indices: {:?}",
                scratch.idx
            )));
        }
        scratch.gsub.resize_to(self.k, self.k);
        for (bi, &src) in scratch.idx.iter().enumerate() {
            scratch
                .gsub
                .row_mut(bi)
                .copy_from_slice(self.generator.row(src));
        }
        // Reshape: stacked blocks → k × (block_rows · cols) system.
        // Each data block is a row of the k×k solve with block entries.
        scratch.rhs.resize_to(self.k, block_rows * cols);
        for (bi, &slot) in scratch.perm.iter().enumerate() {
            scratch
                .rhs
                .row_mut(bi)
                .copy_from_slice(use_set[slot].1.data());
        }
        // Erasure-pattern memo: a repeat surviving-index set reuses the
        // previously computed factors. Reported flops stay the full
        // logical decode cost (the paper's §IV model) on hits and
        // misses alike — cache wins show up in wall-clock and the
        // hit/miss counters, never as a warmth-dependent flop figure.
        let lu: Arc<LuFactors> = match &self.cache {
            Some(cache) => match cache.lookup(&scratch.idx) {
                Some(factors) => factors,
                None => {
                    let factors = Arc::new(LuFactors::factorize(&scratch.gsub)?);
                    cache.insert(scratch.idx.clone(), Arc::clone(&factors));
                    factors
                }
            },
            None => Arc::new(LuFactors::factorize(&scratch.gsub)?),
        };
        let solved = lu.solve_matrix_with(&scratch.rhs, pool, &mut scratch.solve_buf)?;
        let flops = lu.factor_flops() + lu.solve_flops(block_rows * cols);
        // Row i of `solved` is data block i row-major, so the solved
        // storage reinterpreted as (k·block_rows) × cols *is* the
        // stacked result.
        let out = Matrix::from_vec(self.k * block_rows, cols, solved.into_vec())?;
        Ok((out, flops))
    }
}

/// Streaming session for an [`MdsCode`]: gathers the first `k`
/// distinct results, becomes ready at the `k`-th, and runs the `k×k`
/// solve at `finish`. Also serves as the hierarchical code's per-group
/// (inner) and master-side (outer) session.
pub struct MdsDecoder {
    code: MdsCode,
    out_rows: usize,
    gather: GatherK,
    /// Session-owned scratch, threaded through the `finish` solve so
    /// steady-state decoding allocates only the output.
    scratch: DecodeScratch,
    seconds: f64,
    finished: bool,
}

impl MdsDecoder {
    /// Open a session decoding an `out_rows`-row product through `code`.
    pub fn new(code: MdsCode, out_rows: usize) -> Self {
        let (n, k) = (code.n(), code.k());
        Self {
            code,
            out_rows,
            gather: GatherK::new(n, k),
            scratch: DecodeScratch::new(),
            seconds: 0.0,
            finished: false,
        }
    }
}

impl Decoder for MdsDecoder {
    fn push(&mut self, result: WorkerResult) -> Result<DecodeProgress> {
        let t0 = Instant::now();
        let p = self.gather.push(result.shard, result.data);
        self.seconds += t0.elapsed().as_secs_f64();
        p
    }

    fn progress(&self) -> DecodeProgress {
        self.gather.progress()
    }

    fn finish(&mut self) -> Result<DecodeOutput> {
        let t0 = Instant::now();
        if self.finished {
            return Err(Error::InvalidParams(
                "decode session already finished".into(),
            ));
        }
        let (result, flops) = self.code.decode_stacked(&self.gather.got, &mut self.scratch)?;
        if result.rows() != self.out_rows {
            return Err(Error::InvalidParams(format!(
                "decoded {} rows, expected {}",
                result.rows(),
                self.out_rows
            )));
        }
        self.finished = true;
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(DecodeOutput {
            result,
            flops,
            seconds: self.seconds,
        })
    }

    fn flops_so_far(&self) -> u64 {
        0 // all MDS decode work happens in `finish` (one k×k solve)
    }
}

impl CodedScheme for MdsCode {
    fn name(&self) -> String {
        format!("mds({},{})", self.n, self.k)
    }

    fn num_workers(&self) -> usize {
        self.n
    }

    fn num_data_blocks(&self) -> usize {
        self.k
    }

    fn row_divisor(&self) -> usize {
        self.k
    }

    fn encode(&self, a: &Matrix) -> Result<Vec<Matrix>> {
        let blocks = a.split_rows(self.k)?;
        self.encode_blocks(&blocks)
    }

    fn can_decode(&self, present: &[usize]) -> bool {
        let mut distinct: Vec<usize> = present.iter().copied().filter(|&i| i < self.n).collect();
        distinct.sort_unstable();
        distinct.dedup();
        distinct.len() >= self.k
    }

    fn decoder(&self, out_rows: usize, _batch: usize) -> Box<dyn Decoder> {
        Box::new(MdsDecoder::new(self.clone(), out_rows))
    }

    fn decode_caches(&self) -> Vec<Arc<LuCache>> {
        self.cache.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{compute_all_products, select_results};
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn encode_systematic_prefix_is_data() {
        let code = MdsCode::new(5, 3).unwrap();
        let mut r = Rng::new(1);
        let a = random_matrix(&mut r, 9, 4);
        let shards = code.encode(&a).unwrap();
        assert_eq!(shards.len(), 5);
        let blocks = a.split_rows(3).unwrap();
        for i in 0..3 {
            assert_eq!(shards[i], blocks[i]);
        }
    }

    #[test]
    fn any_k_subset_decodes_exactly() {
        let code = MdsCode::new(6, 4).unwrap();
        let mut r = Rng::new(2);
        let a = random_matrix(&mut r, 8, 5);
        let x = random_matrix(&mut r, 5, 2);
        let expect = ops::matmul(&a, &x);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Every 4-subset of 6.
        for s0 in 0..6 {
            for s1 in (s0 + 1)..6 {
                for s2 in (s1 + 1)..6 {
                    for s3 in (s2 + 1)..6 {
                        let subset = select_results(&all, &[s0, s1, s2, s3]);
                        let out = code.decode(&subset, 8).unwrap();
                        assert!(
                            out.result.max_abs_diff(&expect) < 1e-8,
                            "subset {:?} err {}",
                            [s0, s1, s2, s3],
                            out.result.max_abs_diff(&expect)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn systematic_fast_path_is_zero_flops() {
        let code = MdsCode::new(6, 3).unwrap();
        let mut r = Rng::new(3);
        let a = random_matrix(&mut r, 6, 4);
        let x = random_matrix(&mut r, 4, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let out = code.decode(&select_results(&all, &[2, 0, 1]), 6).unwrap();
        assert_eq!(out.flops, 0, "systematic decode must be free");
        assert!(out.result.max_abs_diff(&ops::matmul(&a, &x)) < 1e-12);
    }

    #[test]
    fn parity_decode_counts_flops() {
        let code = MdsCode::new(6, 3).unwrap();
        let mut r = Rng::new(4);
        let a = random_matrix(&mut r, 6, 4);
        let x = random_matrix(&mut r, 4, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let out = code.decode(&select_results(&all, &[3, 4, 5]), 6).unwrap();
        assert!(out.flops > 0);
    }

    #[test]
    fn insufficient_results_rejected() {
        let code = MdsCode::new(5, 3).unwrap();
        let mut r = Rng::new(5);
        let a = random_matrix(&mut r, 6, 2);
        let x = random_matrix(&mut r, 2, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let err = code.decode(&select_results(&all, &[0, 1]), 6);
        assert!(matches!(err, Err(Error::Insufficient { needed: 3, got: 2 })));
    }

    #[test]
    fn duplicate_indices_rejected() {
        let code = MdsCode::new(5, 2).unwrap();
        let mut r = Rng::new(6);
        let a = random_matrix(&mut r, 4, 2);
        let x = random_matrix(&mut r, 2, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let dup = vec![all[3].clone(), all[3].clone()];
        assert!(code.decode(&dup, 4).is_err());
    }

    #[test]
    fn parity_decode_is_arrival_order_invariant() {
        let code = MdsCode::new(6, 3).unwrap();
        let mut r = Rng::new(7);
        let a = random_matrix(&mut r, 6, 4);
        let x = random_matrix(&mut r, 4, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let fwd = code.decode(&select_results(&all, &[1, 4, 5]), 6).unwrap();
        let rev = code.decode(&select_results(&all, &[5, 4, 1]), 6).unwrap();
        assert_eq!(
            fwd.result.data(),
            rev.result.data(),
            "canonical ordering must erase arrival order"
        );
        assert_eq!(fwd.flops, rev.flops);
    }

    #[test]
    fn cached_parity_decode_is_bit_identical_and_counts_hits() {
        let cache = Arc::new(LuCache::new(8));
        let uncached = MdsCode::new(6, 3).unwrap();
        let cached = uncached.clone().with_cache(Arc::clone(&cache));
        let mut r = Rng::new(8);
        let a = random_matrix(&mut r, 6, 4);
        let x = random_matrix(&mut r, 4, 2);
        let shards = cached.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Parity-heavy subset in shuffled arrival order.
        let subset = select_results(&all, &[5, 3, 4]);
        let plain = uncached.decode(&subset, 6).unwrap();
        let cold = cached.decode(&subset, 6).unwrap();
        let warm = cached.decode(&subset, 6).unwrap();
        assert_eq!(plain.result.data(), cold.result.data());
        assert_eq!(cold.result.data(), warm.result.data());
        assert_eq!(plain.flops, cold.flops);
        assert_eq!(cold.flops, warm.flops, "hits report full logical cost");
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn can_decode_logic() {
        let code = MdsCode::new(5, 3).unwrap();
        assert!(code.can_decode(&[0, 1, 2]));
        assert!(code.can_decode(&[4, 2, 0, 1]));
        assert!(!code.can_decode(&[0, 1]));
        assert!(!code.can_decode(&[0, 0, 0])); // duplicates don't count
    }

    #[test]
    fn property_random_subsets_roundtrip() {
        check("mds decode∘encode = A·x on any k-subset", 25, |g| {
            let (n, k) = g.code_params(10);
            let rows = k * g.usize_in(1..4);
            let cols = g.usize_in(1..5);
            let batch = g.usize_in(1..3);
            let mut r = Rng::new(g.usize_in(0..1 << 30) as u64);
            let code = MdsCode::new(n, k).unwrap();
            let a = random_matrix(&mut r, rows, cols);
            let x = random_matrix(&mut r, cols, batch);
            let expect = ops::matmul(&a, &x);
            let shards = code.encode(&a).unwrap();
            let all = compute_all_products(&shards, &x);
            let subset_idx = g.subset(n, k);
            let out = code
                .decode(&select_results(&all, &subset_idx), rows)
                .unwrap();
            assert!(
                out.result.max_abs_diff(&expect) < 1e-7,
                "n={n} k={k} err={}",
                out.result.max_abs_diff(&expect)
            );
        });
    }
}
