//! Polynomial code baseline (Yu, Maddah-Ali, Avestimehr — NIPS'17).
//!
//! For the matrix-vector task the polynomial code specializes to a
//! non-systematic `(n, k)` MDS code whose generator is evaluation of the
//! data polynomial `p(t) = Σ_s A_s t^s` at `n` distinct points: worker
//! `l` computes `p(t_l)·x` and any `k` results interpolate. Its decode is
//! a single monolithic `k×k` solve — `O(k^β)` with `k = k1·k2`, the
//! largest decode cost in Table I, which is exactly what the paper's
//! hierarchical scheme splits into parallel `k1`- and `k2`-sized pieces.
//!
//! Numerical note: the paper's polynomial code uses the monomial basis
//! `p(t) = Σ_s A_s t^s`, whose Vandermonde systems are exponentially
//! ill-conditioned in `k` over the reals (fine over the finite fields
//! the paper implicitly assumes, unusable in f64 beyond k ≈ 20). We
//! evaluate in the **Chebyshev basis** `p(t) = Σ_s A_s T_s(t)` at
//! Chebyshev nodes instead — the same code family (degree-(k−1)
//! polynomial evaluation, any k results interpolate, identical decode
//! cost `O(k^β)`) with well-conditioned interpolation at the sizes the
//! benches decode for real. DESIGN.md documents this substitution.

use crate::coding::{
    CodedScheme, DecodeOutput, DecodeProgress, DecodeScratch, Decoder, GatherK, WorkerResult,
};
use crate::linalg::{lu::LuFactors, ops, LuCache, Matrix};
use crate::parallel::DecodePool;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Instant;

/// `(n, k)` polynomial-evaluation code (Chebyshev basis).
#[derive(Clone, Debug)]
pub struct PolynomialCode {
    n: usize,
    k: usize,
    /// Evaluation points (Chebyshev nodes on [-1, 1]).
    points: Vec<f64>,
    /// `n × k` generator `V[l][s] = T_s(t_l)`.
    generator: Matrix,
    /// Pool the interpolation solve fans its column panels across.
    pool: Arc<DecodePool>,
    /// Optional erasure-pattern factor memo (see [`LuCache`]); attached
    /// by the serving construction path, absent on bare codes.
    cache: Option<Arc<LuCache>>,
}

/// `n × k` matrix of Chebyshev polynomials `T_s(t_l)` via the
/// three-term recurrence.
pub fn chebyshev_vandermonde(points: &[f64], k: usize) -> Matrix {
    let mut m = Matrix::zeros(points.len(), k);
    for (l, &t) in points.iter().enumerate() {
        let row = m.row_mut(l);
        if k >= 1 {
            row[0] = 1.0;
        }
        if k >= 2 {
            row[1] = t;
        }
        for s in 2..k {
            row[s] = 2.0 * t * row[s - 1] - row[s - 2];
        }
    }
    m
}

impl PolynomialCode {
    /// Construct an `(n, k)` polynomial code.
    pub fn new(n: usize, k: usize) -> Result<Self> {
        if k == 0 || k > n {
            return Err(Error::InvalidParams(format!(
                "polynomial: need 1 <= k <= n, got ({n}, {k})"
            )));
        }
        let points = chebyshev_points(n);
        let generator = chebyshev_vandermonde(&points, k);
        Ok(Self {
            n,
            k,
            points,
            generator,
            pool: Arc::new(DecodePool::serial()),
            cache: None,
        })
    }

    /// Attach a decode pool: the interpolation solve's column panels
    /// then run in parallel (bit-identical results).
    pub fn with_pool(mut self, pool: Arc<DecodePool>) -> Self {
        self.pool = pool;
        self
    }

    /// Attach an erasure-pattern LU cache: repeat surviving-index sets
    /// skip refactorizing the Vandermonde submatrix. Must be private to
    /// this code (factors are generator-specific); results are
    /// bit-identical with or without it.
    pub fn with_cache(mut self, cache: Arc<LuCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The evaluation points.
    pub fn points(&self) -> &[f64] {
        &self.points
    }

    /// Interpolate the stacked data blocks from exactly-`k` distinct
    /// `(worker index, product)` pairs: solve the (Chebyshev)
    /// Vandermonde system `V_S · D = Y`. Returns the stacked result and
    /// the flops spent — the monolithic `O(k^β)` solve of Table I.
    pub fn interpolate(&self, coded: &[(usize, Matrix)]) -> Result<(Matrix, u64)> {
        self.interpolate_with(coded, &mut DecodeScratch::new())
    }

    /// [`PolynomialCode::interpolate`] with session scratch: the
    /// Vandermonde submatrix and gathered RHS live in `scratch`
    /// (reused across jobs — zero-alloc steady state beyond the
    /// output), the solve's column panels fan across the code's pool,
    /// and the solved storage is reinterpreted as the stacked result
    /// (no per-block copies).
    pub fn interpolate_with(
        &self,
        coded: &[(usize, Matrix)],
        scratch: &mut DecodeScratch,
    ) -> Result<(Matrix, u64)> {
        if coded.len() < self.k {
            return Err(Error::Insufficient {
                needed: self.k,
                got: coded.len(),
            });
        }
        let use_set = &coded[..self.k];
        let block_rows = use_set[0].1.rows();
        let cols = use_set[0].1.cols();
        for (_, data) in use_set {
            if data.rows() != block_rows || data.cols() != cols {
                return Err(Error::InvalidParams("inconsistent result shapes".into()));
            }
        }
        // Canonical (ascending worker index) order: the assembled system
        // depends only on which workers responded, never on arrival
        // order — the sorted index list is the [`LuCache`] key.
        scratch.perm.clear();
        scratch.perm.extend(0..self.k);
        scratch.perm.sort_unstable_by_key(|&slot| use_set[slot].0);
        scratch.idx.clear();
        scratch
            .idx
            .extend(scratch.perm.iter().map(|&slot| use_set[slot].0));
        if scratch.idx.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::InvalidParams(format!(
                "duplicate worker indices: {:?}",
                scratch.idx
            )));
        }
        scratch.gsub.resize_to(self.k, self.k);
        for (bi, &src) in scratch.idx.iter().enumerate() {
            scratch
                .gsub
                .row_mut(bi)
                .copy_from_slice(self.generator.row(src));
        }
        scratch.rhs.resize_to(self.k, block_rows * cols);
        for (bi, &slot) in scratch.perm.iter().enumerate() {
            scratch
                .rhs
                .row_mut(bi)
                .copy_from_slice(use_set[slot].1.data());
        }
        // Erasure-pattern memo (flops stay the full logical decode cost
        // on hits — see `MdsCode::decode_stacked_with`).
        let lu: Arc<LuFactors> = match &self.cache {
            Some(cache) => match cache.lookup(&scratch.idx) {
                Some(factors) => factors,
                None => {
                    let factors = Arc::new(LuFactors::factorize(&scratch.gsub)?);
                    cache.insert(scratch.idx.clone(), Arc::clone(&factors));
                    factors
                }
            },
            None => Arc::new(LuFactors::factorize(&scratch.gsub)?),
        };
        let solved =
            lu.solve_matrix_with(&scratch.rhs, &self.pool, &mut scratch.solve_buf)?;
        let flops = lu.factor_flops() + lu.solve_flops(block_rows * cols);
        // Row s of `solved` is data block s row-major — its storage is
        // the stacked result.
        let out = Matrix::from_vec(self.k * block_rows, cols, solved.into_vec())?;
        Ok((out, flops))
    }
}

/// Streaming session for the polynomial code: gathers any `k` distinct
/// evaluations and interpolates at `finish` — no incremental shortcut
/// exists (the solve is monolithic), which is exactly the §IV
/// comparison point against the hierarchical session.
pub struct PolynomialDecoder {
    code: PolynomialCode,
    out_rows: usize,
    gather: GatherK,
    /// Session-owned scratch for the interpolation solve.
    scratch: DecodeScratch,
    seconds: f64,
    finished: bool,
}

impl Decoder for PolynomialDecoder {
    fn push(&mut self, result: WorkerResult) -> Result<DecodeProgress> {
        let t0 = Instant::now();
        let p = self.gather.push(result.shard, result.data);
        self.seconds += t0.elapsed().as_secs_f64();
        p
    }

    fn progress(&self) -> DecodeProgress {
        self.gather.progress()
    }

    fn finish(&mut self) -> Result<DecodeOutput> {
        let t0 = Instant::now();
        if self.finished {
            return Err(Error::InvalidParams(
                "decode session already finished".into(),
            ));
        }
        let (result, flops) = self
            .code
            .interpolate_with(&self.gather.got, &mut self.scratch)?;
        if result.rows() != self.out_rows {
            return Err(Error::InvalidParams(format!(
                "decoded {} rows, expected {}",
                result.rows(),
                self.out_rows
            )));
        }
        self.finished = true;
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(DecodeOutput {
            result,
            flops,
            seconds: self.seconds,
        })
    }

    fn flops_so_far(&self) -> u64 {
        0 // the interpolation solve is monolithic, all in `finish`
    }
}

/// `n` Chebyshev nodes `cos((2i+1)π / 2n)` — distinct in `(-1, 1)`.
pub fn chebyshev_points(n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos())
        .collect()
}

impl CodedScheme for PolynomialCode {
    fn name(&self) -> String {
        format!("poly({},{})", self.n, self.k)
    }

    fn num_workers(&self) -> usize {
        self.n
    }

    fn num_data_blocks(&self) -> usize {
        self.k
    }

    fn row_divisor(&self) -> usize {
        self.k
    }

    fn encode(&self, a: &Matrix) -> Result<Vec<Matrix>> {
        let blocks = a.split_rows(self.k)?;
        let refs: Vec<&Matrix> = blocks.iter().collect();
        Ok((0..self.n)
            .map(|l| ops::lincomb(self.generator.row(l), &refs))
            .collect())
    }

    fn can_decode(&self, present: &[usize]) -> bool {
        let mut distinct: Vec<usize> =
            present.iter().copied().filter(|&i| i < self.n).collect();
        distinct.sort_unstable();
        distinct.dedup();
        distinct.len() >= self.k
    }

    fn decoder(&self, out_rows: usize, _batch: usize) -> Box<dyn Decoder> {
        Box::new(PolynomialDecoder {
            code: self.clone(),
            out_rows,
            gather: GatherK::new(self.n, self.k),
            scratch: DecodeScratch::new(),
            seconds: 0.0,
            finished: false,
        })
    }

    fn decode_caches(&self) -> Vec<Arc<LuCache>> {
        self.cache.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{compute_all_products, select_results};
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn chebyshev_points_distinct() {
        let pts = chebyshev_points(50);
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                assert!((pts[i] - pts[j]).abs() > 1e-6);
            }
        }
    }

    #[test]
    fn any_k_subset_interpolates() {
        let code = PolynomialCode::new(7, 4).unwrap();
        let mut r = Rng::new(1);
        let a = random_matrix(&mut r, 8, 5);
        let x = random_matrix(&mut r, 5, 2);
        let expect = ops::matmul(&a, &x);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        for _ in 0..20 {
            let subset = r.subset(7, 4);
            let out = code.decode(&select_results(&all, &subset), 8).unwrap();
            assert!(
                out.result.max_abs_diff(&expect) < 1e-7,
                "subset {subset:?} err {}",
                out.result.max_abs_diff(&expect)
            );
        }
    }

    #[test]
    fn decode_always_pays_full_solve() {
        // Unlike systematic MDS, polynomial codes have no free path.
        let code = PolynomialCode::new(6, 3).unwrap();
        let mut r = Rng::new(2);
        let a = random_matrix(&mut r, 6, 2);
        let x = random_matrix(&mut r, 2, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let out = code.decode(&select_results(&all, &[0, 1, 2]), 6).unwrap();
        assert!(out.flops > 0, "polynomial decode is never free");
    }

    #[test]
    fn cached_interpolation_is_bit_identical() {
        let cache = Arc::new(LuCache::new(4));
        let plain = PolynomialCode::new(6, 3).unwrap();
        let cached = plain.clone().with_cache(Arc::clone(&cache));
        let mut r = Rng::new(9);
        let a = random_matrix(&mut r, 6, 2);
        let x = random_matrix(&mut r, 2, 1);
        let shards = plain.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let subset = select_results(&all, &[4, 1, 5]);
        let base = plain.decode(&subset, 6).unwrap();
        let cold = cached.decode(&subset, 6).unwrap();
        let warm = cached.decode(&subset, 6).unwrap();
        assert_eq!(base.result.data(), cold.result.data());
        assert_eq!(cold.result.data(), warm.result.data());
        assert_eq!(cold.flops, warm.flops);
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn insufficient_rejected() {
        let code = PolynomialCode::new(5, 4).unwrap();
        assert!(!code.can_decode(&[0, 1, 2]));
        assert!(code.can_decode(&[0, 1, 2, 4]));
    }

    #[test]
    fn moderate_k_stays_accurate() {
        // Conditioning check at the decode sizes benches use for real.
        let code = PolynomialCode::new(48, 32).unwrap();
        let mut r = Rng::new(3);
        let a = random_matrix(&mut r, 64, 4);
        let x = random_matrix(&mut r, 4, 1);
        let expect = ops::matmul(&a, &x);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let subset = r.subset(48, 32);
        let out = code.decode(&select_results(&all, &subset), 64).unwrap();
        let err = out.result.max_abs_diff(&expect);
        assert!(err < 1e-3, "interpolation error {err} too large");
    }

    #[test]
    fn property_roundtrip_small() {
        check("poly decode∘encode = A·x", 20, |g| {
            let (n, k) = g.code_params(12);
            let rows = k * g.usize_in(1..3);
            let mut r = Rng::new(g.usize_in(0..1 << 30) as u64);
            let code = PolynomialCode::new(n, k).unwrap();
            let a = random_matrix(&mut r, rows, 3);
            let x = random_matrix(&mut r, 3, 1);
            let expect = ops::matmul(&a, &x);
            let shards = code.encode(&a).unwrap();
            let all = compute_all_products(&shards, &x);
            let subset = g.subset(n, k);
            let out = code.decode(&select_results(&all, &subset), rows).unwrap();
            assert!(out.result.max_abs_diff(&expect) < 1e-5);
        });
    }
}
