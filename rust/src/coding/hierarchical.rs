//! The paper's hierarchical code: `(n1, k1) × (n2, k2)` concatenated
//! MDS coding with **parallel two-level decoding** (§II-A, §IV).
//!
//! Encoding (Fig. 2): split `A` into `k2` blocks, apply the outer
//! `(n2, k2)` MDS code to get `Ã_1..Ã_{n2}` (one per group / rack);
//! split each `Ã_i` into `k1^{(i)}` sub-blocks and apply the inner
//! `(n1^{(i)}, k1^{(i)})` MDS code to get `Â_{i,1}..Â_{i,n1}` (one per
//! worker). Worker `w(i,j)` computes `Â_{i,j}·x`.
//!
//! Decoding: submaster `i` recovers `Ã_i·x` from any `k1` workers of its
//! group (these `n2` decodes are independent → **parallel**), and the
//! master recovers `A·x` from any `k2` groups. Total decode cost
//! `O(k1^β + k1·k2^β)` versus the product code's
//! `O(k1·k2^β + k2·k1^β)` (§IV, Table I).
//!
//! Heterogeneous groups (`n1^{(i)}, k1^{(i)}` varying per group, Fig. 2)
//! are supported; the homogeneous `(n1,k1)×(n2,k2)` constructor is the
//! common case used throughout the evaluation.

use crate::coding::mds::MdsDecoder;
use crate::coding::{
    CodedScheme, DecodeOutput, DecodeProgress, DecodeScratch, Decoder, MdsCode, WorkerResult,
};
use crate::linalg::Matrix;
use crate::parallel::DecodePool;
use crate::scenario::{GroupSpec, Topology};
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Instant;

/// Parameters of a hierarchical code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HierarchicalParams {
    /// Inner code length per group: `n1^{(i)}` for each of the `n2` groups.
    pub n1: Vec<usize>,
    /// Inner code dimension per group: `k1^{(i)}`.
    pub k1: Vec<usize>,
    /// Outer code length (number of groups).
    pub n2: usize,
    /// Outer code dimension.
    pub k2: usize,
}

impl HierarchicalParams {
    /// Homogeneous `(n1, k1) × (n2, k2)` parameters.
    pub fn homogeneous(n1: usize, k1: usize, n2: usize, k2: usize) -> Self {
        Self {
            n1: vec![n1; n2],
            k1: vec![k1; n2],
            n2,
            k2,
        }
    }

    /// Total number of workers `Σ_i n1^{(i)}`.
    pub fn total_workers(&self) -> usize {
        self.n1.iter().sum()
    }

    /// Validate consistency.
    pub fn validate(&self) -> Result<()> {
        if self.n2 == 0 || self.k2 == 0 || self.k2 > self.n2 {
            return Err(Error::InvalidParams(format!(
                "outer code: need 1 <= k2 <= n2, got ({}, {})",
                self.n2, self.k2
            )));
        }
        if self.n1.len() != self.n2 || self.k1.len() != self.n2 {
            return Err(Error::InvalidParams(format!(
                "per-group params: expected {} entries, got n1:{} k1:{}",
                self.n2,
                self.n1.len(),
                self.k1.len()
            )));
        }
        for i in 0..self.n2 {
            if self.k1[i] == 0 || self.k1[i] > self.n1[i] {
                return Err(Error::InvalidParams(format!(
                    "group {i}: need 1 <= k1 <= n1, got ({}, {})",
                    self.n1[i], self.k1[i]
                )));
            }
        }
        Ok(())
    }
}

/// Identifies worker `w(i, j)`: group `i`, in-group index `j`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkerId {
    /// Group (rack) index `i ∈ [n2]`.
    pub group: usize,
    /// Worker index within the group, `j ∈ [n1^{(i)}]`.
    pub index: usize,
}

/// The `(n1, k1) × (n2, k2)` hierarchical code.
///
/// # Partial-work mode (sub-tasks)
///
/// When a group's [`GroupSpec::subtasks`] is `r > 1`, that group's
/// inner code is the `(n1·r, k1·r)` MDS code over `k1·r` sub-blocks of
/// `Ã_g` (Ferdinand–Draper, arXiv:1806.10250, layered on the paper's
/// outer code): worker `j`'s shard is the stack of its `r` coded
/// sub-shards (rows `[s·b, (s+1)·b)` of the shard are sub-task `s`),
/// computed **sequentially**, and the group decodes from **any** `k1·r`
/// distinct sub-results — fast workers contribute all `r`, stragglers
/// contribute however many they finished. With `r = 1` the inner
/// generator is the exact `(n1, k1)` systematic MDS generator of the
/// all-or-nothing scheme, so every encode/decode path is bit-identical
/// to pre-partial behavior.
pub struct HierarchicalCode {
    params: HierarchicalParams,
    /// The scenario this code was built for ([`CodedScheme::topology`]
    /// echoes it verbatim, so the coordinator and the simulator see the
    /// per-group straggler profiles the config described).
    topo: Topology,
    outer: MdsCode,
    inner: Vec<MdsCode>,
    /// Per-group sub-tasks per worker (`r_g`, 1 = all-or-nothing).
    /// `inner[g]` is the `(n1_g·r_g, k1_g·r_g)` code.
    subtasks: Vec<usize>,
    /// Offset of each group's first worker in the flat indexing.
    offsets: Vec<usize>,
    /// Pool for parallel intra-group decoding and the in-decode solve
    /// panels (serial by default).
    pool: Arc<DecodePool>,
}

impl HierarchicalCode {
    /// Build from parameters (validates, constructs all generators).
    /// The scenario profile defaults to the paper's; use
    /// [`Self::from_topology`] to carry per-group straggler profiles.
    pub fn new(params: HierarchicalParams) -> Result<Self> {
        // Validate before indexing: ragged n1/k1 vectors must surface
        // as Err, not as a panic or a silently truncated topology.
        params.validate()?;
        let topo = Topology {
            groups: (0..params.n2)
                .map(|i| GroupSpec::new(params.n1[i], params.k1[i]))
                .collect(),
            k2: params.k2,
        };
        Self::from_topology(topo)
    }

    /// Build from a scenario-layer [`Topology`]: one inner `(n1_g,
    /// k1_g)` MDS code per group concatenated with the `(n2, k2)` outer
    /// code. The topology (including straggler profiles and dead-worker
    /// sets) is kept and returned by [`CodedScheme::topology`].
    pub fn from_topology(topo: Topology) -> Result<Self> {
        topo.validate()?;
        let params = topo.hierarchical_params();
        let subtasks: Vec<usize> = topo.groups.iter().map(|g| g.subtasks).collect();
        let outer = MdsCode::new(params.n2, params.k2)?;
        // Partial-work layering: group g's inner code spans sub-task
        // granularity, (n1·r, k1·r). At r = 1 this is the exact
        // (n1, k1) generator of the all-or-nothing scheme.
        let inner = (0..params.n2)
            .map(|i| MdsCode::new(params.n1[i] * subtasks[i], params.k1[i] * subtasks[i]))
            .collect::<Result<Vec<_>>>()?;
        let mut offsets = Vec::with_capacity(params.n2);
        let mut acc = 0;
        for i in 0..params.n2 {
            offsets.push(acc);
            acc += params.n1[i];
        }
        Ok(Self {
            params,
            topo,
            outer,
            inner,
            subtasks,
            offsets,
            pool: Arc::new(DecodePool::serial()),
        })
    }

    /// Homogeneous constructor.
    pub fn homogeneous(n1: usize, k1: usize, n2: usize, k2: usize) -> Result<Self> {
        Self::new(HierarchicalParams::homogeneous(n1, k1, n2, k2))
    }

    /// Attach a decode pool: the `n2` intra-group decodes of
    /// [`Self::decode_hierarchical`] fan across it (the paper's §IV
    /// parallel-decoding argument), and the inner/outer codes' solve
    /// panels use it inside the streaming sessions. Results are
    /// bit-identical to serial at any pool width.
    pub fn with_pool(mut self, pool: Arc<DecodePool>) -> Self {
        self.outer = self.outer.clone().with_pool(Arc::clone(&pool));
        self.inner = self
            .inner
            .iter()
            .map(|c| c.clone().with_pool(Arc::clone(&pool)))
            .collect();
        self.pool = pool;
        self
    }

    /// Attach a fresh erasure-pattern LU cache to every constituent
    /// code: one per inner group plus one for the outer code (factors
    /// are generator-specific, so caches are never shared across
    /// codes). Every decoder session opened from this instance — group,
    /// master, or standalone — then memoizes repeat surviving-index
    /// sets. Results stay bit-identical to the uncached code.
    pub fn with_decode_caches(mut self) -> Self {
        self.outer = self
            .outer
            .clone()
            .with_cache(Arc::new(crate::linalg::LuCache::default()));
        self.inner = self
            .inner
            .iter()
            .map(|c| {
                c.clone()
                    .with_cache(Arc::new(crate::linalg::LuCache::default()))
            })
            .collect();
        self
    }

    /// Code parameters.
    pub fn params(&self) -> &HierarchicalParams {
        &self.params
    }

    /// Per-group sub-tasks per worker (`r_g`; all 1 = the paper's
    /// all-or-nothing task model).
    pub fn subtasks(&self) -> &[usize] {
        &self.subtasks
    }

    /// Rows of `A` must divide by `k2 · lcm-ish`: we require
    /// `k2 · k1^{(i)} · r^{(i)}` for every group; for the homogeneous
    /// all-or-nothing case this is `k1·k2`.
    pub fn required_row_divisor(&self) -> usize {
        let mut d = self.params.k2;
        for (&k1, &r) in self.params.k1.iter().zip(&self.subtasks) {
            d = lcm(d, self.params.k2 * k1 * r);
        }
        d
    }

    /// Flat worker index of `w(i, j)`.
    pub fn flat_index(&self, id: WorkerId) -> usize {
        self.offsets[id.group] + id.index
    }

    /// Inverse of [`Self::flat_index`].
    pub fn worker_id(&self, flat: usize) -> WorkerId {
        let (group, index) = split_flat_index(&self.offsets, self.params.n2, flat);
        WorkerId { group, index }
    }

    /// Encode `A` hierarchically: returns `shards[i][j] = Â_{i,j}`.
    /// With sub-tasks (`r_g > 1`) a worker's shard stacks its `r_g`
    /// coded sub-shards: rows `[s·b, (s+1)·b)` are sub-task `s`.
    pub fn encode_grouped(&self, a: &Matrix) -> Result<Vec<Vec<Matrix>>> {
        // Outer code: A = [A_1; ...; A_{k2}] → Ã_1..Ã_{n2}.
        let blocks = a.split_rows(self.params.k2)?;
        let coded_groups = self.outer.encode_blocks(&blocks)?;
        // Inner code per group: Ã_i = [Ã_{i,1}; ...] → Â_{i,1}..Â_{i,n1}
        // (sub-task granularity: k1·r sub-blocks → n1·r sub-shards,
        // regrouped r-per-worker).
        coded_groups
            .iter()
            .enumerate()
            .map(|(i, g)| {
                let r = self.subtasks[i];
                let sub = g.split_rows(self.params.k1[i] * r)?;
                let coded = self.inner[i].encode_blocks(&sub)?;
                if r == 1 {
                    return Ok(coded);
                }
                (0..self.params.n1[i])
                    .map(|j| Matrix::vstack(&coded[j * r..(j + 1) * r]))
                    .collect()
            })
            .collect()
    }

    /// Expand full worker products of group `g` into sub-result pairs
    /// for the sub-task-granular inner code: `(j, Â_j·X)` becomes the
    /// `r` pairs `(j·r + s, chunk_s)`. Identity (a copy) at `r = 1` —
    /// the batch paths below branch so the all-or-nothing case keeps
    /// its original zero-expansion slices.
    fn expand_subresults(
        &self,
        group: usize,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<(usize, Matrix)>> {
        let r = self.subtasks[group];
        let mut out = Vec::with_capacity(results.len() * r);
        for (j, data) in results {
            for (s, chunk) in data.split_rows(r)?.into_iter().enumerate() {
                out.push((j * r + s, chunk));
            }
        }
        Ok(out)
    }

    /// Intra-group decode (what submaster `i` runs): recover `Ã_i·X`
    /// from any `k1^{(i)}` worker results of group `i`, given as
    /// `(in-group index, product)` pairs. Returns the stacked group
    /// result and decode flops. Runs on the scratch-based stacked path
    /// — the same elimination the streaming sessions and the batch
    /// fan-out execute.
    pub fn decode_group(
        &self,
        group: usize,
        results: &[(usize, Matrix)],
    ) -> Result<(Matrix, u64)> {
        if group >= self.params.n2 {
            return Err(Error::InvalidParams(format!(
                "group {group} out of n2={}",
                self.params.n2
            )));
        }
        let mut scratch = DecodeScratch::new();
        if self.subtasks[group] == 1 {
            self.inner[group].decode_stacked(results, &mut scratch)
        } else {
            let expanded = self.expand_subresults(group, results)?;
            self.inner[group].decode_stacked(&expanded, &mut scratch)
        }
    }

    /// Cross-group decode (what the master runs): recover `A·X` from any
    /// `k2` group results given as `(group index, Ã_i·X)` pairs. The
    /// outer solve — the largest single elimination of the batch path —
    /// fans its column panels across the attached pool and produces the
    /// stacked result directly (no split/vstack round trip).
    pub fn decode_cross(&self, groups: &[(usize, Matrix)]) -> Result<(Matrix, u64)> {
        let mut scratch = DecodeScratch::new();
        self.outer.decode_stacked_with(groups, &mut scratch, &self.pool)
    }

    /// Full two-level decode from per-group worker results:
    /// `per_group[i]` holds `(in-group index, product)` pairs for group
    /// `i` (may be empty / insufficient for straggling groups). Runs the
    /// `n2` intra-group decodes in parallel when a pool is attached.
    pub fn decode_hierarchical(
        &self,
        per_group: &[Vec<(usize, Matrix)>],
    ) -> Result<DecodeOutput> {
        let t0 = Instant::now();
        if per_group.len() != self.params.n2 {
            return Err(Error::InvalidParams(format!(
                "expected {} groups of results, got {}",
                self.params.n2,
                per_group.len()
            )));
        }
        // Groups that have enough workers to decode.
        let ready: Vec<usize> = (0..self.params.n2)
            .filter(|&i| per_group[i].len() >= self.params.k1[i])
            .collect();
        if ready.len() < self.params.k2 {
            return Err(Error::Insufficient {
                needed: self.params.k2,
                got: ready.len(),
            });
        }
        // Only the k2 first-ready groups need decoding (the master uses
        // the k2 fastest; decoding more wastes exactly the flops §IV
        // counts).
        let used: Vec<usize> = ready[..self.params.k2].to_vec();

        // Stage 1: intra-group decodes — independent, so they fan
        // across the pool. The scoped pool lets tasks borrow
        // `per_group` and the inner codes directly (no input clones,
        // the pre-pool serial path's exact arithmetic), and results
        // come back in `used` order, so parallel == serial bit-for-bit.
        // Each task's solve runs serially to keep the fan-out at one
        // level: group-level parallelism here, panel-level parallelism
        // in the streaming sessions.
        let stage1: Vec<Result<(usize, Matrix, u64)>> = self.pool.map(used, |i| {
            let mut scratch = DecodeScratch::new();
            let (m, f) = if self.subtasks[i] == 1 {
                self.inner[i].decode_stacked_with(
                    &per_group[i],
                    &mut scratch,
                    &DecodePool::serial(),
                )?
            } else {
                // Partial-work: full worker products expand to their
                // sub-results before the (k1·r)×(k1·r) elimination.
                let expanded = self.expand_subresults(i, &per_group[i])?;
                self.inner[i].decode_stacked_with(
                    &expanded,
                    &mut scratch,
                    &DecodePool::serial(),
                )?
            };
            Ok((i, m, f))
        });
        let mut group_results = Vec::with_capacity(self.params.k2);
        let mut flops = 0u64;
        for s in stage1 {
            let (i, m, f) = s?;
            flops += f;
            group_results.push((i, m));
        }
        // Stage 2: cross-group decode.
        let (result, f2) = self.decode_cross(&group_results)?;
        flops += f2;
        Ok(DecodeOutput {
            result,
            flops,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Group results by flat worker index into the per-group layout
    /// [`Self::decode_hierarchical`] expects.
    pub fn group_results(&self, results: &[WorkerResult]) -> Vec<Vec<(usize, Matrix)>> {
        let mut per_group: Vec<Vec<(usize, Matrix)>> =
            (0..self.params.n2).map(|_| Vec::new()).collect();
        for r in results {
            if r.shard >= self.params.total_workers() {
                continue;
            }
            let id = self.worker_id(r.shard);
            per_group[id.group].push((id.index, r.data.clone()));
        }
        per_group
    }
}

/// Streaming session for the hierarchical code with **incremental
/// per-group elimination** (§IV): each group's inner decode runs inside
/// [`Decoder::push`] the instant that group's `k1`-th result arrives,
/// so by the time the `k2`-th group completes, only the outer decode is
/// left for [`Decoder::finish`] — the post-last-arrival latency is the
/// outer solve alone, not the full two-level decode.
pub struct HierarchicalDecoder {
    params: HierarchicalParams,
    inner: Vec<MdsCode>,
    outer: MdsCode,
    /// Per-group sub-tasks per worker (`r_g`): a pushed worker result
    /// expands into `r_g` sub-results and group `g` decodes at its
    /// `k1_g·r_g`-th distinct sub-result.
    subtasks: Vec<usize>,
    offsets: Vec<usize>,
    out_rows: usize,
    /// Collected `(sub-result index, product)` pairs per group
    /// (`(in-group worker index, product)` when `r_g = 1`).
    pending: Vec<Vec<(usize, Matrix)>>,
    /// Duplicate guard per group.
    seen: Vec<Vec<bool>>,
    /// `(group, Ã_g·X)` in completion order, capped at `k2`.
    decoded: Vec<(usize, Matrix)>,
    group_done: Vec<bool>,
    /// Session-owned scratch shared by every inner elimination and the
    /// outer solve — with same-shaped jobs, pushes allocate nothing
    /// beyond each group's decoded partial.
    scratch: DecodeScratch,
    flops: u64,
    seconds: f64,
    finished: bool,
}

impl HierarchicalDecoder {
    fn new(code: &HierarchicalCode, out_rows: usize) -> Self {
        let params = code.params.clone();
        let pending = (0..params.n2)
            .map(|g| Vec::with_capacity(params.k1[g] * code.subtasks[g]))
            .collect();
        let seen = (0..params.n2).map(|g| vec![false; params.n1[g]]).collect();
        let decoded = Vec::with_capacity(params.k2);
        let group_done = vec![false; params.n2];
        Self {
            inner: code.inner.clone(),
            outer: code.outer.clone(),
            subtasks: code.subtasks.clone(),
            offsets: code.offsets.clone(),
            out_rows,
            pending,
            seen,
            decoded,
            group_done,
            scratch: DecodeScratch::new(),
            flops: 0,
            seconds: 0.0,
            finished: false,
            params,
        }
    }

    fn split_flat(&self, flat: usize) -> (usize, usize) {
        split_flat_index(&self.offsets, self.params.n2, flat)
    }
}

/// Map a flat worker index to `(group, in-group index)` given the
/// groups' flat offsets — shared by [`HierarchicalCode::worker_id`] and
/// the streaming decoder so the two can never disagree.
fn split_flat_index(offsets: &[usize], n2: usize, flat: usize) -> (usize, usize) {
    let mut group = 0;
    while group + 1 < n2 && offsets[group + 1] <= flat {
        group += 1;
    }
    (group, flat - offsets[group])
}

impl Decoder for HierarchicalDecoder {
    fn push(&mut self, result: WorkerResult) -> Result<DecodeProgress> {
        let t0 = Instant::now();
        if result.shard >= self.params.total_workers() {
            return Err(Error::InvalidParams(format!(
                "worker {} out of {}",
                result.shard,
                self.params.total_workers()
            )));
        }
        let (g, j) = self.split_flat(result.shard);
        if self.decoded.len() < self.params.k2 && !self.group_done[g] && !self.seen[g][j] {
            let r = self.subtasks[g];
            if r == 1 {
                self.seen[g][j] = true;
                self.pending[g].push((j, result.data));
            } else {
                // Partial-work: a full worker result carries all r of
                // its sub-results (rows [s·b, (s+1)·b) = sub-task s).
                // Split before marking the worker seen, so a malformed
                // result doesn't consume its slot.
                let chunks = result.data.split_rows(r)?;
                self.seen[g][j] = true;
                for (s, chunk) in chunks.into_iter().enumerate() {
                    self.pending[g].push((j * r + s, chunk));
                }
            }
            if self.pending[g].len() >= self.params.k1[g] * r {
                // The incremental step: inner-decode group g now, at its
                // k1-th arrival — off the job's completion critical path.
                // The solve fans its panels across the code's pool.
                let collected = std::mem::take(&mut self.pending[g]);
                let (partial, f) =
                    self.inner[g].decode_stacked(&collected, &mut self.scratch)?;
                self.flops += f;
                self.decoded.push((g, partial));
                self.group_done[g] = true;
            }
        }
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(self.progress())
    }

    fn progress(&self) -> DecodeProgress {
        let done = self.decoded.len();
        if done >= self.params.k2 {
            return DecodeProgress::Ready;
        }
        // Lower bound on further results: the (k2 − done) smallest
        // per-group deficits among not-yet-decoded groups, in whole
        // worker results (a pushed result is worth r_g sub-results).
        let mut deficits: Vec<usize> = (0..self.params.n2)
            .filter(|&g| !self.group_done[g])
            .map(|g| {
                let r = self.subtasks[g];
                (self.params.k1[g] * r).saturating_sub(self.pending[g].len()).div_ceil(r)
            })
            .collect();
        deficits.sort_unstable();
        let needed_groups = self.params.k2 - done;
        let still_needed = deficits
            .iter()
            .take(needed_groups)
            .sum::<usize>()
            .max(1);
        DecodeProgress::NeedMore { still_needed }
    }

    fn finish(&mut self) -> Result<DecodeOutput> {
        let t0 = Instant::now();
        if self.finished {
            return Err(Error::InvalidParams(
                "decode session already finished".into(),
            ));
        }
        if self.decoded.len() < self.params.k2 {
            return Err(Error::Insufficient {
                needed: self.params.k2,
                got: self.decoded.len(),
            });
        }
        let (result, f) = self.outer.decode_stacked(&self.decoded, &mut self.scratch)?;
        self.flops += f;
        if result.rows() != self.out_rows {
            return Err(Error::InvalidParams(format!(
                "decoded {} rows, expected {}",
                result.rows(),
                self.out_rows
            )));
        }
        self.finished = true;
        self.seconds += t0.elapsed().as_secs_f64();
        Ok(DecodeOutput {
            result,
            flops: self.flops,
            seconds: self.seconds,
        })
    }

    fn flops_so_far(&self) -> u64 {
        self.flops
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

impl CodedScheme for HierarchicalCode {
    fn name(&self) -> String {
        let p = &self.params;
        // Partial-work suffix only when sub-tasks are in play, so
        // all-or-nothing names (and everything keyed on them) are
        // untouched.
        let suffix = if self.subtasks.iter().all(|&r| r == 1) {
            String::new()
        } else if self.subtasks.windows(2).all(|w| w[0] == w[1]) {
            format!("r{}", self.subtasks[0])
        } else {
            "r(hetero)".to_string()
        };
        if p.n1.windows(2).all(|w| w[0] == w[1]) && p.k1.windows(2).all(|w| w[0] == w[1]) {
            format!("hier({},{})x({},{}){suffix}", p.n1[0], p.k1[0], p.n2, p.k2)
        } else {
            format!("hier(hetero,n2={},k2={}){suffix}", p.n2, p.k2)
        }
    }

    fn num_workers(&self) -> usize {
        self.params.total_workers()
    }

    fn num_data_blocks(&self) -> usize {
        // k2 groups × k1 sub-blocks (homogeneous notion; heterogeneous
        // groups report the outer dimension only via k2 · min k1).
        self.params.k2 * self.params.k1.iter().min().copied().unwrap_or(1)
    }

    fn row_divisor(&self) -> usize {
        self.required_row_divisor()
    }

    fn encode(&self, a: &Matrix) -> Result<Vec<Matrix>> {
        Ok(self.encode_grouped(a)?.into_iter().flatten().collect())
    }

    fn can_decode(&self, present: &[usize]) -> bool {
        let mut per_group = vec![0usize; self.params.n2];
        let mut seen = std::collections::HashSet::new();
        for &f in present {
            if f < self.params.total_workers() && seen.insert(f) {
                per_group[self.worker_id(f).group] += 1;
            }
        }
        let ready = (0..self.params.n2)
            .filter(|&i| per_group[i] >= self.params.k1[i])
            .count();
        ready >= self.params.k2
    }

    fn decoder(&self, out_rows: usize, _batch: usize) -> Box<dyn Decoder> {
        Box::new(HierarchicalDecoder::new(self, out_rows))
    }

    fn topology(&self) -> Topology {
        self.topo.clone()
    }

    fn group_decoder(
        &self,
        group: usize,
        out_rows: usize,
        _batch: usize,
    ) -> Option<Box<dyn Decoder>> {
        if group >= self.params.n2 {
            return None;
        }
        // A group's share of the output is one outer block: m / k2
        // rows. The session runs over the inner code's own index space:
        // sub-result indices `j·r + s` in partial-work mode (any k1·r
        // of them decode — fractional worker contributions included),
        // plain in-group worker indices when r = 1.
        Some(Box::new(MdsDecoder::new(
            self.inner[group].clone(),
            out_rows / self.params.k2,
        )))
    }

    fn master_decoder(&self, out_rows: usize, _batch: usize) -> Box<dyn Decoder> {
        // Consumes group partials: shard = group index, data = Ã_i·X.
        Box::new(MdsDecoder::new(self.outer.clone(), out_rows))
    }

    fn decode_caches(&self) -> Vec<Arc<crate::linalg::LuCache>> {
        self.inner
            .iter()
            .chain(std::iter::once(&self.outer))
            .filter_map(|c| c.cache().cloned())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{compute_all_products, select_results};
    use crate::linalg::ops;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn decode_caches_cover_inner_and_outer_and_stay_bit_identical() {
        let plain = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        let cached = HierarchicalCode::homogeneous(3, 2, 3, 2)
            .unwrap()
            .with_decode_caches();
        assert!(plain.decode_caches().is_empty(), "bare codes are uncached");
        assert_eq!(cached.decode_caches().len(), 4, "3 inner + 1 outer");
        let mut r = Rng::new(11);
        let a = random_matrix(&mut r, 8, 3);
        let x = random_matrix(&mut r, 3, 2);
        let shards = cached.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Parity-leaning subset: in-group workers {1,2} of groups 0 and
        // 2, so every inner solve and the outer solve take the general
        // (cacheable) path.
        let subset = select_results(&all, &[1, 2, 7, 8]);
        let p = plain.decode(&subset, 8).unwrap();
        let c1 = cached.decode(&subset, 8).unwrap();
        let c2 = cached.decode(&subset, 8).unwrap();
        assert_eq!(p.result.data(), c1.result.data());
        assert_eq!(c1.result.data(), c2.result.data());
        assert_eq!(p.flops, c1.flops);
        assert_eq!(c1.flops, c2.flops, "hits report full logical cost");
        let stats: Vec<_> = cached.decode_caches().iter().map(|c| c.stats()).collect();
        let hits: u64 = stats.iter().map(|s| s.hits).sum();
        let misses: u64 = stats.iter().map(|s| s.misses).sum();
        assert_eq!(misses, 3, "two inner + one outer cold factorization");
        assert_eq!(hits, 3, "repeat pattern must hit every cache");
    }

    /// The paper's Fig. 3 toy example: (3,2) × (3,2).
    #[test]
    fn fig3_toy_example_structure() {
        let code = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        let mut r = Rng::new(1);
        let a = random_matrix(&mut r, 8, 3); // k1*k2 = 4 | 8
        let shards = code.encode_grouped(&a).unwrap();
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|g| g.len() == 3));
        // Outer structure: group 3's Ã_3 = g·[Ã_1; Ã_2] with g the outer
        // generator's parity row (Fig. 3 uses g = (1,1); our systematic
        // generator draws g randomly — the *structure* is identical:
        // Â_{3,j} = g0·Â_{1,j} + g1·Â_{2,j}).
        let outer_g = crate::linalg::vandermonde::systematic_mds(3, 2).unwrap();
        let parity = outer_g.row(2);
        let combo = {
            let mut m = Matrix::zeros(shards[0][0].rows(), shards[0][0].cols());
            ops::axpy(parity[0], shards[0][0].data(), m.data_mut());
            ops::axpy(parity[1], shards[1][0].data(), m.data_mut());
            m
        };
        assert!(
            shards[2][0].max_abs_diff(&combo) < 1e-12,
            "parity group shard must be the generator combination of systematic group shards"
        );
        // Inner structure: Â_{i,3} = h0·Â_{i,1} + h1·Â_{i,2} with h the
        // inner parity row.
        let inner_g = crate::linalg::vandermonde::systematic_mds(3, 2).unwrap();
        let h = inner_g.row(2);
        for i in 0..3 {
            let mut s = Matrix::zeros(shards[i][0].rows(), shards[i][0].cols());
            ops::axpy(h[0], shards[i][0].data(), s.data_mut());
            ops::axpy(h[1], shards[i][1].data(), s.data_mut());
            assert!(shards[i][2].max_abs_diff(&s) < 1e-12);
        }
    }

    #[test]
    fn decode_from_fastest_k1_of_k2_groups() {
        let code = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        let mut r = Rng::new(2);
        let a = random_matrix(&mut r, 8, 4);
        let x = random_matrix(&mut r, 4, 1);
        let expect = ops::matmul(&a, &x);
        let shards: Vec<Matrix> = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Use parity-heavy subsets: groups 1 and 2 (0-indexed 1, 2),
        // workers 1,2 of each (parity worker included).
        let picks = [
            code.flat_index(WorkerId { group: 1, index: 1 }),
            code.flat_index(WorkerId { group: 1, index: 2 }),
            code.flat_index(WorkerId { group: 2, index: 0 }),
            code.flat_index(WorkerId { group: 2, index: 2 }),
        ];
        let out = code.decode(&select_results(&all, &picks), 8).unwrap();
        assert!(out.result.max_abs_diff(&expect) < 1e-8);
    }

    #[test]
    fn insufficient_groups_rejected() {
        let code = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        let mut r = Rng::new(3);
        let a = random_matrix(&mut r, 8, 2);
        let x = random_matrix(&mut r, 2, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Only one group has ≥ k1 workers.
        let picks = [0usize, 1, 3]; // group 0: 2 workers; group 1: 1 worker
        let err = code.decode(&select_results(&all, &picks), 8);
        assert!(matches!(err, Err(Error::Insufficient { needed: 2, got: 1 })));
    }

    #[test]
    fn heterogeneous_groups_roundtrip() {
        let params = HierarchicalParams {
            n1: vec![4, 3, 5],
            k1: vec![2, 2, 3],
            n2: 3,
            k2: 2,
        };
        let code = HierarchicalCode::new(params).unwrap();
        let mut r = Rng::new(4);
        let rows = code.required_row_divisor();
        let a = random_matrix(&mut r, rows, 3);
        let x = random_matrix(&mut r, 3, 2);
        let expect = ops::matmul(&a, &x);
        let grouped = code.encode_grouped(&a).unwrap();
        assert_eq!(grouped[0].len(), 4);
        assert_eq!(grouped[1].len(), 3);
        assert_eq!(grouped[2].len(), 5);
        // Decode from groups 0 (workers 2,3) and 2 (workers 0,2,4).
        let per_group = vec![
            vec![
                (2usize, grouped[0][2].clone()),
                (3usize, grouped[0][3].clone()),
            ]
            .into_iter()
            .map(|(j, s)| (j, ops::matmul(&s, &x)))
            .collect::<Vec<_>>(),
            vec![],
            vec![
                (0usize, grouped[2][0].clone()),
                (2usize, grouped[2][2].clone()),
                (4usize, grouped[2][4].clone()),
            ]
            .into_iter()
            .map(|(j, s)| (j, ops::matmul(&s, &x)))
            .collect::<Vec<_>>(),
        ];
        let out = code.decode_hierarchical(&per_group).unwrap();
        assert!(out.result.max_abs_diff(&expect) < 1e-8);
        // The standalone group decode produces group 0's share (m / k2
        // rows of Ã_0·X) on the same stacked path.
        let (g0, _) = code.decode_group(0, &per_group[0]).unwrap();
        assert_eq!(g0.rows(), rows / 2);
    }

    #[test]
    fn parallel_pool_decode_matches_serial_bitwise() {
        let mut r = Rng::new(5);
        let a = random_matrix(&mut r, 24, 6);
        let x = random_matrix(&mut r, 6, 2);
        let serial = HierarchicalCode::homogeneous(4, 2, 4, 3).unwrap();
        let shards = serial.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // groups 0,1,2 each contribute workers {1,3}; group 3 straggles.
        let picks: Vec<usize> = (0..3)
            .flat_map(|g| {
                [
                    serial.flat_index(WorkerId { group: g, index: 1 }),
                    serial.flat_index(WorkerId { group: g, index: 3 }),
                ]
            })
            .collect();
        let o1 = serial.decode(&select_results(&all, &picks), 24).unwrap();
        // Both the streaming-session decode and the batch
        // decode_hierarchical fan-out must be bit-identical to serial
        // at every pool width.
        for threads in [2, 4, 8] {
            let pool = Arc::new(DecodePool::new(threads).unwrap());
            let parallel = HierarchicalCode::homogeneous(4, 2, 4, 3)
                .unwrap()
                .with_pool(pool);
            let o2 = parallel.decode(&select_results(&all, &picks), 24).unwrap();
            assert_eq!(o1.result.data(), o2.result.data(), "threads={threads}");
            assert_eq!(o1.flops, o2.flops);
            let per_group = parallel.group_results(&select_results(&all, &picks));
            let o3 = parallel.decode_hierarchical(&per_group).unwrap();
            let o4 = serial.decode_hierarchical(&per_group).unwrap();
            assert_eq!(o4.result.data(), o3.result.data(), "threads={threads}");
            assert_eq!(o4.flops, o3.flops);
        }
    }

    #[test]
    fn flat_index_roundtrip() {
        let code = HierarchicalCode::new(HierarchicalParams {
            n1: vec![3, 5, 2],
            k1: vec![2, 3, 1],
            n2: 3,
            k2: 2,
        })
        .unwrap();
        for flat in 0..code.num_workers() {
            let id = code.worker_id(flat);
            assert_eq!(code.flat_index(id), flat);
        }
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(HierarchicalCode::homogeneous(2, 3, 3, 2).is_err()); // k1 > n1
        assert!(HierarchicalCode::homogeneous(3, 2, 2, 3).is_err()); // k2 > n2
        assert!(HierarchicalCode::new(HierarchicalParams {
            n1: vec![3, 3],
            k1: vec![2],
            n2: 2,
            k2: 1,
        })
        .is_err()); // ragged
    }

    #[test]
    fn property_decode_invariant_to_result_order() {
        check("hier decode order-invariant", 15, |g| {
            let n2 = g.usize_in(2..5);
            let k2 = g.usize_in(1..n2 + 1);
            let n1 = g.usize_in(2..5);
            let k1 = g.usize_in(1..n1 + 1);
            let mut r = Rng::new(g.usize_in(0..1 << 30) as u64);
            let code = HierarchicalCode::homogeneous(n1, k1, n2, k2).unwrap();
            let rows = code.required_row_divisor();
            let a = random_matrix(&mut r, rows, 3);
            let x = random_matrix(&mut r, 3, 1);
            let expect = ops::matmul(&a, &x);
            let shards = code.encode(&a).unwrap();
            let all = compute_all_products(&shards, &x);
            // All workers respond, in two different random orders.
            let mut order1: Vec<usize> = (0..code.num_workers()).collect();
            let mut order2 = order1.clone();
            r.shuffle(&mut order1);
            r.shuffle(&mut order2);
            let o1 = code.decode(&select_results(&all, &order1), rows).unwrap();
            let o2 = code.decode(&select_results(&all, &order2), rows).unwrap();
            assert!(o1.result.max_abs_diff(&expect) < 1e-7);
            assert!(o2.result.max_abs_diff(&expect) < 1e-7);
        });
    }

    #[test]
    fn streaming_session_matches_batch_and_front_loads_inner_work() {
        let code = HierarchicalCode::homogeneous(4, 2, 4, 2).unwrap();
        let mut r = Rng::new(11);
        let rows = code.required_row_divisor() * 2;
        let a = random_matrix(&mut r, rows, 3);
        let x = random_matrix(&mut r, 3, 2);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Parity-heavy arrivals: workers {2,3} of every group.
        let picks: Vec<usize> = (0..4)
            .flat_map(|g| {
                [
                    code.flat_index(WorkerId { group: g, index: 2 }),
                    code.flat_index(WorkerId { group: g, index: 3 }),
                ]
            })
            .collect();
        let subset = select_results(&all, &picks);
        let batch = code.decode(&subset, rows).unwrap();

        let mut session = code.decoder(rows, 2);
        let mut ready_at = None;
        for (i, res) in subset.iter().enumerate() {
            if session.push(res.clone()).unwrap().is_ready() {
                ready_at = Some(i);
                break;
            }
        }
        // Ready exactly when the k2-th group completes (4th arrival).
        assert_eq!(ready_at, Some(3));
        // Inner-decode work already happened inside push.
        assert!(session.flops_so_far() > 0, "inner decodes must be front-loaded");
        let streamed = session.finish().unwrap();
        // Bit-for-bit agreement with the batch (replay) path.
        assert_eq!(streamed.result.data(), batch.result.data());
        assert_eq!(streamed.flops, batch.flops);
        assert!(streamed.result.max_abs_diff(&ops::matmul(&a, &x)) < 1e-7);
    }

    #[test]
    fn group_and_master_sessions_compose_to_full_decode() {
        // Drive the submaster-side (inner) and master-side (outer)
        // sessions by hand — exactly what the live coordinator does —
        // and check the composition reconstructs A·X.
        let code = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        let mut r = Rng::new(12);
        let a = random_matrix(&mut r, 8, 3);
        let x = random_matrix(&mut r, 3, 1);
        let grouped = code.encode_grouped(&a).unwrap();
        let mut master = code.master_decoder(8, 1);
        for g in [2usize, 0] {
            let mut gs = code.group_decoder(g, 8, 1).unwrap();
            // Feed workers 1 then 2 of the group (parity included).
            for j in [1usize, 2] {
                let data = ops::matmul(&grouped[g][j], &x);
                gs.push(WorkerResult { shard: j, data }).unwrap();
            }
            let part = gs.finish().unwrap();
            assert_eq!(part.result.rows(), 4); // m / k2
            master
                .push(WorkerResult { shard: g, data: part.result })
                .unwrap();
        }
        assert!(master.progress().is_ready());
        let out = master.finish().unwrap();
        assert!(out.result.max_abs_diff(&ops::matmul(&a, &x)) < 1e-7);
    }

    #[test]
    fn r1_topology_is_bit_identical_to_all_or_nothing() {
        // A topology whose groups carry subtasks = 1 (the default)
        // builds the exact generators, encode and decode of the
        // pre-partial scheme — the acceptance bit-identity guarantee.
        let plain = HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap();
        let topo = Topology::homogeneous(3, 2, 3, 2);
        let viatopo = HierarchicalCode::from_topology(topo).unwrap();
        assert_eq!(plain.subtasks(), &[1, 1, 1]);
        let mut rng = Rng::new(41);
        let a = random_matrix(&mut rng, 8, 3);
        let x = random_matrix(&mut rng, 3, 1);
        let s1 = plain.encode(&a).unwrap();
        let s2 = viatopo.encode(&a).unwrap();
        for (m1, m2) in s1.iter().zip(&s2) {
            assert_eq!(m1.data(), m2.data());
        }
        let all = compute_all_products(&s1, &x);
        let picks: Vec<usize> = (0..plain.num_workers()).collect();
        let o1 = plain.decode(&select_results(&all, &picks), 8).unwrap();
        let o2 = viatopo.decode(&select_results(&all, &picks), 8).unwrap();
        assert_eq!(o1.result.data(), o2.result.data());
        assert_eq!(o1.flops, o2.flops);
        assert_eq!(plain.name(), viatopo.name(), "no r suffix at r = 1");
    }

    #[test]
    fn subtask_sessions_recover_from_straggler_partials() {
        // (4,2)×(3,2), r = 4: a group decodes from ANY k1·r = 8
        // distinct sub-results — here one complete worker plus three
        // stragglers' partial work (2 + 1 + 1 sub-results).
        let mut topo = Topology::homogeneous(4, 2, 3, 2);
        for g in &mut topo.groups {
            g.subtasks = 4;
        }
        let code = HierarchicalCode::from_topology(topo).unwrap();
        assert_eq!(code.name(), "hier(4,2)x(3,2)r4");
        let r = 4usize;
        let mut rng = Rng::new(21);
        let rows = code.required_row_divisor();
        assert_eq!(rows, 16); // k2·k1·r
        let a = random_matrix(&mut rng, rows, 3);
        let x = random_matrix(&mut rng, 3, 2);
        let expect = ops::matmul(&a, &x);
        let grouped = code.encode_grouped(&a).unwrap();
        // Per-worker sub-products: sub-task s = rows [s·b, (s+1)·b).
        let sub_products = |g: usize, j: usize| -> Vec<Matrix> {
            grouped[g][j]
                .split_rows(r)
                .unwrap()
                .iter()
                .map(|shard| ops::matmul(shard, &x))
                .collect()
        };
        let mut master = code.master_decoder(rows, 2);
        for g in [0usize, 2] {
            let mut session = code.group_decoder(g, rows, 2).unwrap();
            let contributions: [(usize, usize); 4] = [(1, 4), (0, 2), (2, 1), (3, 1)];
            let mut pushed = 0;
            let mut ready = false;
            for (j, count) in contributions {
                for (s, data) in sub_products(g, j).into_iter().take(count).enumerate() {
                    pushed += 1;
                    ready = session
                        .push(WorkerResult { shard: j * r + s, data })
                        .unwrap()
                        .is_ready();
                }
            }
            assert_eq!(pushed, 8);
            assert!(ready, "k1·r sub-results must make the group ready");
            let part = session.finish().unwrap();
            assert_eq!(part.result.rows(), rows / 2); // m / k2
            master
                .push(WorkerResult { shard: g, data: part.result })
                .unwrap();
        }
        assert!(master.progress().is_ready());
        let out = master.finish().unwrap();
        assert!(out.result.max_abs_diff(&expect) < 1e-6);
    }

    #[test]
    fn subtask_batch_decode_matches_serial_across_pool_widths() {
        let mut topo = Topology::homogeneous(4, 2, 4, 3);
        for g in &mut topo.groups {
            g.subtasks = 2;
        }
        let serial = HierarchicalCode::from_topology(topo.clone()).unwrap();
        let mut rng = Rng::new(31);
        let rows = serial.required_row_divisor();
        let a = random_matrix(&mut rng, rows, 5);
        let x = random_matrix(&mut rng, 5, 2);
        let expect = ops::matmul(&a, &x);
        let shards = serial.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        // Parity-heavy subset: workers {2,3} of groups 0..2.
        let picks: Vec<usize> = (0..3)
            .flat_map(|g| {
                [
                    serial.flat_index(WorkerId { group: g, index: 2 }),
                    serial.flat_index(WorkerId { group: g, index: 3 }),
                ]
            })
            .collect();
        let o1 = serial.decode(&select_results(&all, &picks), rows).unwrap();
        assert!(o1.result.max_abs_diff(&expect) < 1e-7);
        assert!(o1.flops > 0, "parity sub-results force a real elimination");
        for threads in [2, 8] {
            let pool = Arc::new(DecodePool::new(threads).unwrap());
            let parallel = HierarchicalCode::from_topology(topo.clone())
                .unwrap()
                .with_pool(pool);
            let o2 = parallel.decode(&select_results(&all, &picks), rows).unwrap();
            assert_eq!(o1.result.data(), o2.result.data(), "threads={threads}");
            assert_eq!(o1.flops, o2.flops);
            let per_group = parallel.group_results(&select_results(&all, &picks));
            let o3 = parallel.decode_hierarchical(&per_group).unwrap();
            assert_eq!(o1.result.data(), o3.result.data(), "threads={threads}");
            assert_eq!(o1.flops, o3.flops);
        }
    }

    #[test]
    fn systematic_everything_decodes_free() {
        // If the k1 systematic workers of the k2 systematic groups
        // respond, the whole decode is a reshuffle: 0 flops.
        let code = HierarchicalCode::homogeneous(4, 2, 3, 2).unwrap();
        let mut r = Rng::new(7);
        let a = random_matrix(&mut r, 8, 3);
        let x = random_matrix(&mut r, 3, 1);
        let shards = code.encode(&a).unwrap();
        let all = compute_all_products(&shards, &x);
        let picks: Vec<usize> = (0..2)
            .flat_map(|g| {
                [
                    code.flat_index(WorkerId { group: g, index: 0 }),
                    code.flat_index(WorkerId { group: g, index: 1 }),
                ]
            })
            .collect();
        let out = code.decode(&select_results(&all, &picks), 8).unwrap();
        assert_eq!(out.flops, 0);
        assert!(out.result.max_abs_diff(&ops::matmul(&a, &x)) < 1e-12);
    }
}
