//! Admission-queue counter: the per-model backpressure gate.
//!
//! One [`AdmissionGate`] guards one model's submission queue. Clients
//! reserve a slot at submit time ([`AdmissionGate::try_reserve`]); the
//! batcher releases slots as it dispatches or sheds
//! ([`AdmissionGate::release`]). The whole point of pulling this out of
//! `ModelEntry` is that the reserve/release pair is now a single,
//! model-checkable object: `tests/model_check.rs` proves (exhaustively,
//! for small schedules) that racing reserves never exceed the cap and
//! that releases never underflow the gauge — the double-shed symptom.

use crate::sync::AtomicU64;

/// Bounded admission counter: at most `cap` reservations outstanding.
#[derive(Debug)]
pub struct AdmissionGate {
    /// Admission cap: reservations beyond `cap` outstanding bounce.
    /// Atomic so a control-plane rollout can retune it live; shrinking
    /// below the current gauge only stops *new* reserves — outstanding
    /// reservations drain normally.
    cap: AtomicU64,
    /// Outstanding reservations (requests accepted, not yet released
    /// by dispatch or shed).
    queued: AtomicU64,
}

impl AdmissionGate {
    /// Fresh gate admitting up to `cap` outstanding reservations.
    pub fn new(cap: usize) -> Self {
        Self {
            cap: AtomicU64::new(cap as u64),
            queued: AtomicU64::new(0),
        }
    }

    /// The current admission cap.
    pub fn cap(&self) -> usize {
        self.cap.load() as usize
    }

    /// Retune the admission cap (control-plane hot reload). Takes
    /// effect on the next `try_reserve`; never disturbs outstanding
    /// reservations.
    pub fn set_cap(&self, cap: usize) {
        self.cap.store(cap as u64);
    }

    /// Currently outstanding reservations (gauge; racy by nature, exact
    /// under quiescence).
    pub fn queued(&self) -> u64 {
        self.queued.load()
    }

    /// Try to reserve one queue slot. `true` on success; `false` means
    /// the queue is full and the submission must bounce with `Busy`.
    /// The bounded increment is one atomic step, so concurrent
    /// reserves can never overshoot `cap`.
    pub fn try_reserve(&self) -> bool {
        let cap = self.cap.load();
        self.queued
            .fetch_update(|q| if q < cap { Some(q + 1) } else { None })
            .is_ok()
    }

    /// Release one reserved slot (dispatch or shed). Saturates at zero
    /// — an unpaired release must not wrap the gauge to `u64::MAX` —
    /// and debug builds assert the pairing so the unpaired caller is
    /// caught in tests.
    pub fn release(&self) {
        // fetch_update with a total closure cannot return Err; ignore
        // rather than unwrap so this stays panic-free on the hot path.
        let prev = self
            .queued
            .fetch_update(|q| Some(q.saturating_sub(1)))
            .unwrap_or(0);
        debug_assert!(prev > 0, "admission gauge released below zero (unpaired release)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn reserve_bounces_at_cap_and_release_reopens() {
        let g = AdmissionGate::new(2);
        assert_eq!(g.cap(), 2);
        assert!(g.try_reserve());
        assert!(g.try_reserve());
        assert!(!g.try_reserve(), "third reserve must bounce");
        assert_eq!(g.queued(), 2);
        g.release();
        assert!(g.try_reserve(), "released slot is reusable");
    }

    #[test]
    fn set_cap_retunes_live_without_disturbing_reservations() {
        let g = AdmissionGate::new(1);
        assert!(g.try_reserve());
        assert!(!g.try_reserve(), "cap 1 is full");
        // Rollout raises the cap: new reserves proceed immediately.
        g.set_cap(3);
        assert_eq!(g.cap(), 3);
        assert!(g.try_reserve());
        // Rollout shrinks below the outstanding gauge: new reserves
        // bounce, outstanding reservations drain normally.
        g.set_cap(1);
        assert!(!g.try_reserve());
        assert_eq!(g.queued(), 2, "shrinking never cancels reservations");
        g.release();
        g.release();
        assert!(g.try_reserve(), "drained gauge reopens under the new cap");
    }

    #[test]
    fn zero_cap_rejects_everything() {
        let g = AdmissionGate::new(0);
        assert!(!g.try_reserve());
        assert_eq!(g.queued(), 0);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn release_saturates_at_zero_in_release_builds() {
        let g = AdmissionGate::new(4);
        g.release();
        assert_eq!(g.queued(), 0, "unpaired release must clamp, not wrap");
    }

    #[test]
    fn concurrent_reserves_never_exceed_cap() {
        // Stress version of the model-check invariant (example-based;
        // the exhaustive proof lives in tests/model_check.rs).
        let g = Arc::new(AdmissionGate::new(8));
        let admitted: usize = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    let g = Arc::clone(&g);
                    s.spawn(move || (0..100).filter(|_| g.try_reserve()).count())
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().expect("no panics"))
                .sum()
        });
        assert_eq!(admitted, 8, "exactly cap reservations win");
        assert_eq!(g.queued(), 8);
    }
}
