//! Synchronization facade for the concurrent serving stack.
//!
//! Every hand-rolled primitive the coordinator relies on —
//! [`CompletionSlot`](crate::coordinator::CompletionSlot)'s
//! mutex+condvar pair, the admission-queue counters
//! ([`AdmissionGate`]), the master's drain state machine
//! ([`DrainState`]) — goes through the types in this module instead of
//! using `std::sync` directly. That buys two things:
//!
//! 1. **Poison transparency.** `lock()` / `read()` / `write()` return
//!    guards directly, recovering the inner value from a poisoned lock
//!    ([`std::sync::PoisonError::into_inner`]). A panic on one
//!    coordinator thread must not cascade `.expect("poisoned")` panics
//!    through the rest of the thread tree: all state guarded by these
//!    locks is kept consistent at every await-free critical section
//!    boundary, so observing a poisoned lock is always safe here.
//! 2. **Model checking.** Under `--features modelcheck` the same types
//!    compile with instrumentation hooks into [`model`], an in-repo
//!    loom-style exhaustive interleaving explorer (the offline
//!    substitute for the `loom` crate — this build has no external
//!    dependencies). The model-check suite (`tests/model_check.rs`)
//!    drives `CompletionSlot`, `AdmissionGate` and the drain protocol
//!    through **every** schedule of small thread counts, proving
//!    first-write-wins, no lost wakeups, no double-shed and
//!    drain-never-hangs rather than spot-checking them.
//!
//! Outside an active exploration (and always in the default build) the
//! wrappers are zero-cost passthroughs to `std::sync`.
//!
//! Known model limitations (documented, deliberate):
//! * `RwLock` is a passthrough even under `modelcheck` — no coordinator
//!   invariant under model test uses reader/writer distinctions.
//! * `Condvar::wait_timeout` behaves as `wait` during exploration:
//!   schedules are untimed, so liveness must come from notifies (which
//!   is exactly what the no-lost-wakeup tests assert).
//! * The explorer is sequentially consistent; it does not model weak
//!   memory reorderings (all facade atomics are `SeqCst`).

use std::sync::PoisonError;
use std::time::Duration;

pub mod admission;
pub mod clock;
pub mod drain;
#[cfg(feature = "modelcheck")]
pub mod model;

pub use admission::AdmissionGate;
pub use clock::{Backoff, Clock, MockClock, WallClock};
pub use drain::DrainState;

/// Poison-transparent mutex; under `modelcheck` an instrumented one.
///
/// `lock()` returns the guard directly: poisoning is recovered, not
/// propagated (see the module docs for why that is sound here).
pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
    #[cfg(feature = "modelcheck")]
    id: usize,
}

impl<T> Mutex<T> {
    /// Fresh mutex owning `t`.
    pub fn new(t: T) -> Self {
        Self {
            inner: std::sync::Mutex::new(t),
            #[cfg(feature = "modelcheck")]
            id: model::next_resource_id(),
        }
    }

    /// Acquire, blocking. Recovers from poisoning instead of panicking.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        #[cfg(feature = "modelcheck")]
        model::mutex_acquire(self.id);
        let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        MutexGuard {
            inner: Some(g),
            mutex: self,
        }
    }

    /// Consume the mutex, returning the inner value (poison-recovered).
    pub fn into_inner(self) -> T {
        self.inner
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner)
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

/// Guard returned by [`Mutex::lock`]. Releases on drop; hand it to
/// [`Condvar::wait`] to sleep on the condition.
pub struct MutexGuard<'a, T> {
    /// `Some` while the real lock is held; taken by `Condvar::wait`
    /// before re-waiting and by `Drop`.
    inner: Option<std::sync::MutexGuard<'a, T>>,
    mutex: &'a Mutex<T>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        (**self).fmt(f)
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.inner.take() {
            drop(g);
            #[cfg(feature = "modelcheck")]
            model::mutex_release(self.mutex.id);
        }
    }
}

/// Condition variable paired with [`Mutex`]; wait/notify semantics of
/// [`std::sync::Condvar`], instrumented under `modelcheck`.
pub struct Condvar {
    inner: std::sync::Condvar,
    #[cfg(feature = "modelcheck")]
    id: usize,
}

impl Condvar {
    /// Fresh condition variable.
    pub fn new() -> Self {
        Self {
            inner: std::sync::Condvar::new(),
            #[cfg(feature = "modelcheck")]
            id: model::next_resource_id(),
        }
    }

    /// Atomically release `guard`'s mutex and sleep until notified;
    /// returns with the mutex re-acquired. Poisoning is recovered.
    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let mutex = guard.mutex;
        let inner = guard.inner.take().expect("guard already released");
        std::mem::forget(guard);
        #[cfg(feature = "modelcheck")]
        if model::active() {
            // Exploration path: the real guard is dropped here, the
            // atomic release-and-enqueue happens inside the scheduler
            // (no other thread runs in between — this thread still
            // holds the schedule grant), and the re-acquired real lock
            // is uncontended by construction.
            drop(inner);
            model::condvar_wait(self.id, mutex.id);
            let g = mutex.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return MutexGuard {
                inner: Some(g),
                mutex,
            };
        }
        let g = self.inner.wait(inner).unwrap_or_else(PoisonError::into_inner);
        MutexGuard {
            inner: Some(g),
            mutex,
        }
    }

    /// [`Condvar::wait`] bounded by `timeout`; the `bool` is `true` if
    /// the wait timed out. Under exploration this never times out —
    /// schedules are untimed, so termination must come from notifies.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        timeout: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let mutex = guard.mutex;
        let inner = guard.inner.take().expect("guard already released");
        std::mem::forget(guard);
        #[cfg(feature = "modelcheck")]
        if model::active() {
            drop(inner);
            let _ = timeout;
            model::condvar_wait(self.id, mutex.id);
            let g = mutex.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return (
                MutexGuard {
                    inner: Some(g),
                    mutex,
                },
                false,
            );
        }
        let (g, res) = self
            .inner
            .wait_timeout(inner, timeout)
            .unwrap_or_else(PoisonError::into_inner);
        (
            MutexGuard {
                inner: Some(g),
                mutex,
            },
            res.timed_out(),
        )
    }

    /// Wake every thread waiting on this condition.
    pub fn notify_all(&self) {
        #[cfg(feature = "modelcheck")]
        if model::active() {
            model::condvar_notify_all(self.id);
            return;
        }
        self.inner.notify_all();
    }

    /// Wake one waiting thread (under exploration: the lowest-id
    /// waiter — a documented determinization of std's "any waiter").
    pub fn notify_one(&self) {
        #[cfg(feature = "modelcheck")]
        if model::active() {
            model::condvar_notify_one(self.id);
            return;
        }
        self.inner.notify_one();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

/// Poison-transparent reader-writer lock. A passthrough to
/// [`std::sync::RwLock`] in every build (see module docs): no model
/// test exercises reader parallelism, and recovering poison is the
/// behavior the coordinator needs everywhere it reads shared tables.
pub struct RwLock<T> {
    inner: std::sync::RwLock<T>,
}

impl<T> RwLock<T> {
    /// Fresh lock owning `t`.
    pub fn new(t: T) -> Self {
        Self {
            inner: std::sync::RwLock::new(t),
        }
    }

    /// Acquire shared, blocking; poison-recovered.
    pub fn read(&self) -> std::sync::RwLockReadGuard<'_, T> {
        self.inner.read().unwrap_or_else(PoisonError::into_inner)
    }

    /// Acquire exclusive, blocking; poison-recovered.
    pub fn write(&self) -> std::sync::RwLockWriteGuard<'_, T> {
        self.inner.write().unwrap_or_else(PoisonError::into_inner)
    }

    /// Consume the lock, returning the inner value (poison-recovered).
    pub fn into_inner(self) -> T {
        self.inner
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner)
    }
}

impl<T: Default> Default for RwLock<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

/// Sequentially-consistent atomic counter, instrumented under
/// `modelcheck` (one schedule decision point before every operation).
/// Used by [`AdmissionGate`]; plain statistics counters keep using
/// `std::sync::atomic` directly — their races are benign by design and
/// not worth exploration states.
pub struct AtomicU64 {
    inner: std::sync::atomic::AtomicU64,
}

impl AtomicU64 {
    /// Fresh counter at `v`.
    pub fn new(v: u64) -> Self {
        Self {
            inner: std::sync::atomic::AtomicU64::new(v),
        }
    }

    /// Read the current value.
    pub fn load(&self) -> u64 {
        #[cfg(feature = "modelcheck")]
        model::maybe_yield();
        self.inner.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Overwrite the current value (control-plane retunes).
    pub fn store(&self, v: u64) {
        #[cfg(feature = "modelcheck")]
        model::maybe_yield();
        self.inner.store(v, std::sync::atomic::Ordering::SeqCst)
    }

    /// Atomic read-modify-write: retries `f` until the exchange wins
    /// (the retry loop makes this a single atomic step — the model
    /// treats it as one operation, which is equivalent). Returns
    /// `Ok(previous)` when `f` returned `Some`, `Err(current)` when it
    /// bailed with `None`.
    pub fn fetch_update<F: FnMut(u64) -> Option<u64>>(&self, f: F) -> Result<u64, u64> {
        #[cfg(feature = "modelcheck")]
        model::maybe_yield();
        self.inner.fetch_update(
            std::sync::atomic::Ordering::SeqCst,
            std::sync::atomic::Ordering::SeqCst,
            f,
        )
    }
}

impl std::fmt::Debug for AtomicU64 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mutex_lock_and_into_inner() {
        let m = Mutex::new(5usize);
        *m.lock() += 2;
        assert_eq!(*m.lock(), 7);
        assert_eq!(m.into_inner(), 7);
    }

    #[test]
    fn mutex_recovers_from_poison() {
        let m = Arc::new(Mutex::new(0usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        // A poisoned facade lock hands out the value, not a panic.
        assert_eq!(*m.lock(), 0);
    }

    #[test]
    fn rwlock_read_write_and_poison_recovery() {
        let l = Arc::new(RwLock::new(1usize));
        *l.write() = 2;
        assert_eq!(*l.read(), 2);
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*l.read(), 2);
        match Arc::try_unwrap(l) {
            Ok(inner) => assert_eq!(inner.into_inner(), 2),
            Err(_) => panic!("sole owner"),
        }
    }

    #[test]
    fn condvar_wait_and_notify() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock();
            while !*g {
                g = cv.wait(g);
            }
        });
        std::thread::sleep(Duration::from_millis(10));
        let (m, cv) = &*pair;
        *m.lock() = true;
        cv.notify_all();
        h.join().expect("waiter exits");
    }

    #[test]
    fn condvar_wait_timeout_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.lock();
        let (_g, timed_out) = cv.wait_timeout(g, Duration::from_millis(5));
        assert!(timed_out);
    }

    #[test]
    fn atomic_fetch_update_bounded() {
        let a = AtomicU64::new(0);
        assert_eq!(a.fetch_update(|v| (v < 2).then_some(v + 1)), Ok(0));
        assert_eq!(a.fetch_update(|v| (v < 2).then_some(v + 1)), Ok(1));
        assert_eq!(a.fetch_update(|v| (v < 2).then_some(v + 1)), Err(2));
        assert_eq!(a.load(), 2);
    }
}
