//! The master's drain state machine, as an explicit type.
//!
//! The master loop used to track "how many jobs are in flight" and "am
//! I draining" as two loose locals whose interplay decided when the
//! thread could exit. [`DrainState`] makes that interplay a pure,
//! deterministic state machine: the loop feeds it events
//! (`job_dispatched`, `job_settled`, `begin_drain`) and exits exactly
//! when a transition reports `true`. Pure state means it is unit- and
//! model-testable in isolation — `tests/model_check.rs` drives it
//! through every interleaving of a mini master protocol and proves the
//! drain handshake can never hang (there is always a future transition
//! that reports exit once drain has begun and jobs keep settling).

/// Tracks in-flight jobs and the drain request; decides loop exit.
///
/// Invariant: `can_exit()` ⇔ `draining && active == 0`, and every
/// transition returns whether that just became true, so callers never
/// re-derive the exit condition from raw counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DrainState {
    /// Jobs dispatched and not yet settled (completed/failed/cancelled).
    active: usize,
    /// A drain was requested: no new work will arrive; exit when idle.
    draining: bool,
}

impl DrainState {
    /// Fresh state: nothing in flight, not draining.
    pub fn new() -> Self {
        Self::default()
    }

    /// Jobs currently in flight.
    pub fn active(&self) -> usize {
        self.active
    }

    /// Whether a drain has been requested.
    pub fn draining(&self) -> bool {
        self.draining
    }

    /// A job entered flight.
    pub fn job_dispatched(&mut self) {
        self.active += 1;
    }

    /// A job settled (completed, failed or cancelled). Returns `true`
    /// iff the loop may now exit (draining and nothing left in flight).
    /// Saturates rather than underflows if a settle was double-counted
    /// — the exit condition stays monotone either way.
    pub fn job_settled(&mut self) -> bool {
        self.active = self.active.saturating_sub(1);
        self.can_exit()
    }

    /// Drain requested: no further dispatches will arrive. Returns
    /// `true` iff the loop may exit immediately (nothing in flight).
    pub fn begin_drain(&mut self) -> bool {
        self.draining = true;
        self.can_exit()
    }

    /// The exit condition: draining with nothing in flight.
    pub fn can_exit(&self) -> bool {
        self.draining && self.active == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_requires_drain_and_idle() {
        let mut d = DrainState::new();
        assert!(!d.can_exit(), "fresh state must not exit");
        d.job_dispatched();
        assert!(!d.begin_drain(), "job still in flight");
        assert!(d.job_settled(), "last settle under drain exits");
        assert!(d.can_exit());
    }

    #[test]
    fn drain_on_idle_exits_immediately() {
        let mut d = DrainState::new();
        assert!(d.begin_drain());
    }

    #[test]
    fn settle_without_drain_never_exits() {
        let mut d = DrainState::new();
        d.job_dispatched();
        d.job_dispatched();
        assert!(!d.job_settled());
        assert!(!d.job_settled());
        assert!(!d.can_exit(), "idle but not draining");
        assert!(d.begin_drain());
    }

    #[test]
    fn double_settle_saturates_and_exit_stays_monotone() {
        let mut d = DrainState::new();
        d.job_dispatched();
        assert!(!d.job_settled());
        // A spurious extra settle must not wrap `active` and un-exit.
        assert!(!d.job_settled());
        assert_eq!(d.active(), 0);
        assert!(d.begin_drain());
        assert!(d.can_exit());
    }

    #[test]
    fn interleaved_dispatch_and_settle_under_drain() {
        let mut d = DrainState::new();
        d.job_dispatched();
        d.job_dispatched();
        assert!(!d.begin_drain());
        assert!(!d.job_settled(), "one job still active");
        assert!(d.job_settled(), "last settle exits");
    }
}
