//! In-repo exhaustive interleaving explorer (loom-style model checker).
//!
//! The build environment is fully offline, so instead of the `loom`
//! crate this module implements the same idea from scratch: run a
//! small concurrent test body many times, once per **schedule** — a
//! distinct interleaving of the threads' synchronization operations —
//! until every schedule has been tried. Real OS threads execute the
//! body, but a controller (the [`explore`] caller) grants exactly one
//! thread the right to run at any moment; threads hand the grant back
//! at every *yield point* (mutex acquire, condvar wait/notify, atomic
//! op, spawn, join). At each step where more than one thread is
//! runnable, the controller records a decision; depth-first search
//! over those decisions with deterministic replay enumerates the full
//! schedule space.
//!
//! What this checks, and how:
//! * **Safety invariants** — assertions inside the body run under
//!   every schedule; any failing interleaving is reported with the
//!   decision trace that reproduces it.
//! * **Liveness (no lost wakeups, drain-never-hangs)** — a schedule in
//!   which every unfinished thread is blocked is a deadlock; the
//!   controller detects it immediately (no timeouts involved) and
//!   reports which thread is blocked on what.
//!
//! Model granularity (documented simplifications):
//! * Sequentially consistent: no weak-memory reordering is modeled.
//!   The facade's atomics are `SeqCst`, so the model matches the code.
//! * `notify_one` wakes the lowest-id waiter instead of branching the
//!   schedule on the choice of waiter. The coordinator only uses
//!   `notify_all`.
//! * Timeouts never fire during exploration (see
//!   [`super::Condvar::wait_timeout`]).
//!
//! The explorer refuses to silently truncate: if the schedule space
//! exceeds the caller's `max_schedules` bound it panics, so a test
//! that passes really did run exhaustively.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, Once, PoisonError};

/// Global resource-id allocator for facade mutexes/condvars. Ids only
/// need uniqueness; per-schedule determinism follows from the
/// single-runner discipline (objects are created in schedule order).
static NEXT_RESOURCE_ID: AtomicUsize = AtomicUsize::new(0);

pub(crate) fn next_resource_id() -> usize {
    NEXT_RESOURCE_ID.fetch_add(1, Ordering::Relaxed)
}

/// Sentinel panic payload used to unwind suspended threads when a
/// schedule aborts (assertion failure or deadlock elsewhere). Filtered
/// by the quiet panic hook and by [`finish`].
struct Cancelled;

fn cancel_unwind() -> ! {
    std::panic::panic_any(Cancelled)
}

/// What a model thread is blocked on.
#[derive(Clone, Debug, PartialEq, Eq)]
enum BlockOn {
    /// Waiting to acquire facade mutex `#id`.
    Mutex(usize),
    /// Waiting on facade condvar `#id`.
    Condvar(usize),
    /// Waiting for thread `tid` to finish.
    Join(usize),
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum TState {
    Runnable,
    Blocked(BlockOn),
    Finished,
}

struct Shared {
    /// Per-thread scheduler state, indexed by tid.
    states: Vec<TState>,
    /// The thread currently holding the run grant, if any.
    running: Option<usize>,
    /// Facade-mutex ownership: resource id → owning tid.
    owners: HashMap<usize, usize>,
    /// Set when the schedule is being torn down early.
    abort: bool,
    /// First non-sentinel panic message observed this schedule.
    panic_msg: Option<String>,
}

/// One schedule's coordination state, shared by the controller and
/// every model thread of that schedule iteration.
struct Scheduler {
    shared: StdMutex<Shared>,
    cv: StdCondvar,
    handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Scheduler {
    fn new() -> Self {
        Self {
            shared: StdMutex::new(Shared {
                states: Vec::new(),
                running: None,
                owners: HashMap::new(),
                abort: false,
                panic_msg: None,
            }),
            cv: StdCondvar::new(),
            handles: StdMutex::new(Vec::new()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Shared> {
        self.shared.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Sleep until this thread holds the run grant (or the schedule
    /// aborts, in which case this unwinds with the cancel sentinel).
    /// The caller must already have relinquished (`running = None`,
    /// own state set, controller notified).
    fn wait_for_grant<'a>(
        &'a self,
        mut sh: std::sync::MutexGuard<'a, Shared>,
        me: usize,
    ) -> std::sync::MutexGuard<'a, Shared> {
        loop {
            if sh.abort {
                drop(sh);
                cancel_unwind();
            }
            if sh.running == Some(me) {
                return sh;
            }
            sh = self.cv.wait(sh).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Relinquish the grant, let the controller pick the next runner,
    /// and return once this thread is granted again. This is the basic
    /// yield point every instrumented operation goes through.
    fn yield_now(&self, me: usize) {
        let mut sh = self.lock();
        if sh.abort {
            drop(sh);
            cancel_unwind();
        }
        sh.states[me] = TState::Runnable;
        sh.running = None;
        self.cv.notify_all();
        let sh = self.wait_for_grant(sh, me);
        drop(sh);
    }

    /// Acquire logical ownership of mutex `res`, blocking (in model
    /// time) while another thread owns it. No leading yield — callers
    /// that want a pre-acquire decision point do it themselves.
    fn acquire_no_yield(&self, me: usize, res: usize) {
        loop {
            let mut sh = self.lock();
            if sh.abort {
                drop(sh);
                cancel_unwind();
            }
            if let std::collections::hash_map::Entry::Vacant(e) = sh.owners.entry(res) {
                e.insert(me);
                return;
            }
            sh.states[me] = TState::Blocked(BlockOn::Mutex(res));
            sh.running = None;
            self.cv.notify_all();
            let sh = self.wait_for_grant(sh, me);
            drop(sh);
            // Granted again: some owner released. Retry the acquire —
            // another thread may have been granted first and taken it.
        }
    }

    /// Release logical ownership of `res` and make its waiters
    /// runnable. Not a yield point (the next operation of the caller
    /// yields, and the woken waiters create the decision); must also
    /// be safe to call mid-unwind, so it never blocks or panics.
    fn release(&self, me: usize, res: usize) {
        let mut sh = self.lock();
        let owner = sh.owners.remove(&res);
        debug_assert!(
            owner == Some(me) || sh.abort,
            "release of mutex #{res} by non-owner t{me} (owner {owner:?})"
        );
        wake_mutex_waiters(&mut sh, res);
        self.cv.notify_all();
    }

    /// Atomically release `mutex_id` and enqueue on condvar `cv_id`;
    /// once notified and granted, re-acquire the mutex.
    fn cond_wait(&self, me: usize, cv_id: usize, mutex_id: usize) {
        {
            let mut sh = self.lock();
            if sh.abort {
                drop(sh);
                cancel_unwind();
            }
            // The release and the enqueue happen in one critical
            // section with no other thread running: this is the atomic
            // release-and-wait a real condvar guarantees.
            sh.owners.remove(&mutex_id);
            wake_mutex_waiters(&mut sh, mutex_id);
            sh.states[me] = TState::Blocked(BlockOn::Condvar(cv_id));
            sh.running = None;
            self.cv.notify_all();
            let sh = self.wait_for_grant(sh, me);
            drop(sh);
        }
        self.acquire_no_yield(me, mutex_id);
    }

    /// Make every waiter of condvar `cv_id` runnable.
    fn cond_notify(&self, me: usize, cv_id: usize, all: bool) {
        // Decision point before the notify: it may race with waits.
        self.yield_now(me);
        let mut sh = self.lock();
        if sh.abort {
            drop(sh);
            cancel_unwind();
        }
        for st in sh.states.iter_mut() {
            if *st == TState::Blocked(BlockOn::Condvar(cv_id)) {
                *st = TState::Runnable;
                if !all {
                    // Lowest-tid waiter: deterministic stand-in for
                    // std's "any one waiter" (see module docs).
                    break;
                }
            }
        }
        self.cv.notify_all();
    }
}

/// Wake every thread blocked acquiring mutex `res`. They re-contend
/// when granted; losers block again.
fn wake_mutex_waiters(sh: &mut Shared, res: usize) {
    for st in sh.states.iter_mut() {
        if *st == TState::Blocked(BlockOn::Mutex(res)) {
            *st = TState::Runnable;
        }
    }
}

/// Per-thread context: which schedule this thread belongs to.
struct Ctx {
    sched: Arc<Scheduler>,
    tid: usize,
}

thread_local! {
    static CTX: std::cell::RefCell<Option<Ctx>> = const { std::cell::RefCell::new(None) };
}

fn ctx() -> Option<(Arc<Scheduler>, usize)> {
    CTX.with(|c| c.borrow().as_ref().map(|x| (Arc::clone(&x.sched), x.tid)))
}

/// True when the calling thread is executing under an active
/// exploration (the facade branches on this).
pub fn active() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// Facade hook: decision point before an atomic operation.
pub(crate) fn maybe_yield() {
    if let Some((sched, me)) = ctx() {
        sched.yield_now(me);
    }
}

/// Facade hook: logical mutex acquire (with a pre-acquire decision
/// point). No-op outside exploration.
pub(crate) fn mutex_acquire(id: usize) {
    if let Some((sched, me)) = ctx() {
        sched.yield_now(me);
        sched.acquire_no_yield(me, id);
    }
}

/// Facade hook: logical mutex release. No-op outside exploration.
pub(crate) fn mutex_release(id: usize) {
    if let Some((sched, me)) = ctx() {
        sched.release(me, id);
    }
}

/// Facade hook: condvar wait choreography. The caller (the facade)
/// must have dropped the real guard already and re-locks after.
pub(crate) fn condvar_wait(cv_id: usize, mutex_id: usize) {
    if let Some((sched, me)) = ctx() {
        sched.cond_wait(me, cv_id, mutex_id);
    }
}

/// Facade hook: wake all condvar waiters.
pub(crate) fn condvar_notify_all(cv_id: usize) {
    if let Some((sched, me)) = ctx() {
        sched.cond_notify(me, cv_id, true);
    }
}

/// Facade hook: wake one condvar waiter.
pub(crate) fn condvar_notify_one(cv_id: usize) {
    if let Some((sched, me)) = ctx() {
        sched.cond_notify(me, cv_id, false);
    }
}

/// Handle to a thread spawned with [`spawn`] inside a model body.
pub struct JoinHandle {
    sched: Arc<Scheduler>,
    tid: usize,
}

impl JoinHandle {
    /// Wait (in model time) for the thread to finish. Panics in the
    /// target thread abort the whole schedule, so this returns `()`.
    pub fn join(self) {
        let (_, me) = ctx().expect("JoinHandle::join outside explore()");
        let sched = &self.sched;
        sched.yield_now(me);
        loop {
            let mut sh = sched.lock();
            if sh.abort {
                drop(sh);
                cancel_unwind();
            }
            if sh.states[self.tid] == TState::Finished {
                return;
            }
            sh.states[me] = TState::Blocked(BlockOn::Join(self.tid));
            sh.running = None;
            sched.cv.notify_all();
            let sh = sched.wait_for_grant(sh, me);
            drop(sh);
        }
    }
}

/// Spawn a thread inside a model body. Must be called from within
/// [`explore`]'s body (directly or transitively).
pub fn spawn<F: FnOnce() + Send + 'static>(f: F) -> JoinHandle {
    let (sched, me) = ctx().expect("model::spawn outside explore()");
    let tid = {
        let mut sh = sched.lock();
        sh.states.push(TState::Runnable);
        sh.states.len() - 1
    };
    let s2 = Arc::clone(&sched);
    let real = std::thread::Builder::new()
        .name(format!("model-t{tid}"))
        .spawn(move || thread_main(s2, tid, f))
        .expect("failed to spawn model thread");
    sched
        .handles
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .push(real);
    // Decision point: the child may run before the spawner continues.
    sched.yield_now(me);
    JoinHandle { sched, tid }
}

/// Entry wrapper every model thread runs: install the context, wait
/// for the first grant, run the body, record the outcome.
fn thread_main<F: FnOnce()>(sched: Arc<Scheduler>, tid: usize, f: F) {
    CTX.with(|c| {
        *c.borrow_mut() = Some(Ctx {
            sched: Arc::clone(&sched),
            tid,
        });
    });
    let result = catch_unwind(AssertUnwindSafe(|| {
        {
            let sh = sched.lock();
            let sh = sched.wait_for_grant(sh, tid);
            drop(sh);
        }
        f();
    }));
    finish(&sched, tid, result);
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Mark `tid` finished, wake its joiners, record a real panic (the
/// cancel sentinel is teardown, not failure) and hand the grant back.
fn finish(sched: &Scheduler, tid: usize, result: std::thread::Result<()>) {
    let mut sh = sched.lock();
    sh.states[tid] = TState::Finished;
    for st in sh.states.iter_mut() {
        if *st == TState::Blocked(BlockOn::Join(tid)) {
            *st = TState::Runnable;
        }
    }
    if let Err(payload) = result {
        if !payload.is::<Cancelled>() {
            if sh.panic_msg.is_none() {
                sh.panic_msg = Some(payload_message(payload.as_ref()));
            }
            sh.abort = true;
        }
    }
    if sh.running == Some(tid) {
        sh.running = None;
    }
    sched.cv.notify_all();
}

fn payload_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic payload>".to_string())
}

/// Outcome of running one schedule to completion (or failure).
enum Outcome {
    /// All threads finished; the decision trace taken.
    Complete(Vec<(usize, usize)>),
    /// A thread panicked; message plus the reproducing choice trace.
    Panic(String, Vec<usize>),
    /// Every unfinished thread was blocked; description + trace.
    Deadlock(String, Vec<usize>),
}

/// The controller: grant threads one at a time, record decisions,
/// detect completion / panic / deadlock.
fn run_schedule(sched: &Scheduler, replay: &[usize]) -> Outcome {
    let mut choices: Vec<(usize, usize)> = Vec::new();
    let trace = |cs: &[(usize, usize)]| cs.iter().map(|c| c.1).collect::<Vec<_>>();
    let mut sh = sched.lock();
    loop {
        while sh.running.is_some() {
            sh = sched.cv.wait(sh).unwrap_or_else(PoisonError::into_inner);
        }
        if sh.panic_msg.is_some() {
            sh.abort = true;
            sched.cv.notify_all();
            sh = wait_all_finished(sched, sh);
            let msg = sh.panic_msg.clone().unwrap_or_default();
            return Outcome::Panic(msg, trace(&choices));
        }
        let runnable: Vec<usize> = sh
            .states
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == TState::Runnable)
            .map(|(i, _)| i)
            .collect();
        if runnable.is_empty() {
            if sh.states.iter().all(|s| *s == TState::Finished) {
                return Outcome::Complete(choices);
            }
            let desc = describe_blocked(&sh.states);
            sh.abort = true;
            sched.cv.notify_all();
            sh = wait_all_finished(sched, sh);
            return Outcome::Deadlock(desc, trace(&choices));
        }
        let depth = choices.len();
        let pick = replay.get(depth).copied().unwrap_or(0);
        debug_assert!(
            pick < runnable.len(),
            "replay divergence at depth {depth}: pick {pick} of {} runnable \
             (nondeterministic body?)",
            runnable.len()
        );
        let pick = pick.min(runnable.len() - 1);
        choices.push((runnable.len(), pick));
        sh.running = Some(runnable[pick]);
        sched.cv.notify_all();
    }
}

fn wait_all_finished<'a>(
    sched: &'a Scheduler,
    mut sh: std::sync::MutexGuard<'a, Shared>,
) -> std::sync::MutexGuard<'a, Shared> {
    while !sh.states.iter().all(|s| *s == TState::Finished) {
        sh = sched.cv.wait(sh).unwrap_or_else(PoisonError::into_inner);
    }
    sh
}

fn describe_blocked(states: &[TState]) -> String {
    states
        .iter()
        .enumerate()
        .map(|(tid, st)| match st {
            TState::Finished => format!("t{tid}: finished"),
            TState::Runnable => format!("t{tid}: runnable"),
            TState::Blocked(BlockOn::Mutex(r)) => format!("t{tid}: blocked on mutex #{r}"),
            TState::Blocked(BlockOn::Condvar(r)) => {
                format!("t{tid}: waiting on condvar #{r} (never notified)")
            }
            TState::Blocked(BlockOn::Join(t)) => format!("t{tid}: joining t{t}"),
        })
        .collect::<Vec<_>>()
        .join("; ")
}

/// Next DFS replay prefix after a completed schedule, or `None` when
/// the space is exhausted: bump the deepest decision that still has an
/// untried alternative, drop everything below it.
fn next_replay(choices: &[(usize, usize)]) -> Option<Vec<usize>> {
    for depth in (0..choices.len()).rev() {
        let (n, picked) = choices[depth];
        if picked + 1 < n {
            let mut prefix: Vec<usize> =
                choices[..depth].iter().map(|c| c.1).collect();
            prefix.push(picked + 1);
            return Some(prefix);
        }
    }
    None
}

/// Suppress the default "thread panicked" report for the cancel
/// sentinel — teardown of suspended threads is not a failure. All
/// other panics keep the previous hook's behavior.
fn install_quiet_cancel_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<Cancelled>().is_none() {
                prev(info);
            }
        }));
    });
}

/// Run `body` under every schedule of its threads' synchronization
/// operations; returns the number of schedules explored. Panics —
/// with the reproducing decision trace — if any schedule fails an
/// assertion or deadlocks, and panics loudly if the schedule space
/// exceeds `max_schedules` (never truncates silently).
///
/// `body` runs as model thread `t0` and may [`spawn`] further threads.
/// Use `crate::sync` primitives inside; `std::sync` objects are
/// invisible to the scheduler.
pub fn explore<F: Fn() + Send + Sync + 'static>(
    name: &str,
    max_schedules: usize,
    body: F,
) -> usize {
    install_quiet_cancel_hook();
    let body = Arc::new(body);
    let mut replay: Vec<usize> = Vec::new();
    let mut schedules = 0usize;
    loop {
        schedules += 1;
        assert!(
            schedules <= max_schedules,
            "model '{name}': schedule space exceeds {max_schedules} schedules; \
             shrink the test or raise the bound (exploration must stay exhaustive)"
        );
        let sched = Arc::new(Scheduler::new());
        sched.lock().states.push(TState::Runnable); // tid 0: the body
        let b = Arc::clone(&body);
        let s2 = Arc::clone(&sched);
        let root = std::thread::Builder::new()
            .name("model-t0".to_string())
            .spawn(move || thread_main(s2, 0, move || b()))
            .expect("failed to spawn model root thread");
        sched
            .handles
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(root);
        let outcome = run_schedule(&sched, &replay);
        for h in sched
            .handles
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .drain(..)
        {
            let _ = h.join();
        }
        match outcome {
            Outcome::Complete(choices) => match next_replay(&choices) {
                Some(next) => replay = next,
                None => return schedules,
            },
            Outcome::Panic(msg, trace) => panic!(
                "model '{name}': schedule {schedules} failed \
                 (decision trace {trace:?}): {msg}"
            ),
            Outcome::Deadlock(desc, trace) => panic!(
                "model '{name}': deadlock in schedule {schedules} \
                 (decision trace {trace:?}): {desc}"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::{Condvar, Mutex};

    #[test]
    fn explores_both_orders_of_two_threads() {
        // Two threads append their id under a facade mutex; across the
        // exploration both orders must be observed.
        let seen: Arc<StdMutex<std::collections::HashSet<Vec<u8>>>> =
            Arc::new(StdMutex::new(std::collections::HashSet::new()));
        let seen2 = Arc::clone(&seen);
        explore("two orders", 1_000, move || {
            let log = Arc::new(Mutex::new(Vec::<u8>::new()));
            let l2 = Arc::clone(&log);
            let t = spawn(move || l2.lock().push(1));
            log.lock().push(0);
            t.join();
            let order = log.lock().clone();
            seen2.lock().expect("collector").insert(order);
        });
        let seen = seen.lock().expect("collector");
        assert!(seen.contains(&vec![0, 1]), "order 0,1 explored");
        assert!(seen.contains(&vec![1, 0]), "order 1,0 explored");
    }

    #[test]
    fn assertion_failures_report_a_trace() {
        let r = std::panic::catch_unwind(|| {
            explore("seeded failure", 1_000, || {
                let flag = Arc::new(Mutex::new(false));
                let f2 = Arc::clone(&flag);
                let t = spawn(move || *f2.lock() = true);
                // Bug under test: asserts before joining the writer —
                // fails in schedules where the writer runs late.
                assert!(*flag.lock(), "writer must have run (it may not have)");
                t.join();
            });
        });
        let msg = payload_message(r.expect_err("some schedule fails").as_ref());
        assert!(msg.contains("decision trace"), "got: {msg}");
    }

    #[test]
    fn deadlock_is_detected_and_described() {
        let r = std::panic::catch_unwind(|| {
            explore("abba deadlock", 10_000, || {
                let a = Arc::new(Mutex::new(()));
                let b = Arc::new(Mutex::new(()));
                let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
                let t = spawn(move || {
                    let _gb = b2.lock();
                    let _ga = a2.lock();
                });
                let _ga = a.lock();
                let _gb = b.lock();
                drop((_ga, _gb));
                t.join();
            });
        });
        let msg = payload_message(r.expect_err("ABBA must deadlock").as_ref());
        assert!(msg.contains("deadlock"), "got: {msg}");
        assert!(msg.contains("blocked on mutex"), "got: {msg}");
    }

    #[test]
    fn lost_wakeup_bug_is_caught_as_deadlock() {
        // Buggy protocol: the waiter sleeps without re-checking the
        // flag under the lock, so a notify that lands before the wait
        // is lost and the waiter hangs. The explorer must find the
        // schedule that exposes it.
        let r = std::panic::catch_unwind(|| {
            explore("lost wakeup", 10_000, || {
                let pair = Arc::new((Mutex::new(false), Condvar::new()));
                let p2 = Arc::clone(&pair);
                let t = spawn(move || {
                    let (m, cv) = &*p2;
                    *m.lock() = true;
                    cv.notify_all();
                });
                let (m, cv) = &*pair;
                let g = m.lock();
                // BUG: no `while !*g` re-check before waiting.
                let _g = cv.wait(g);
                t.join();
            });
        });
        let msg = payload_message(r.expect_err("lost wakeup must hang").as_ref());
        assert!(msg.contains("never notified"), "got: {msg}");
    }

    #[test]
    fn correct_condvar_protocol_passes_exhaustively() {
        explore("correct handoff", 10_000, || {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = Arc::clone(&pair);
            let t = spawn(move || {
                let (m, cv) = &*p2;
                *m.lock() = true;
                cv.notify_all();
            });
            let (m, cv) = &*pair;
            let mut g = m.lock();
            while !*g {
                g = cv.wait(g);
            }
            drop(g);
            t.join();
        });
    }

    #[test]
    fn schedule_count_is_stable_and_exhaustive() {
        let body = || {
            let m = Arc::new(Mutex::new(0u32));
            let m2 = Arc::clone(&m);
            let t = spawn(move || *m2.lock() += 1);
            *m.lock() += 1;
            t.join();
            assert_eq!(*m.lock(), 2);
        };
        let n1 = explore("count a", 10_000, body);
        let n2 = explore("count b", 10_000, body);
        assert_eq!(n1, n2, "replay must be deterministic");
        assert!(n1 > 1, "two racing increments have multiple schedules");
    }
}
