//! Deterministic time source for the failure detector.
//!
//! The master's liveness sweep and the chaos driver both ask "how many
//! milliseconds have elapsed" through the [`Clock`] trait instead of
//! reading `Instant::now()` directly. Production code uses
//! [`WallClock`]; failure-detector tests use [`MockClock`] and advance
//! time explicitly, so suspect → dead transitions are exercised without
//! a single `thread::sleep`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic millisecond clock. Implementations must be monotonic
/// (successive `now_ms` reads never decrease) but need not be wall
/// time — [`MockClock`] only moves when told to.
pub trait Clock: Send + Sync {
    /// Milliseconds since the clock's epoch (creation for
    /// [`WallClock`], zero for [`MockClock`]).
    fn now_ms(&self) -> u64;
}

/// Real time, measured from the clock's creation.
#[derive(Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    /// Clock whose epoch is "now".
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ms(&self) -> u64 {
        // u64 millis overflow after ~584M years of uptime; saturating
        // keeps the cast total anyway.
        u64::try_from(self.epoch.elapsed().as_millis()).unwrap_or(u64::MAX)
    }
}

/// Manually advanced clock for tests. Starts at zero; `advance` moves
/// it forward. Shared freely across threads (atomic inside).
#[derive(Debug, Default)]
pub struct MockClock {
    ms: AtomicU64,
}

impl MockClock {
    /// Clock frozen at t = 0 ms.
    pub fn new() -> Self {
        Self::default()
    }

    /// Move time forward by `delta_ms`.
    pub fn advance(&self, delta_ms: u64) {
        self.ms.fetch_add(delta_ms, Ordering::SeqCst);
    }

    /// Jump to an absolute time (no-op if `at_ms` is in the past —
    /// the trait promises monotonicity).
    pub fn set(&self, at_ms: u64) {
        self.ms.fetch_max(at_ms, Ordering::SeqCst);
    }
}

impl Clock for MockClock {
    fn now_ms(&self) -> u64 {
        self.ms.load(Ordering::SeqCst)
    }
}

/// Deterministic exponential backoff schedule: doubles from a base
/// delay up to a clamp. Pure arithmetic — the caller owns the actual
/// sleeping (and any clock reads), so the schedule itself is fully
/// reproducible and trivially testable. Used by the transport node's
/// reconnect loop.
#[derive(Debug)]
pub struct Backoff {
    base_ms: u64,
    next_ms: u64,
    max_ms: u64,
}

impl Backoff {
    /// Schedule starting at `base_ms`, doubling, clamped to `max_ms`.
    /// A zero base is lifted to 1 ms so the schedule actually grows.
    pub fn new(base_ms: u64, max_ms: u64) -> Self {
        let base = base_ms.max(1);
        Self {
            base_ms: base,
            next_ms: base,
            max_ms: max_ms.max(base),
        }
    }

    /// The delay to apply for this attempt; the following attempt's
    /// delay doubles (clamped).
    pub fn next_delay_ms(&mut self) -> u64 {
        let d = self.next_ms;
        self.next_ms = self.next_ms.saturating_mul(2).min(self.max_ms);
        d
    }

    /// Reset to the base delay (call after a successful attempt).
    pub fn reset(&mut self) {
        self.next_ms = self.base_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_clock_advances_and_clamps_monotonic() {
        let c = MockClock::new();
        assert_eq!(c.now_ms(), 0);
        c.advance(25);
        assert_eq!(c.now_ms(), 25);
        c.set(100);
        assert_eq!(c.now_ms(), 100);
        c.set(50); // backwards jump ignored
        assert_eq!(c.now_ms(), 100);
    }

    #[test]
    fn wall_clock_is_monotonic() {
        let c = WallClock::new();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
    }

    #[test]
    fn backoff_doubles_clamps_and_resets() {
        let mut b = Backoff::new(10, 80);
        assert_eq!(b.next_delay_ms(), 10);
        assert_eq!(b.next_delay_ms(), 20);
        assert_eq!(b.next_delay_ms(), 40);
        assert_eq!(b.next_delay_ms(), 80);
        assert_eq!(b.next_delay_ms(), 80); // clamped
        b.reset();
        assert_eq!(b.next_delay_ms(), 10);
    }

    #[test]
    fn backoff_degenerate_params_stay_sane() {
        // Zero base lifts to 1 ms and still grows; max below base
        // clamps to base.
        let mut b = Backoff::new(0, 0);
        assert_eq!(b.next_delay_ms(), 1);
        assert_eq!(b.next_delay_ms(), 1);
        let mut b = Backoff::new(100, 5);
        assert_eq!(b.next_delay_ms(), 100);
        assert_eq!(b.next_delay_ms(), 100);
    }
}
