//! The PJRT runtime service: one dedicated thread owns the client and
//! all compiled executables; the rest of the system talks to it through
//! a cloneable [`PjrtRuntime`] handle.
//!
//! Rationale: the `xla` crate's PJRT objects are not `Sync`, and the
//! coordinator runs many worker threads. Funnelling execution through a
//! service thread keeps ownership single-threaded (no unsafe), matches
//! the one-accelerator-per-host deployment the artifacts target, and
//! gives a natural place for the executable cache and execution metrics.

use crate::runtime::artifact::{ArtifactEntry, ArtifactManifest};
use crate::runtime::tensor::Tensor32;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Execution statistics of the runtime service.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// Executions served.
    pub executions: u64,
    /// Artifacts compiled (cache misses).
    pub compiles: u64,
    /// Total seconds inside PJRT execute calls.
    pub execute_seconds: f64,
    /// Total seconds inside compilation.
    pub compile_seconds: f64,
}

enum Request {
    Execute {
        name: String,
        inputs: Vec<Tensor32>,
        reply: mpsc::Sender<Result<Tensor32>>,
    },
    Stats {
        reply: mpsc::Sender<RuntimeStats>,
    },
    Shutdown,
}

/// Cloneable handle to the runtime service thread.
#[derive(Clone)]
pub struct PjrtRuntime {
    tx: mpsc::Sender<Request>,
    manifest: Arc<ArtifactManifest>,
    _joiner: Arc<Joiner>,
}

struct Joiner {
    tx: mpsc::Sender<Request>,
    handle: Mutex<Option<thread::JoinHandle<()>>>,
}

impl Drop for Joiner {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.lock().ok().and_then(|mut g| g.take()) {
            let _ = h.join();
        }
    }
}

impl PjrtRuntime {
    /// Start the service: load the manifest, create the CPU PJRT client
    /// on the service thread, return a handle.
    pub fn start(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = artifact_dir.into();
        let manifest = Arc::new(ArtifactManifest::load(&dir)?);
        manifest.verify_files()?;
        let (tx, rx) = mpsc::channel::<Request>();
        let thread_manifest = Arc::clone(&manifest);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = thread::Builder::new()
            .name("hiercode-pjrt".to_string())
            .spawn(move || service_main(thread_manifest, rx, ready_tx))
            .map_err(|e| Error::Runtime(format!("cannot spawn runtime thread: {e}")))?;
        // Wait for client creation so startup errors surface here.
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("runtime thread died during startup".into()))??;
        Ok(Self {
            tx: tx.clone(),
            manifest,
            _joiner: Arc::new(Joiner {
                tx,
                handle: Mutex::new(Some(handle)),
            }),
        })
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Execute artifact `name` with `inputs`; returns the (single)
    /// output tensor. Blocks until the service thread finishes the call.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor32>) -> Result<Tensor32> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named '{name}'")))?;
        validate_inputs(entry, &inputs)?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| Error::Runtime("runtime service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("runtime service dropped the request".into()))?
    }

    /// Convenience: execute the worker matvec artifact for shard
    /// `(r, d)` × request `(d, b)`.
    pub fn execute_worker(&self, shard: &Tensor32, x: &Tensor32) -> Result<Tensor32> {
        let (r, d) = (shard.shape[0], shard.shape[1]);
        let b = x.shape[1];
        let entry = self
            .manifest
            .find_worker(r, d, b)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no worker artifact for shard {r}x{d}, batch {b} \
                     (add the shape to python/compile/aot.py WORKER_SPECS)"
                ))
            })?
            .name
            .clone();
        self.execute(&entry, vec![shard.clone(), x.clone()])
    }

    /// Fetch execution statistics.
    pub fn stats(&self) -> Result<RuntimeStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| Error::Runtime("runtime service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("runtime service dropped the request".into()))
    }
}

fn validate_inputs(entry: &ArtifactEntry, inputs: &[Tensor32]) -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        return Err(Error::Runtime(format!(
            "artifact {} expects {} inputs, got {}",
            entry.name,
            entry.inputs.len(),
            inputs.len()
        )));
    }
    for (i, (t, expect)) in inputs.iter().zip(&entry.inputs).enumerate() {
        if &t.shape != expect {
            return Err(Error::Runtime(format!(
                "artifact {} input #{i}: shape {:?} != manifest {:?}",
                entry.name, t.shape, expect
            )));
        }
    }
    Ok(())
}

fn service_main(
    manifest: Arc<ArtifactManifest>,
    rx: mpsc::Receiver<Request>,
    ready_tx: mpsc::Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready_tx.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready_tx.send(Err(Error::Runtime(format!(
                "PjRtClient::cpu() failed: {e}"
            ))));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut stats = RuntimeStats::default();
    crate::log_info!(
        "runtime",
        "PJRT service up: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Execute {
                name,
                inputs,
                reply,
            } => {
                let result =
                    serve_execute(&client, &manifest, &mut cache, &mut stats, &name, inputs);
                let _ = reply.send(result);
            }
        }
    }
    crate::log_info!("runtime", "PJRT service shut down ({} executions)", stats.executions);
}

fn serve_execute(
    client: &xla::PjRtClient,
    manifest: &ArtifactManifest,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    stats: &mut RuntimeStats,
    name: &str,
    inputs: Vec<Tensor32>,
) -> Result<Tensor32> {
    let entry = manifest
        .find(name)
        .ok_or_else(|| Error::Runtime(format!("no artifact named '{name}'")))?;
    if !cache.contains_key(name) {
        let t0 = std::time::Instant::now();
        let path = manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
        stats.compiles += 1;
        stats.compile_seconds += t0.elapsed().as_secs_f64();
        crate::log_debug!("runtime", "compiled {name} in {:.1}ms", t0.elapsed().as_secs_f64() * 1e3);
        cache.insert(name.to_string(), exe);
    }
    let exe = cache.get(name).expect("just inserted");
    // Build input literals.
    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| Error::Runtime(format!("reshape input: {e}")))
        })
        .collect::<Result<Vec<_>>>()?;
    let t0 = std::time::Instant::now();
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
    let out_lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("fetch result of {name}: {e}")))?;
    // aot.py lowers with return_tuple=True → 1-tuple.
    let out = out_lit
        .to_tuple1()
        .map_err(|e| Error::Runtime(format!("untuple result of {name}: {e}")))?;
    let data = out
        .to_vec::<f32>()
        .map_err(|e| Error::Runtime(format!("read result of {name}: {e}")))?;
    stats.executions += 1;
    stats.execute_seconds += t0.elapsed().as_secs_f64();
    Tensor32::new(entry.output.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{artifacts_available, default_artifact_dir};

    fn runtime_or_skip() -> Option<PjrtRuntime> {
        let dir = default_artifact_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(PjrtRuntime::start(dir).expect("runtime starts"))
    }

    #[test]
    fn executes_worker_artifact_correctly() {
        let Some(rt) = runtime_or_skip() else { return };
        // worker_matvec_r16_d32_b1: shard (16, 32) @ x (32, 1).
        let mut rng = crate::util::rng::Rng::new(5);
        let shard = Tensor32::new(
            vec![16, 32],
            (0..16 * 32).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        )
        .unwrap();
        let x = Tensor32::new(
            vec![32, 1],
            (0..32).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        )
        .unwrap();
        let out = rt.execute_worker(&shard, &x).unwrap();
        assert_eq!(out.shape, vec![16, 1]);
        // Cross-check against Rust linalg.
        let sm = shard.to_matrix().unwrap();
        let xm = x.to_matrix().unwrap();
        let expect = crate::linalg::ops::matmul(&sm, &xm);
        let got = out.to_matrix().unwrap();
        assert!(
            got.max_abs_diff(&expect) < 1e-4,
            "PJRT vs linalg diff {}",
            got.max_abs_diff(&expect)
        );
    }

    #[test]
    fn executable_cache_hits() {
        let Some(rt) = runtime_or_skip() else { return };
        let shard = Tensor32::zeros(vec![16, 32]);
        let x = Tensor32::zeros(vec![32, 1]);
        rt.execute_worker(&shard, &x).unwrap();
        rt.execute_worker(&shard, &x).unwrap();
        rt.execute_worker(&shard, &x).unwrap();
        let stats = rt.stats().unwrap();
        assert!(stats.executions >= 3);
        assert_eq!(stats.compiles, 1, "one compile, then cache hits");
    }

    #[test]
    fn shape_mismatch_rejected_before_reaching_pjrt() {
        let Some(rt) = runtime_or_skip() else { return };
        let bad_shard = Tensor32::zeros(vec![17, 32]);
        let x = Tensor32::zeros(vec![32, 1]);
        assert!(rt.execute_worker(&bad_shard, &x).is_err());
        let err = rt
            .execute(
                "worker_matvec_r16_d32_b1",
                vec![Tensor32::zeros(vec![16, 32])],
            )
            .unwrap_err();
        assert!(format!("{err}").contains("expects 2 inputs"));
    }

    #[test]
    fn unknown_artifact_rejected() {
        let Some(rt) = runtime_or_skip() else { return };
        assert!(rt.execute("nope", vec![]).is_err());
    }

    #[test]
    fn encode_artifact_matches_rust_encode() {
        let Some(rt) = runtime_or_skip() else { return };
        // encode_n6_k3_r64_d32.
        let (n, k, r, d) = (6, 3, 64, 32);
        let gen = crate::linalg::vandermonde::systematic_mds(n, k).unwrap();
        let g = Tensor32::from_matrix(&gen);
        let mut rng = crate::util::rng::Rng::new(9);
        let blocks = Tensor32::new(
            vec![k, r, d],
            (0..k * r * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        )
        .unwrap();
        let out = rt
            .execute(&format!("encode_n{n}_k{k}_r{r}_d{d}"), vec![g, blocks.clone()])
            .unwrap();
        assert_eq!(out.shape, vec![n, r, d]);
        // Check one coded block (the last parity row) against lincomb.
        let row = gen.row(n - 1);
        for e in 0..r * d {
            let mut acc = 0.0f64;
            for j in 0..k {
                acc += row[j] * blocks.data[j * r * d + e] as f64;
            }
            let got = out.data[(n - 1) * r * d + e] as f64;
            assert!(
                (got - acc).abs() < 1e-3,
                "elem {e}: PJRT {got} vs expected {acc}"
            );
        }
    }
}
