//! Artifact manifest: discovery and shape-checking of AOT outputs.

use crate::config::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// One AOT artifact as described by `manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Logical name, e.g. `worker_matvec_r256_d128_b4`.
    pub name: String,
    /// File name within the artifact directory.
    pub file: String,
    /// L2 entry point (`worker_task` / `encode_task`).
    pub entry: String,
    /// Input shapes in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shape.
    pub output: Vec<usize>,
    /// Element type (always `f32` today).
    pub dtype: String,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    dir: PathBuf,
    entries: Vec<ArtifactEntry>,
}

fn parse_shape(v: &Json, ctx: &str) -> Result<Vec<usize>> {
    v.as_array()
        .ok_or_else(|| Error::Config(format!("{ctx}: shape must be an array")))?
        .iter()
        .map(|d| {
            d.as_usize()
                .ok_or_else(|| Error::Config(format!("{ctx}: bad dimension")))
        })
        .collect()
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let v = Json::parse(text)?;
        let version = v.req_usize("version", "manifest")?;
        crate::util::manifest::check_version("manifest", version as u64, 1)?;
        let arts = v
            .req("artifacts", "manifest")?
            .as_array()
            .ok_or_else(|| Error::Config("manifest: 'artifacts' must be an array".into()))?;
        let mut entries = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let ctx = format!("manifest artifact #{i}");
            let inputs = a
                .req("inputs", &ctx)?
                .as_array()
                .ok_or_else(|| Error::Config(format!("{ctx}: inputs must be an array")))?
                .iter()
                .map(|s| parse_shape(s, &ctx))
                .collect::<Result<Vec<_>>>()?;
            entries.push(ArtifactEntry {
                name: a.req_str("name", &ctx)?,
                file: a.req_str("file", &ctx)?,
                entry: a.req_str("entry", &ctx)?,
                inputs,
                output: parse_shape(a.req("output", &ctx)?, &ctx)?,
                dtype: a.req_str("dtype", &ctx)?,
            });
        }
        Ok(Self { dir, entries })
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Look up by logical name.
    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find the worker-matvec artifact for shard `(r, d)` and batch `b`.
    pub fn find_worker(&self, r: usize, d: usize, b: usize) -> Option<&ArtifactEntry> {
        self.find(&format!("worker_matvec_r{r}_d{d}_b{b}"))
    }

    /// Find the encode artifact for an `(n, k)` code over `(r, d)` blocks.
    pub fn find_encode(&self, n: usize, k: usize, r: usize, d: usize) -> Option<&ArtifactEntry> {
        self.find(&format!("encode_n{n}_k{k}_r{r}_d{d}"))
    }

    /// Absolute path of an entry's HLO text file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Verify every listed file exists on disk.
    pub fn verify_files(&self) -> Result<()> {
        for e in &self.entries {
            let p = self.path_of(e);
            if !p.exists() {
                return Err(Error::Runtime(format!(
                    "manifest lists {} but {} does not exist",
                    e.name,
                    p.display()
                )));
            }
        }
        Ok(())
    }
}

/// True if an artifact directory with a manifest exists — integration
/// tests use this to skip PJRT paths gracefully before `make artifacts`.
pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.json").exists()
}

/// Locate the repo's artifact directory from the test/bench environment
/// (`HIERCODE_ARTIFACTS` override, else `./artifacts`).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("HIERCODE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "worker_matvec_r16_d32_b1", "file": "worker_matvec_r16_d32_b1.hlo.txt",
         "sha256_16": "x", "entry": "worker_task",
         "inputs": [[16, 32], [32, 1]], "output": [16, 1], "dtype": "f32"},
        {"name": "encode_n6_k3_r64_d32", "file": "encode_n6_k3_r64_d32.hlo.txt",
         "sha256_16": "y", "entry": "encode_task",
         "inputs": [[6, 3], [3, 64, 32]], "output": [6, 64, 32], "dtype": "f32"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.entries().len(), 2);
        let w = m.find_worker(16, 32, 1).unwrap();
        assert_eq!(w.entry, "worker_task");
        assert_eq!(w.inputs, vec![vec![16, 32], vec![32, 1]]);
        assert_eq!(w.output, vec![16, 1]);
        let e = m.find_encode(6, 3, 64, 32).unwrap();
        assert_eq!(e.output, vec![6, 64, 32]);
        assert!(m.find("nonexistent").is_none());
        assert!(m.find_worker(17, 32, 1).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = r#"{"version": 2, "artifacts": []}"#;
        assert!(ArtifactManifest::parse(bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_malformed_entries() {
        let bad = r#"{"version": 1, "artifacts": [{"name": "x"}]}"#;
        assert!(ArtifactManifest::parse(bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn verify_files_catches_missing() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/nonexistent-dir")).unwrap();
        assert!(m.verify_files().is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Exercises the real artifact dir when `make artifacts` has run.
        let dir = default_artifact_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(!m.entries().is_empty());
        m.verify_files().unwrap();
    }
}
