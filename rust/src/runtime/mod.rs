//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute
//! them from the Rust request path.
//!
//! * [`artifact`] — parses `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`) and shape-checks every entry;
//! * [`tensor`] — the plain `f32` tensor type crossing the boundary;
//! * [`service`] — a dedicated runtime thread that owns the
//!   `PjRtClient` and all compiled executables, serving execute requests
//!   over channels (PJRT objects never cross threads), with lazy
//!   compile-on-first-use and a per-artifact executable cache.
//!
//! Interchange is HLO **text**: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` (see
//! /opt/xla-example/README.md for why serialized protos don't work).

pub mod artifact;
pub mod service;
pub mod tensor;

pub use artifact::{ArtifactEntry, ArtifactManifest};
pub use service::{PjrtRuntime, RuntimeStats};
pub use tensor::Tensor32;
