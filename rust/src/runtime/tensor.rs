//! The `f32` tensor type crossing the Rust↔PJRT boundary.

use crate::linalg::Matrix;
use crate::{Error, Result};

/// A dense row-major `f32` tensor with explicit shape — what PJRT
/// executables consume and produce. The coordinator's `f64` matrices
/// convert at this boundary (artifacts are compiled for `f32`, the
/// dtype the paper's workloads — ML gradients, page-rank — use).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor32 {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major data; `len == shape.iter().product()`.
    pub data: Vec<f32>,
}

impl Tensor32 {
    /// Build, validating the element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(Error::Runtime(format!(
                "tensor data length {} != shape {:?} product {expect}",
                data.len(),
                shape
            )));
        }
        Ok(Self { shape, data })
    }

    /// Zero-filled tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self {
            shape,
            data: vec![0.0; n],
        }
    }

    /// From an `f64` matrix (row-major), narrowing to `f32`.
    pub fn from_matrix(m: &Matrix) -> Self {
        Self {
            shape: vec![m.rows(), m.cols()],
            data: m.data().iter().map(|&x| x as f32).collect(),
        }
    }

    /// To an `f64` matrix; requires a rank-2 shape.
    pub fn to_matrix(&self) -> Result<Matrix> {
        if self.shape.len() != 2 {
            return Err(Error::Runtime(format!(
                "expected rank-2 tensor, got shape {:?}",
                self.shape
            )));
        }
        Matrix::from_vec(
            self.shape[0],
            self.shape[1],
            self.data.iter().map(|&x| x as f64).collect(),
        )
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_length() {
        assert!(Tensor32::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor32::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let t = Tensor32::from_matrix(&m);
        assert_eq!(t.shape, vec![2, 2]);
        let back = t.to_matrix().unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rank3_to_matrix_rejected() {
        let t = Tensor32::zeros(vec![2, 2, 2]);
        assert!(t.to_matrix().is_err());
    }
}
