//! The socket transport hub: the master's side of the multi-process
//! cluster.
//!
//! One [`SocketHub`] lives in the master process. It binds a
//! [`Listener`] (UDS or TCP), accepts one connection per group from
//! `hiercode node` processes, performs the versioned [`wire`] handshake
//! ([`WireMsg::Hello`] → [`WireMsg::Welcome`] / [`WireMsg::Reject`]),
//! and then:
//!
//! * **downstream** — a writer thread per group drains that group's
//!   outbox (a FIFO of encoded frames: retained model `Load`s first,
//!   then the master's `Job` / `Finish` / `Shutdown` stream) into the
//!   socket, so the Load-before-Job ordering the in-memory channels
//!   guarantee holds over the wire too;
//! * **upstream** — a reader thread per connection decodes `Partial`
//!   and `Heartbeat` frames back into [`MasterMsg`]s for the master's
//!   single inbox, re-stamping arrival times locally (an `Instant`
//!   never crosses the wire).
//!
//! Silence semantics carry over exactly: a torn connection clears the
//! group's outbox (sends become drops), its beacons stop, and the
//! failure detector ages the group out — the same path as an in-memory
//! dead channel. A [`FaultPlan`](crate::coordinator::fault::FaultPlan)
//! `LinkSever` becomes a *real* teardown: the hub shuts the stream
//! down and refuses re-handshakes until `LinkHeal`, at which point the
//! node's reconnect-with-backoff loop re-establishes the link and the
//! hub re-ships every retained model shard.

use super::wire::{self, WireMsg, NO_WORKER};
use super::{Listener, Stream, Transport, TransportAddr};
use crate::coordinator::chaos::FaultInjector;
use crate::coordinator::messages::{JobId, MasterMsg, PartialResult, SubmasterMsg};
use crate::coordinator::metrics::Metrics;
use crate::linalg::Matrix;
use crate::sync::{Clock, Condvar, Mutex, WallClock};
use crate::Result;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

/// How long a freshly accepted connection gets to present its `Hello`
/// before the hub drops it (a guard against half-open dials wedging
/// the accept loop).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Per-group link state.
struct GroupLink {
    /// Encoded-frame outbox toward the group's node. `None` while
    /// disconnected: sends are silently dropped — in-memory "dead
    /// receiver" semantics over a socket.
    outbox: Mutex<Option<mpsc::Sender<WireMsg>>>,
    /// The live stream, retained so a fault-plan sever can tear the
    /// connection down for real.
    stream: Mutex<Option<Stream>>,
    /// Fault-plan sever flag: while set, the connection is torn down
    /// and re-handshakes are refused (retryable — the node keeps its
    /// backoff loop alive for the heal).
    severed: AtomicBool,
    /// Whether this group ever completed a handshake — distinguishes a
    /// reconnect (counted) from the initial connect (not).
    ever_connected: AtomicBool,
    /// Reconnects completed on this link.
    reconnects: AtomicU64,
    /// Bytes/frames shipped to and received from this group.
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
    frames_tx: AtomicU64,
    frames_rx: AtomicU64,
}

impl GroupLink {
    fn new() -> Self {
        Self {
            outbox: Mutex::new(None),
            stream: Mutex::new(None),
            severed: AtomicBool::new(false),
            ever_connected: AtomicBool::new(false),
            reconnects: AtomicU64::new(0),
            bytes_tx: AtomicU64::new(0),
            bytes_rx: AtomicU64::new(0),
            frames_tx: AtomicU64::new(0),
            frames_rx: AtomicU64::new(0),
        }
    }
}

/// Per-group transport counters, surfaced through
/// [`SocketHub::group_stats`] into the cluster's metrics snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct GroupLinkStats {
    /// Bytes shipped to this group's node.
    pub bytes_sent: u64,
    /// Bytes received from this group's node.
    pub bytes_received: u64,
    /// Frames shipped to this group's node.
    pub frames_sent: u64,
    /// Frames received from this group's node.
    pub frames_received: u64,
    /// Reconnects completed on this link.
    pub reconnects: u64,
}

/// Shared state between the accept loop, the per-connection reader and
/// writer threads, and the [`Transport`] / [`FaultInjector`] surfaces.
struct HubInner {
    addr: TransportAddr,
    listener: Listener,
    /// Cluster identity carried in the handshake (the config seed):
    /// a node dialed at the wrong cluster is rejected fatally instead
    /// of silently mixing job streams.
    cluster_id: u64,
    links: Vec<GroupLink>,
    /// Connection admission table: `conn[g]` is true while group `g`
    /// holds a live handshaken connection. Guards against duplicate
    /// connections and backs [`SocketHub::wait_connected`].
    conn: Mutex<Vec<bool>>,
    conn_cv: Condvar,
    /// Retained model shards in flat cluster-wide worker order, for
    /// (re)connect re-shipping. Lock order: `models` → `outbox` (a
    /// (re)connect publishes the outbox while holding `models`, so a
    /// concurrent `retain_and_ship` either sees the outbox and ships
    /// directly, or the connect's snapshot already contains the model).
    models: Mutex<Vec<(u32, Vec<Matrix>)>>,
    /// Flat index of each group's first worker.
    group_offsets: Vec<usize>,
    /// Workers per group.
    group_sizes: Vec<usize>,
    metrics: Arc<Metrics>,
    master_tx: mpsc::Sender<MasterMsg>,
    closed: AtomicBool,
    clock: WallClock,
    /// Reader threads spawned per accepted connection (joined at
    /// close, after the streams are shut down).
    readers: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Writer threads (joined at close, after the outboxes are taken —
    /// `mpsc` delivers already-buffered frames after the sender drops,
    /// so queued `Shutdown` frames still flush).
    writers: Mutex<Vec<thread::JoinHandle<()>>>,
}

/// Socket-backed [`Transport`]: listener + per-group framed links.
pub struct SocketHub {
    inner: Arc<HubInner>,
    accept: Mutex<Option<thread::JoinHandle<()>>>,
}

impl SocketHub {
    /// Bind `addr` and start accepting node connections for `groups`
    /// groups. `group_offsets`/`group_sizes` give the flat worker
    /// layout (for `Load` frame addressing), `cluster_id` the identity
    /// nodes must echo in their `Hello`.
    pub fn launch(
        addr: &TransportAddr,
        group_offsets: Vec<usize>,
        group_sizes: Vec<usize>,
        cluster_id: u64,
        metrics: Arc<Metrics>,
        master_tx: mpsc::Sender<MasterMsg>,
    ) -> Result<Arc<Self>> {
        let listener = Listener::bind(addr)?;
        let n2 = group_sizes.len();
        let inner = Arc::new(HubInner {
            addr: addr.clone(),
            listener,
            cluster_id,
            links: (0..n2).map(|_| GroupLink::new()).collect(),
            conn: Mutex::new(vec![false; n2]),
            conn_cv: Condvar::new(),
            models: Mutex::new(Vec::new()),
            group_offsets,
            group_sizes,
            metrics,
            master_tx,
            closed: AtomicBool::new(false),
            clock: WallClock::new(),
            readers: Mutex::new(Vec::new()),
            writers: Mutex::new(Vec::new()),
        });
        let accept_inner = Arc::clone(&inner);
        let accept = thread::Builder::new()
            .name("hiercode-hub".into())
            .spawn(move || accept_loop(&accept_inner))?;
        crate::log_info!("transport", "hub listening on {addr} for {n2} groups");
        Ok(Arc::new(Self {
            inner,
            accept: Mutex::new(Some(accept)),
        }))
    }

    /// Block until every group holds a live connection, or `timeout_ms`
    /// elapses. Returns whether the cluster is fully connected.
    pub fn wait_connected(&self, timeout_ms: u64) -> bool {
        let deadline = self.inner.clock.now_ms().saturating_add(timeout_ms);
        let mut conn = self.inner.conn.lock();
        loop {
            if conn.iter().all(|&c| c) {
                return true;
            }
            let now = self.inner.clock.now_ms();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self
                .inner
                .conn_cv
                .wait_timeout(conn, Duration::from_millis(deadline - now));
            conn = guard;
        }
    }

    /// Groups currently holding a live handshaken connection.
    pub fn connected_groups(&self) -> usize {
        self.inner.conn.lock().iter().filter(|&&c| c).count()
    }

    /// Retain `model`'s shards (flat cluster-wide worker order) and
    /// ship a `Load` frame per worker to every currently connected
    /// group. Future (re)connects re-ship from the retained table.
    pub fn retain_and_ship(&self, model: u32, shards: Vec<Matrix>) {
        // Lock order models → outbox (see `HubInner::models`): holding
        // `models` across the sends means a concurrent reconnect cannot
        // publish an outbox that misses this model.
        let mut models = self.inner.models.lock();
        models.push((model, shards));
        let (id, shards) = match models.last() {
            Some((id, shards)) => (*id, shards),
            None => return,
        };
        for (g, link) in self.inner.links.iter().enumerate() {
            let outbox = link.outbox.lock();
            if let Some(tx) = outbox.as_ref() {
                ship_model_loads(&self.inner, g, id, shards, tx);
            }
        }
    }

    /// Per-group transport counters (snapshot).
    pub fn group_stats(&self) -> Vec<GroupLinkStats> {
        self.inner
            .links
            .iter()
            .map(|l| GroupLinkStats {
                bytes_sent: l.bytes_tx.load(Ordering::Relaxed),
                bytes_received: l.bytes_rx.load(Ordering::Relaxed),
                frames_sent: l.frames_tx.load(Ordering::Relaxed),
                frames_received: l.frames_rx.load(Ordering::Relaxed),
                reconnects: l.reconnects.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Tear the hub down: flush and close every link, stop the accept
    /// loop, join every transport thread, remove the UDS socket file.
    /// Idempotent.
    pub fn close(&self) {
        if self.inner.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        // Take the outboxes first: `mpsc` still delivers frames that
        // were buffered before the sender dropped, so writers flush
        // their queues (including any Shutdown frame) and then exit.
        for link in &self.inner.links {
            link.outbox.lock().take();
        }
        for w in self.inner.writers.lock().drain(..) {
            let _ = w.join();
        }
        // Now tear the streams down so blocked readers see EOF.
        for link in &self.inner.links {
            if let Some(s) = link.stream.lock().take() {
                s.shutdown();
            }
        }
        // Unblock the accept loop with a throwaway self-connection.
        let _ = Stream::connect(&self.inner.addr);
        if let Some(h) = self.accept.lock().take() {
            let _ = h.join();
        }
        for r in self.inner.readers.lock().drain(..) {
            let _ = r.join();
        }
        if let TransportAddr::Uds(path) = &self.inner.addr {
            let _ = std::fs::remove_file(path);
        }
        crate::log_debug!("transport", "hub on {} closed", self.inner.addr);
    }
}

impl Drop for SocketHub {
    fn drop(&mut self) {
        self.close();
    }
}

impl Transport for SocketHub {
    fn groups(&self) -> usize {
        self.inner.links.len()
    }

    fn send(&self, group: usize, msg: SubmasterMsg) {
        let Some(link) = self.inner.links.get(group) else {
            return;
        };
        // Upstream-only variants never travel master → node.
        let frame = match msg {
            SubmasterMsg::Job(job) => WireMsg::Job {
                id: job.id.0,
                model: job.model.0,
                out_rows: job.out_rows as u64,
                x: (*job.x).clone(),
            },
            SubmasterMsg::Finish(id) => WireMsg::Finish { id: id.0 },
            SubmasterMsg::Shutdown => WireMsg::Shutdown,
            // `Swap` does not cross processes: heavy rollouts are
            // memory-transport only (the gate rejects them on sockets),
            // and node processes rebuild their scheme from re-shipped
            // `Load` frames, not from a swapped trait object.
            SubmasterMsg::Done(_)
            | SubmasterMsg::Heartbeat(_)
            | SubmasterMsg::Swap(_) => return,
        };
        let outbox = link.outbox.lock();
        if let Some(tx) = outbox.as_ref() {
            let _ = tx.send(frame);
        }
        // No outbox = disconnected: dropped silently, the detector's
        // problem — identical to the in-memory dead-receiver path.
    }
}

impl FaultInjector for SocketHub {
    fn worker_crash(&self, group: usize, index: usize) {
        // Workers live in the node's process; the hub cannot reach
        // them. Process-level chaos (kill the node) covers this arm.
        crate::log_warn!(
            "transport",
            "worker_crash({group},{index}) ignored: workers live in node \
             processes — kill the node instead"
        );
    }

    fn worker_restart(&self, group: usize, index: usize) -> f64 {
        crate::log_warn!(
            "transport",
            "worker_restart({group},{index}) ignored: workers live in node \
             processes — respawn the node instead"
        );
        f64::NAN
    }

    fn link_sever(&self, group: usize) {
        let Some(link) = self.inner.links.get(group) else {
            return;
        };
        link.severed.store(true, Ordering::SeqCst);
        // Real teardown: drop the outbox (sends become silence) and
        // shut the stream down so the node sees EOF mid-flight.
        link.outbox.lock().take();
        if let Some(s) = link.stream.lock().take() {
            s.shutdown();
        }
        crate::log_debug!("transport", "severed group {group}'s connection");
    }

    fn link_heal(&self, group: usize) {
        if let Some(link) = self.inner.links.get(group) {
            link.severed.store(false, Ordering::SeqCst);
            crate::log_debug!(
                "transport",
                "healed group {group}: re-handshakes accepted again"
            );
        }
    }

    fn uplink_degrade(&self, group: usize, delay_ms: f64, drop_per_mille: u64) {
        crate::log_warn!(
            "transport",
            "uplink_degrade({group}, {delay_ms}, {drop_per_mille}) ignored: \
             degradation is injected node-side in socket mode"
        );
    }
}

/// Queue one `Load` frame per worker of `group` for `model` into `tx`,
/// addressed by flat cluster-wide index.
fn ship_model_loads(
    inner: &HubInner,
    group: usize,
    model: u32,
    shards: &[Matrix],
    tx: &mpsc::Sender<WireMsg>,
) {
    let off = inner.group_offsets.get(group).copied().unwrap_or(0);
    let n = inner.group_sizes.get(group).copied().unwrap_or(0);
    for j in 0..n {
        let Some(shard) = shards.get(off + j) else {
            continue;
        };
        let _ = tx.send(WireMsg::Load {
            model,
            worker: (off + j) as u32,
            shard: shard.clone(),
        });
    }
}

/// Accept loop: handshake every incoming connection, then hand it to a
/// reader/writer thread pair.
fn accept_loop(inner: &Arc<HubInner>) {
    loop {
        let stream = match inner.listener.accept() {
            Ok(s) => s,
            Err(e) => {
                if inner.closed.load(Ordering::SeqCst) {
                    break;
                }
                crate::log_warn!("transport", "accept failed: {e}");
                continue;
            }
        };
        if inner.closed.load(Ordering::SeqCst) {
            break;
        }
        match handshake(inner, stream) {
            Ok(Some((group, stream))) => {
                if let Err(e) = attach(inner, group, stream) {
                    crate::log_warn!(
                        "transport",
                        "group {group}: attach failed: {e}"
                    );
                }
            }
            Ok(None) => {} // rejected; already counted
            Err(e) => {
                Metrics::inc(&inner.metrics.transport_handshake_failures);
                crate::log_debug!("transport", "handshake failed: {e}");
            }
        }
    }
}

/// Run the server side of the handshake on a fresh connection.
/// `Ok(Some(..))` admits the connection, `Ok(None)` means a `Reject`
/// was delivered, `Err` a protocol/IO failure.
fn handshake(
    inner: &Arc<HubInner>,
    mut stream: Stream,
) -> std::io::Result<Option<(usize, Stream)>> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let (msg, _) = match WireMsg::read_from(&mut stream) {
        Ok(v) => v,
        Err(e) => {
            return Err(std::io::Error::other(format!("bad hello frame: {e}")));
        }
    };
    let WireMsg::Hello {
        protocol,
        group,
        cluster_id,
    } = msg
    else {
        return Err(std::io::Error::other(format!(
            "expected Hello, got kind {}",
            msg.kind()
        )));
    };
    let reject = |stream: &mut Stream, reason: String, retryable: bool| {
        Metrics::inc(&inner.metrics.transport_handshake_failures);
        crate::log_debug!("transport", "rejecting group {group}: {reason}");
        let _ = stream.write_all(&WireMsg::Reject { reason, retryable }.encode());
        Ok(None)
    };
    if protocol != wire::VERSION {
        return reject(
            &mut stream,
            format!(
                "protocol version {protocol} unsupported (hub speaks {})",
                wire::VERSION
            ),
            false,
        );
    }
    let g = group as usize;
    if g >= inner.links.len() {
        return reject(
            &mut stream,
            format!("group {g} out of range (hub has {})", inner.links.len()),
            false,
        );
    }
    if cluster_id != inner.cluster_id {
        return reject(
            &mut stream,
            format!(
                "cluster id mismatch: node {cluster_id}, hub {}",
                inner.cluster_id
            ),
            false,
        );
    }
    if inner.links[g].severed.load(Ordering::SeqCst) {
        return reject(&mut stream, format!("group {g} is severed"), true);
    }
    // Duplicate check and admission are one check-and-set under the
    // conn lock, so two racing dials for the same group cannot both
    // pass.
    {
        let mut conn = inner.conn.lock();
        if conn[g] {
            drop(conn);
            return reject(
                &mut stream,
                format!("group {g} is already connected"),
                true,
            );
        }
        conn[g] = true;
        inner.conn_cv.notify_all();
    }
    stream.write_all(&WireMsg::Welcome.encode())?;
    stream.set_read_timeout(None)?;
    Ok(Some((g, stream)))
}

/// Wire an admitted connection into its group link: publish a fresh
/// outbox pre-loaded with every retained model's shards, then spawn the
/// writer and reader threads.
fn attach(inner: &Arc<HubInner>, group: usize, stream: Stream) -> Result<()> {
    let link = &inner.links[group];
    let write_half = stream.try_clone()?;
    let (tx, rx) = mpsc::channel::<WireMsg>();
    {
        // Snapshot the model table and publish the outbox under one
        // `models` hold: re-shipping and publication are atomic against
        // a concurrent `retain_and_ship`.
        let models = inner.models.lock();
        for (id, shards) in models.iter() {
            ship_model_loads(inner, group, *id, shards, &tx);
        }
        *link.outbox.lock() = Some(tx);
        *link.stream.lock() = Some(stream.try_clone()?);
    }
    if link.ever_connected.swap(true, Ordering::SeqCst) {
        link.reconnects.fetch_add(1, Ordering::Relaxed);
        Metrics::inc(&inner.metrics.transport_reconnects);
        crate::log_info!("transport", "group {group} reconnected");
    } else {
        crate::log_info!("transport", "group {group} connected");
    }
    let w_inner = Arc::clone(inner);
    let writer = thread::Builder::new()
        .name(format!("hiercode-hub-w{group}"))
        .spawn(move || writer_loop(&w_inner, group, write_half, rx))?;
    inner.writers.lock().push(writer);
    let r_inner = Arc::clone(inner);
    let reader = thread::Builder::new()
        .name(format!("hiercode-hub-r{group}"))
        .spawn(move || reader_loop(&r_inner, group, stream))?;
    inner.readers.lock().push(reader);
    Ok(())
}

/// Drain the group's outbox into the socket, counting bytes and
/// frames. Exits when the outbox sender is dropped (disconnect or hub
/// close) or the socket dies.
fn writer_loop(
    inner: &Arc<HubInner>,
    group: usize,
    mut stream: Stream,
    rx: mpsc::Receiver<WireMsg>,
) {
    while let Ok(frame) = rx.recv() {
        let bytes = frame.encode();
        if stream.write_all(&bytes).is_err() {
            // The reader side owns disconnect bookkeeping; just stop
            // consuming — the dropped receiver turns future sends into
            // silence.
            break;
        }
        let n = bytes.len() as u64;
        Metrics::add(&inner.metrics.transport_bytes_sent, n);
        Metrics::inc(&inner.metrics.transport_frames_sent);
        if let Some(link) = inner.links.get(group) {
            link.bytes_tx.fetch_add(n, Ordering::Relaxed);
            link.frames_tx.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Decode upstream frames into [`MasterMsg`]s until the connection
/// dies, then run disconnect bookkeeping so the group reads as silent.
fn reader_loop(inner: &Arc<HubInner>, group: usize, mut stream: Stream) {
    loop {
        let (msg, size) = match WireMsg::read_from(&mut stream) {
            Ok(v) => v,
            Err(e) => {
                if !inner.closed.load(Ordering::SeqCst) {
                    crate::log_debug!(
                        "transport",
                        "group {group} connection lost: {e}"
                    );
                }
                break;
            }
        };
        let n = size as u64;
        Metrics::add(&inner.metrics.transport_bytes_received, n);
        Metrics::inc(&inner.metrics.transport_frames_received);
        if let Some(link) = inner.links.get(group) {
            link.bytes_rx.fetch_add(n, Ordering::Relaxed);
            link.frames_rx.fetch_add(1, Ordering::Relaxed);
        }
        match msg {
            WireMsg::Partial {
                id,
                shard,
                decoded,
                decode_flops,
                data,
            } => {
                // The node's submaster decoded in its own process with
                // its own metrics sink; mirror its decode accounting
                // here so socket-mode counters match the in-memory
                // oracle (the latency sample is a placeholder — decode
                // seconds don't cross the wire).
                if decoded {
                    Metrics::inc(&inner.metrics.group_decodes);
                    Metrics::add(&inner.metrics.decode_flops, decode_flops);
                    inner.metrics.record_group_decode(group, 0.0);
                }
                let _ = inner.master_tx.send(MasterMsg::Partial(PartialResult {
                    id: JobId(id),
                    shard: usize::try_from(shard).unwrap_or(usize::MAX),
                    data,
                    decoded,
                    decode_flops,
                    // Re-stamped at receipt: Instants never cross the
                    // wire (allowlisted — wall-clock at the process
                    // boundary, the decoded bytes are Instant-free).
                    finished_at: std::time::Instant::now(),
                }));
            }
            WireMsg::Heartbeat { group: g, worker } => {
                let _ = inner.master_tx.send(MasterMsg::Heartbeat {
                    group: g as usize,
                    worker: (worker != NO_WORKER).then_some(worker as usize),
                });
            }
            other => {
                crate::log_debug!(
                    "transport",
                    "group {group} sent unexpected kind {} upstream; ignored",
                    other.kind()
                );
            }
        }
    }
    // Disconnect bookkeeping: silence the outbox, clear the stream,
    // free the seat so the node may re-handshake.
    if let Some(link) = inner.links.get(group) {
        link.outbox.lock().take();
        link.stream.lock().take();
    }
    {
        let mut conn = inner.conn.lock();
        if let Some(c) = conn.get_mut(group) {
            *c = false;
        }
        inner.conn_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::JobBroadcast;
    use crate::coordinator::messages::ModelId;
    use std::io::Read as _;

    fn test_addr(tag: &str) -> TransportAddr {
        use std::sync::atomic::AtomicU64 as StdAtomicU64;
        static NEXT: StdAtomicU64 = StdAtomicU64::new(0);
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        TransportAddr::Uds(std::env::temp_dir().join(format!(
            "hiercode-hubtest-{tag}-{}-{n}.sock",
            std::process::id()
        )))
    }

    fn launch_hub(addr: &TransportAddr, n2: usize) -> (Arc<SocketHub>, mpsc::Receiver<MasterMsg>) {
        let (master_tx, master_rx) = mpsc::channel();
        let hub = SocketHub::launch(
            addr,
            (0..n2).map(|g| 2 * g).collect(),
            vec![2; n2],
            42,
            Arc::new(Metrics::with_groups(n2)),
            master_tx,
        )
        .expect("launch hub");
        (hub, master_rx)
    }

    fn hello(group: u32) -> WireMsg {
        WireMsg::Hello {
            protocol: wire::VERSION,
            group,
            cluster_id: 42,
        }
    }

    fn dial(addr: &TransportAddr, msg: &WireMsg) -> (Stream, WireMsg) {
        let mut s = Stream::connect(addr).expect("connect");
        s.write_all(&msg.encode()).expect("send hello");
        let (reply, _) = WireMsg::read_from(&mut s).expect("handshake reply");
        (s, reply)
    }

    #[test]
    fn handshake_welcomes_and_rejects() {
        let addr = test_addr("hs");
        let (hub, _rx) = launch_hub(&addr, 2);
        // Good hello → Welcome.
        let (_s0, reply) = dial(&addr, &hello(0));
        assert!(matches!(reply, WireMsg::Welcome));
        // Admission happens before Welcome is written, so by the time
        // we read the reply the seat is taken.
        assert_eq!(hub.connected_groups(), 1);
        // Duplicate group → retryable Reject.
        let (_s1, reply) = dial(&addr, &hello(0));
        let WireMsg::Reject { retryable, .. } = reply else {
            panic!("expected duplicate reject, got {reply:?}");
        };
        assert!(retryable, "duplicates retry after the holder dies");
        // Out-of-range group → fatal Reject.
        let (_s2, reply) = dial(&addr, &hello(9));
        assert!(matches!(reply, WireMsg::Reject { retryable: false, .. }));
        // Wrong protocol version → fatal Reject.
        let (_s3, reply) = dial(
            &addr,
            &WireMsg::Hello {
                protocol: wire::VERSION + 1,
                group: 1,
                cluster_id: 42,
            },
        );
        assert!(matches!(reply, WireMsg::Reject { retryable: false, .. }));
        // Wrong cluster id → fatal Reject.
        let (_s4, reply) = dial(
            &addr,
            &WireMsg::Hello {
                protocol: wire::VERSION,
                group: 1,
                cluster_id: 7,
            },
        );
        assert!(matches!(reply, WireMsg::Reject { retryable: false, .. }));
        hub.close();
    }

    #[test]
    fn jobs_flow_downstream_and_partials_upstream() {
        let addr = test_addr("flow");
        let (hub, master_rx) = launch_hub(&addr, 1);
        let (mut s, reply) = dial(&addr, &hello(0));
        assert!(matches!(reply, WireMsg::Welcome));
        assert!(hub.wait_connected(2000), "group 0 connects");
        // Master → node: a job broadcast crosses as a Job frame.
        hub.send(
            0,
            SubmasterMsg::Job(JobBroadcast {
                id: JobId(7),
                model: ModelId(1),
                out_rows: 4,
                x: Arc::new(Matrix::identity(2)),
            }),
        );
        let (frame, _) = WireMsg::read_from(&mut s).expect("job frame");
        let WireMsg::Job { id, model, out_rows, .. } = frame else {
            panic!("expected Job, got {frame:?}");
        };
        assert_eq!((id, model, out_rows), (7, 1, 4));
        // Node → master: a decoded partial mirrors the submaster's
        // decode accounting onto the hub's metrics.
        s.write_all(
            &WireMsg::Partial {
                id: 7,
                shard: 0,
                decoded: true,
                decode_flops: 99,
                data: Matrix::identity(2),
            }
            .encode(),
        )
        .expect("send partial");
        let msg = master_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("partial arrives");
        let MasterMsg::Partial(pr) = msg else {
            panic!("expected Partial, got {msg:?}");
        };
        assert_eq!(pr.id, JobId(7));
        assert_eq!(pr.decode_flops, 99);
        // Heartbeats translate, NO_WORKER → submaster beacon.
        s.write_all(
            &WireMsg::Heartbeat {
                group: 0,
                worker: NO_WORKER,
            }
            .encode(),
        )
        .expect("send beacon");
        let msg = master_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("beacon arrives");
        assert!(matches!(
            msg,
            MasterMsg::Heartbeat {
                group: 0,
                worker: None
            }
        ));
        let snap = hub.inner.metrics.snapshot();
        assert_eq!(snap.group_decodes, 1);
        assert_eq!(snap.decode_flops, 99);
        assert!(snap.transport_frames_sent >= 1);
        assert!(snap.transport_frames_received >= 2);
        assert!(snap.transport_bytes_sent > 0);
        assert!(snap.transport_bytes_received > 0);
        let stats = hub.group_stats();
        assert_eq!(stats.len(), 1);
        assert!(stats[0].frames_sent >= 1 && stats[0].frames_received >= 2);
        hub.close();
    }

    #[test]
    fn retained_models_ship_on_connect_and_reconnect_counts() {
        let addr = test_addr("reship");
        let (hub, _rx) = launch_hub(&addr, 1);
        // Retain a model before any node connects: 2 workers in group 0
        // at flat offsets 0 and 1.
        hub.retain_and_ship(3, vec![Matrix::identity(2), Matrix::zeros(2, 2)]);
        let (mut s, reply) = dial(&addr, &hello(0));
        assert!(matches!(reply, WireMsg::Welcome));
        for expect_worker in [0u32, 1] {
            let (frame, _) = WireMsg::read_from(&mut s).expect("load frame");
            let WireMsg::Load { model, worker, .. } = frame else {
                panic!("expected Load, got {frame:?}");
            };
            assert_eq!((model, worker), (3, expect_worker));
        }
        // Tear the connection down node-side; the hub frees the seat.
        s.shutdown();
        drop(s);
        // Reconnect: the retained model re-ships and reconnects counts.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut s2 = loop {
            let (s2, reply) = dial(&addr, &hello(0));
            match reply {
                WireMsg::Welcome => break s2,
                WireMsg::Reject { retryable: true, .. } => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "seat never freed after disconnect"
                    );
                    thread::sleep(Duration::from_millis(10));
                }
                other => panic!("unexpected reply {other:?}"),
            }
        };
        let (frame, _) = WireMsg::read_from(&mut s2).expect("re-shipped load");
        assert!(matches!(frame, WireMsg::Load { model: 3, worker: 0, .. }));
        assert_eq!(hub.group_stats()[0].reconnects, 1);
        assert_eq!(
            hub.inner
                .metrics
                .transport_reconnects
                .load(Ordering::Relaxed),
            1
        );
        hub.close();
    }

    #[test]
    fn sever_tears_down_and_refuses_until_heal() {
        let addr = test_addr("sever");
        let (hub, _rx) = launch_hub(&addr, 1);
        let (mut s, reply) = dial(&addr, &hello(0));
        assert!(matches!(reply, WireMsg::Welcome));
        assert!(hub.wait_connected(2000));
        hub.link_sever(0);
        // The node-side read sees EOF — the sever is a real teardown.
        let mut buf = [0u8; 1];
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match s.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => assert!(std::time::Instant::now() < deadline, "no EOF"),
            }
        }
        // Re-handshakes bounce retryably while severed...
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let (_s, reply) = dial(&addr, &hello(0));
            match reply {
                WireMsg::Reject { retryable, ref reason } => {
                    if reason.contains("severed") {
                        assert!(retryable);
                        break;
                    }
                    // Seat not freed yet: the reader is still tearing
                    // down. Retry.
                    assert!(std::time::Instant::now() < deadline);
                    thread::sleep(Duration::from_millis(10));
                }
                WireMsg::Welcome => panic!("severed group must not connect"),
                other => panic!("unexpected reply {other:?}"),
            }
        }
        // ...and succeed after the heal.
        hub.link_heal(0);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let (_s, reply) = dial(&addr, &hello(0));
            match reply {
                WireMsg::Welcome => break,
                WireMsg::Reject { retryable: true, .. } => {
                    assert!(std::time::Instant::now() < deadline, "heal ignored");
                    thread::sleep(Duration::from_millis(10));
                }
                other => panic!("unexpected reply {other:?}"),
            }
        }
        hub.close();
    }

    #[test]
    fn close_is_idempotent_and_removes_socket_file() {
        let addr = test_addr("close");
        let (hub, _rx) = launch_hub(&addr, 1);
        hub.close();
        hub.close();
        if let TransportAddr::Uds(path) = &addr {
            assert!(!path.exists(), "socket file cleaned up");
        }
    }
}
