//! Transport layer: the links between master ↔ submasters ↔ workers,
//! abstracted so the cluster runs identically over in-process channels
//! and real sockets.
//!
//! The paper's architecture is a tree — master, `n2` submasters, `n1`
//! workers each — and until this layer existed the whole tree lived in
//! one process wired by `mpsc` FIFOs. [`Transport`] abstracts exactly
//! the surface the master uses: a fixed set of downstream group links
//! carrying [`SubmasterMsg`]s, best-effort (a send into a dead link is
//! *silence*, which is precisely the signal the failure detector
//! consumes). Two implementations:
//!
//! - [`memory::MemoryTransport`] — the original in-memory FIFO fan-out,
//!   kept as the bit-identical fast path and the test oracle;
//! - [`socket::SocketHub`] — a listener plus per-group socket
//!   connections (Unix-domain or TCP) carrying the versioned,
//!   checksummed frames of [`wire`], with handshakes,
//!   reconnect-with-backoff and shard re-shipping, so submaster/worker
//!   trees run as separate OS processes (`hiercode node`, driven by
//!   [`node::run_node`]).
//!
//! Silence semantics are load-bearing: neither implementation reports
//! delivery failure to the master. An unreachable group simply stops
//! producing partials and heartbeats, the `FailureDetector` ages it
//! out, and the liveness sweep fails unsatisfiable jobs fast — the
//! same code path for a dropped channel and a torn TCP connection.

pub mod memory;
pub mod node;
pub mod socket;
pub mod wire;

use crate::coordinator::messages::SubmasterMsg;
use crate::{Error, Result};
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

/// The master's view of its downstream links: `groups()` fixed lanes,
/// each carrying [`SubmasterMsg`]s in order, best-effort.
///
/// `send` deliberately returns `()` — delivery failure is expressed as
/// downstream silence, never as an error the master must branch on.
/// That keeps the master's control flow identical across transports,
/// which is what makes the in-memory path a valid oracle for the
/// socket path.
pub trait Transport: Send + Sync {
    /// Number of downstream group links (`n2`).
    fn groups(&self) -> usize;
    /// Enqueue `msg` toward group `group`. Out-of-range groups and
    /// dead links are silently dropped.
    fn send(&self, group: usize, msg: SubmasterMsg);
}

/// A transport endpoint address: `uds:/path/to.sock` or
/// `tcp:host:port`. UDS is the default for local multi-process
/// clusters; the TCP form exists so nothing in the framing or
/// handshake assumes same-host peers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportAddr {
    /// Unix-domain socket path.
    Uds(PathBuf),
    /// TCP `host:port`.
    Tcp(String),
}

impl TransportAddr {
    /// Parse `uds:<path>` or `tcp:<host>:<port>`.
    pub fn parse(s: &str) -> Result<Self> {
        if let Some(path) = s.strip_prefix("uds:") {
            if path.is_empty() {
                return Err(Error::Config("uds: address needs a socket path".into()));
            }
            Ok(Self::Uds(PathBuf::from(path)))
        } else if let Some(hostport) = s.strip_prefix("tcp:") {
            if !hostport.contains(':') {
                return Err(Error::Config(format!(
                    "tcp: address needs host:port, got '{hostport}'"
                )));
            }
            Ok(Self::Tcp(hostport.to_string()))
        } else {
            Err(Error::Config(format!(
                "transport address '{s}' must start with 'uds:' or 'tcp:'"
            )))
        }
    }
}

impl std::fmt::Display for TransportAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Uds(p) => write!(f, "uds:{}", p.display()),
            Self::Tcp(hp) => write!(f, "tcp:{hp}"),
        }
    }
}

/// A bound listener over either address family.
pub enum Listener {
    /// Unix-domain listener.
    Uds(UnixListener),
    /// TCP listener.
    Tcp(std::net::TcpListener),
}

impl Listener {
    /// Bind `addr`. A stale UDS socket file from a dead process is
    /// removed first (the bind would otherwise fail `AddrInUse`
    /// forever — the file outlives its listener).
    pub fn bind(addr: &TransportAddr) -> std::io::Result<Self> {
        match addr {
            TransportAddr::Uds(path) => {
                let _ = std::fs::remove_file(path);
                UnixListener::bind(path).map(Self::Uds)
            }
            TransportAddr::Tcp(hp) => std::net::TcpListener::bind(hp.as_str()).map(Self::Tcp),
        }
    }

    /// Accept one connection.
    pub fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Self::Uds(l) => l.accept().map(|(s, _)| Stream::Uds(s)),
            Self::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        }
    }
}

/// A connected stream over either address family, exposing exactly the
/// operations the hub and node need.
#[derive(Debug)]
pub enum Stream {
    /// Unix-domain stream.
    Uds(UnixStream),
    /// TCP stream.
    Tcp(std::net::TcpStream),
}

impl Stream {
    /// Dial `addr`.
    pub fn connect(addr: &TransportAddr) -> std::io::Result<Self> {
        match addr {
            TransportAddr::Uds(path) => UnixStream::connect(path).map(Self::Uds),
            TransportAddr::Tcp(hp) => std::net::TcpStream::connect(hp.as_str()).map(Self::Tcp),
        }
    }

    /// Clone the underlying descriptor (reader/writer split).
    pub fn try_clone(&self) -> std::io::Result<Self> {
        match self {
            Self::Uds(s) => s.try_clone().map(Self::Uds),
            Self::Tcp(s) => s.try_clone().map(Self::Tcp),
        }
    }

    /// Tear the connection down in both directions: blocked reads on
    /// every clone return EOF — how a fault-plan sever becomes real
    /// downstream silence.
    pub fn shutdown(&self) {
        let how = std::net::Shutdown::Both;
        let _ = match self {
            Self::Uds(s) => s.shutdown(how),
            Self::Tcp(s) => s.shutdown(how),
        };
    }

    /// Bound blocking reads (handshake guard); `None` restores fully
    /// blocking reads for the steady state.
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Self::Uds(s) => s.set_read_timeout(dur),
            Self::Tcp(s) => s.set_read_timeout(dur),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Self::Uds(s) => s.read(buf),
            Self::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Self::Uds(s) => s.write(buf),
            Self::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Self::Uds(s) => s.flush(),
            Self::Tcp(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parses_both_families_and_displays_back() {
        let u = TransportAddr::parse("uds:/tmp/x.sock").unwrap();
        assert_eq!(u, TransportAddr::Uds(PathBuf::from("/tmp/x.sock")));
        assert_eq!(u.to_string(), "uds:/tmp/x.sock");
        let t = TransportAddr::parse("tcp:127.0.0.1:9000").unwrap();
        assert_eq!(t, TransportAddr::Tcp("127.0.0.1:9000".to_string()));
        assert_eq!(t.to_string(), "tcp:127.0.0.1:9000");
    }

    #[test]
    fn addr_rejects_malformed_forms() {
        for bad in ["", "uds:", "tcp:nohost", "udp:/x", "/tmp/x.sock"] {
            assert!(TransportAddr::parse(bad).is_err(), "accepted '{bad}'");
        }
    }
}
