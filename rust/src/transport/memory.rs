//! The in-memory transport: the original `mpsc` fan-out behind the
//! [`Transport`] trait.
//!
//! This is not a shim for tests — it *is* the single-process fast
//! path, byte-for-byte the channel wiring the cluster used before the
//! transport layer existed, and therefore the oracle the socket
//! transport is measured against (`tests/socket_transport.rs` demands
//! bit-identical decode outputs across the two).

use super::Transport;
use crate::coordinator::messages::SubmasterMsg;
use std::sync::mpsc;

/// One `mpsc` sender per submaster. Dropped receivers make `send` a
/// silent no-op — in-memory "silence" matching a torn socket.
pub struct MemoryTransport {
    links: Vec<mpsc::Sender<SubmasterMsg>>,
}

impl MemoryTransport {
    /// Wrap the per-group senders (possibly empty, for master unit
    /// tests that exercise no downstream).
    pub fn new(links: Vec<mpsc::Sender<SubmasterMsg>>) -> Self {
        Self { links }
    }
}

impl Transport for MemoryTransport {
    fn groups(&self) -> usize {
        self.links.len()
    }

    fn send(&self, group: usize, msg: SubmasterMsg) {
        if let Some(tx) = self.links.get(group) {
            let _ = tx.send(msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::JobId;

    #[test]
    fn delivers_in_order_and_drops_dead_or_missing_links() {
        let (tx, rx) = mpsc::channel();
        let (dead_tx, dead_rx) = mpsc::channel::<SubmasterMsg>();
        drop(dead_rx);
        let t = MemoryTransport::new(vec![tx, dead_tx]);
        assert_eq!(t.groups(), 2);
        t.send(0, SubmasterMsg::Finish(JobId(1)));
        t.send(0, SubmasterMsg::Finish(JobId(2)));
        t.send(1, SubmasterMsg::Shutdown); // dead receiver: silence
        t.send(9, SubmasterMsg::Shutdown); // out of range: silence
        assert!(matches!(rx.try_recv(), Ok(SubmasterMsg::Finish(JobId(1)))));
        assert!(matches!(rx.try_recv(), Ok(SubmasterMsg::Finish(JobId(2)))));
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn empty_transport_is_safe() {
        let t = MemoryTransport::new(vec![]);
        assert_eq!(t.groups(), 0);
        t.send(0, SubmasterMsg::Shutdown);
    }
}
