//! The node side of the socket transport: one submaster/worker group
//! running as its own OS process (`hiercode node`), joined to the
//! master's [`SocketHub`](super::socket::SocketHub) by the bootstrap
//! handshake.
//!
//! A node rebuilds the *same* scheme from the *same* config the master
//! loaded, replays the master's launch-time seed stream to recover its
//! own group's worker and submaster RNGs (so a socket-mode cluster
//! computes bit-identically to the in-memory one), then spawns the
//! ordinary [`worker`] and [`submaster`] threads wired by local `mpsc`
//! channels. The process boundary is bridged by exactly two loops:
//!
//! * **downstream** (this thread): dial → handshake → decode frames:
//!   `Load` installs shards into local workers, `Job`/`Finish` feed the
//!   local submaster, `Shutdown` tears the tree down;
//! * **upstream** (the pump thread): the submaster's `MasterMsg`s —
//!   partials and heartbeats — encode into frames and write to the
//!   shared uplink. A dead uplink turns writes into drops: silence,
//!   never an error, mirroring the in-memory dead-channel semantics.
//!
//! A lost connection (hub restart, fault-plan sever) sends the node
//! back to a deterministic dial loop ([`Backoff`]) until the hub
//! re-admits it — at which point the hub re-ships every retained model
//! shard before any new job, restoring the Load-before-Job invariant.

use super::wire::{self, WireMsg, NO_WORKER};
use super::{Stream, TransportAddr};
use crate::config::schema::ClusterConfig;
use crate::coordinator::backend::{ComputeBackend, WorkerShard};
use crate::coordinator::chaos::LivenessConfig;
use crate::coordinator::fault::{FaultConfig, FaultState};
use crate::coordinator::messages::{
    CancelSet, JobBroadcast, JobId, MasterMsg, ModelId, SubmasterMsg, WorkerCmd, WorkerLink,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::submaster::{self, LinkDelay};
use crate::coordinator::worker::{self, WorkerCtx, WorkerDelay};
use crate::runtime::PjrtRuntime;
use crate::sync::{Backoff, Clock, Mutex, RwLock, WallClock};
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::io::Write as _;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

/// How long the node waits for the hub's handshake reply.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Everything a node process needs to join a cluster.
pub struct NodeOptions {
    /// The cluster config — must be byte-for-byte the config the hub's
    /// master loaded (the handshake checks the seed as a cluster id,
    /// catching the obvious mispairings).
    pub config: ClusterConfig,
    /// Which group (`0..n2`) this process hosts.
    pub group: usize,
    /// The hub's listen address.
    pub addr: TransportAddr,
    /// Give up dialing after this long without a successful handshake
    /// (measured per connection attempt window, refreshed on success).
    pub max_dial_ms: u64,
    /// Reconnect backoff base delay.
    pub dial_backoff_ms: u64,
    /// Reconnect backoff clamp.
    pub dial_backoff_max_ms: u64,
}

/// Run one group's submaster/worker tree against the hub at
/// `opts.addr`. Blocks until the hub sends `Shutdown` (clean exit) or
/// the dial window is exhausted / the hub rejects fatally (error).
pub fn run_node(opts: NodeOptions) -> Result<()> {
    let config = &opts.config;
    // Mirror the in-process launch gates exactly: a node must refuse
    // the same configs the master would.
    let partial = config.code.topology.groups.iter().any(|g| g.subtasks > 1);
    if config.runtime.use_pjrt && partial {
        return Err(Error::InvalidParams(
            "partial-work mode (subtasks_per_worker > 1) requires the \
             native backend: sub-shard shapes have no AOT'd PJRT \
             artifacts yet — set runtime.use_pjrt = false"
                .into(),
        ));
    }
    let scheme = config.build_scheme()?;
    let backend = if config.runtime.use_pjrt {
        ComputeBackend::Pjrt(PjrtRuntime::start(config.runtime.artifact_dir.clone())?)
    } else {
        ComputeBackend::Native
    };
    let topology = crate::coordinator::cluster::serving_topology(&scheme, config);
    let n2 = topology.n2();
    if opts.group >= n2 {
        return Err(Error::InvalidParams(format!(
            "node group {} out of range: topology has {n2} groups",
            opts.group
        )));
    }
    let group_sizes = topology.group_sizes();
    let offset: usize = group_sizes.iter().take(opts.group).sum();

    // Replay the master's launch-time seed stream: per group, one
    // `next_u64` per worker then one `split` for the submaster — the
    // exact draw order of `ClusterCore::launch_with_faults`. Only our
    // group's values are kept; later groups' draws can't affect ours,
    // so the replay stops early.
    let mut seed_rng = Rng::new(config.seed);
    let mut worker_seeds = Vec::new();
    let mut sub_rng = None;
    for (g, spec) in topology.groups.iter().enumerate() {
        let mut seeds = Vec::with_capacity(spec.n1);
        for _ in 0..spec.n1 {
            seeds.push(seed_rng.next_u64());
        }
        let r = seed_rng.split();
        if g == opts.group {
            worker_seeds = seeds;
            sub_rng = Some(r);
            break;
        }
    }
    let Some(sub_rng) = sub_rng else {
        return Err(Error::InvalidParams("empty topology".into()));
    };

    // Local fault switchboard: launch-time dead workers from the
    // scenario fold in, same as in-process launch.
    let fault_state = Arc::new(FaultState::from_config(&group_sizes, &FaultConfig::none()));
    for (g, spec) in topology.groups.iter().enumerate() {
        for &j in &spec.dead_workers {
            fault_state.set_worker_dead(g, j, true);
        }
    }
    let liveness = if config.chaos.liveness {
        LivenessConfig::new(
            Duration::from_secs_f64(config.chaos.heartbeat_ms / 1e3),
            Duration::from_secs_f64(config.chaos.suspect_ms / 1e3),
            Duration::from_secs_f64(config.chaos.dead_ms / 1e3),
        )
    } else {
        LivenessConfig::disabled()
    };
    let beat = liveness.beat_period();

    // Node-local metrics sink: the submaster's decode accounting lands
    // here; the hub mirrors the counters that must match the in-memory
    // oracle from the Partial frames it receives.
    let metrics = Arc::new(Metrics::with_groups(n2));
    let (master_tx, master_rx) = mpsc::channel::<MasterMsg>();
    let (sub_tx, sub_rx) = mpsc::channel::<SubmasterMsg>();
    let cancel = Arc::new(CancelSet::new());
    let spec = &topology.groups[opts.group];
    let group_scale = config.straggler.scale * spec.slowdown();
    let mut threads = Vec::with_capacity(spec.n1 + 1);
    let mut group_links: Vec<WorkerLink> = Vec::with_capacity(spec.n1);
    for (j, &seed) in worker_seeds.iter().enumerate() {
        let (w_tx, w_rx) = mpsc::channel::<WorkerCmd>();
        let ctx = WorkerCtx {
            group: opts.group,
            index: j,
            backend: backend.clone(),
            delay: WorkerDelay {
                model: spec.worker,
                scale: group_scale,
                enabled: config.straggler.enabled,
            },
            subtasks: spec.subtasks,
            cancel: Arc::clone(&cancel),
            faults: Arc::clone(&fault_state),
            heartbeat: beat,
            submaster: sub_tx.clone(),
        };
        threads.push(worker::spawn(ctx, Rng::new(seed), w_rx)?);
        group_links.push(Arc::new(RwLock::new(w_tx)));
    }
    threads.push(submaster::spawn(
        opts.group,
        offset,
        Arc::clone(&scheme),
        group_links.clone(),
        LinkDelay {
            model: spec.link,
            scale: group_scale,
            enabled: config.straggler.enabled,
        },
        Arc::clone(&fault_state),
        spec.subtasks,
        beat,
        Arc::clone(&cancel),
        Arc::clone(&metrics),
        sub_rng,
        sub_rx,
        master_tx,
    )?);

    // Upstream pump: submaster → frames → whatever stream currently
    // occupies the uplink slot. `None` (disconnected) or a failed
    // write is a silent drop — real silence, which is exactly what the
    // hub's failure detector is listening for. The pump exits when the
    // submaster (the only sender) hangs up.
    let uplink: Arc<Mutex<Option<Stream>>> = Arc::new(Mutex::new(None));
    let pump_uplink = Arc::clone(&uplink);
    let pump = thread::Builder::new()
        .name(format!("hiercode-node-up{}", opts.group))
        .spawn(move || {
            while let Ok(msg) = master_rx.recv() {
                let frame = match msg {
                    MasterMsg::Partial(pr) => WireMsg::Partial {
                        id: pr.id.0,
                        shard: pr.shard as u64,
                        decoded: pr.decoded,
                        decode_flops: pr.decode_flops,
                        data: pr.data,
                    },
                    MasterMsg::Heartbeat { group, worker } => WireMsg::Heartbeat {
                        group: group as u32,
                        worker: worker.map(|j| j as u32).unwrap_or(NO_WORKER),
                    },
                    _ => continue,
                };
                let bytes = frame.encode();
                let mut slot = pump_uplink.lock();
                if let Some(stream) = slot.as_mut() {
                    if stream.write_all(&bytes).is_err() {
                        *slot = None;
                    }
                }
            }
        })?;

    // Downstream loop: dial, handshake, decode frames until Shutdown.
    let result = downstream_loop(&opts, &uplink, &sub_tx, &group_links, offset, spec.n1);

    // Teardown: make sure the local tree exits even on an error path
    // (the hub's Shutdown already went through `sub_tx` on the clean
    // path; a second one is harmless — the submaster is gone).
    let _ = sub_tx.send(SubmasterMsg::Shutdown);
    drop(sub_tx);
    for t in threads {
        let _ = t.join();
    }
    uplink.lock().take();
    let _ = pump.join();
    crate::log_info!(
        "transport",
        "node group {} exiting: {}",
        opts.group,
        if result.is_ok() { "clean shutdown" } else { "error" }
    );
    result
}

/// Dial/handshake/read until the hub says `Shutdown` (Ok), the hub
/// rejects fatally, or the dial window closes without a connection.
fn downstream_loop(
    opts: &NodeOptions,
    uplink: &Arc<Mutex<Option<Stream>>>,
    sub_tx: &mpsc::Sender<SubmasterMsg>,
    group_links: &[WorkerLink],
    offset: usize,
    n1: usize,
) -> Result<()> {
    let clock = WallClock::new();
    let mut backoff = Backoff::new(opts.dial_backoff_ms, opts.dial_backoff_max_ms);
    loop {
        let mut stream = dial(opts, &clock, &mut backoff)?;
        backoff.reset();
        match stream.try_clone() {
            Ok(up) => *uplink.lock() = Some(up),
            Err(e) => {
                crate::log_warn!("transport", "uplink clone failed: {e}; redialing");
                continue;
            }
        }
        crate::log_info!(
            "transport",
            "node group {} connected to {}",
            opts.group,
            opts.addr
        );
        loop {
            let (msg, _) = match WireMsg::read_from(&mut stream) {
                Ok(v) => v,
                Err(e) => {
                    crate::log_warn!(
                        "transport",
                        "node group {} lost its connection: {e}; redialing",
                        opts.group
                    );
                    uplink.lock().take();
                    break; // back to the dial loop
                }
            };
            match msg {
                WireMsg::Load {
                    model,
                    worker,
                    shard,
                } => {
                    let flat = worker as usize;
                    if flat < offset || flat >= offset + n1 {
                        crate::log_warn!(
                            "transport",
                            "Load for worker {flat} outside group {} \
                             (offset {offset}, n1 {n1}); dropped",
                            opts.group
                        );
                        continue;
                    }
                    let ws = match WorkerShard::new(&shard) {
                        Ok(ws) => ws,
                        Err(e) => {
                            crate::log_warn!(
                                "transport",
                                "bad shard for worker {flat}: {e}; dropped"
                            );
                            continue;
                        }
                    };
                    if let Some(link) = group_links.get(flat - offset) {
                        let _ = link.read().send(WorkerCmd::Load {
                            model: ModelId(model),
                            shard: Box::new(ws),
                        });
                    }
                }
                WireMsg::Job {
                    id,
                    model,
                    out_rows,
                    x,
                } => {
                    let _ = sub_tx.send(SubmasterMsg::Job(JobBroadcast {
                        id: JobId(id),
                        model: ModelId(model),
                        out_rows: usize::try_from(out_rows).unwrap_or(usize::MAX),
                        x: Arc::new(x),
                    }));
                }
                WireMsg::Finish { id } => {
                    let _ = sub_tx.send(SubmasterMsg::Finish(JobId(id)));
                }
                WireMsg::Shutdown => {
                    let _ = sub_tx.send(SubmasterMsg::Shutdown);
                    uplink.lock().take();
                    return Ok(());
                }
                other => {
                    crate::log_debug!(
                        "transport",
                        "unexpected downstream kind {}; ignored",
                        other.kind()
                    );
                }
            }
        }
    }
}

/// One dial window: connect + handshake with deterministic backoff
/// until `Welcome`, a fatal `Reject`, or the window closes.
fn dial(opts: &NodeOptions, clock: &WallClock, backoff: &mut Backoff) -> Result<Stream> {
    let deadline = clock.now_ms().saturating_add(opts.max_dial_ms);
    loop {
        match try_handshake(opts) {
            Ok(HandshakeOutcome::Admitted(stream)) => return Ok(stream),
            Ok(HandshakeOutcome::FatalReject(reason)) => {
                return Err(Error::Coordinator(format!(
                    "hub rejected node group {}: {reason}",
                    opts.group
                )));
            }
            Ok(HandshakeOutcome::Retry(why)) => {
                crate::log_debug!(
                    "transport",
                    "node group {} dial retry: {why}",
                    opts.group
                );
            }
            Err(e) => {
                crate::log_debug!(
                    "transport",
                    "node group {} dial failed: {e}",
                    opts.group
                );
            }
        }
        if clock.now_ms() >= deadline {
            return Err(Error::Coordinator(format!(
                "node group {} could not reach {} within {} ms",
                opts.group, opts.addr, opts.max_dial_ms
            )));
        }
        thread::sleep(Duration::from_millis(backoff.next_delay_ms()));
    }
}

enum HandshakeOutcome {
    Admitted(Stream),
    Retry(String),
    FatalReject(String),
}

/// One connect + Hello/Welcome exchange.
fn try_handshake(opts: &NodeOptions) -> std::io::Result<HandshakeOutcome> {
    let mut stream = Stream::connect(&opts.addr)?;
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    stream.write_all(
        &WireMsg::Hello {
            protocol: wire::VERSION,
            group: opts.group as u32,
            cluster_id: opts.config.seed,
        }
        .encode(),
    )?;
    let (reply, _) = match WireMsg::read_from(&mut stream) {
        Ok(v) => v,
        Err(e) => {
            return Ok(HandshakeOutcome::Retry(format!("handshake read: {e}")));
        }
    };
    match reply {
        WireMsg::Welcome => {
            stream.set_read_timeout(None)?;
            Ok(HandshakeOutcome::Admitted(stream))
        }
        WireMsg::Reject { reason, retryable } => {
            if retryable {
                Ok(HandshakeOutcome::Retry(reason))
            } else {
                Ok(HandshakeOutcome::FatalReject(reason))
            }
        }
        other => Ok(HandshakeOutcome::Retry(format!(
            "expected Welcome/Reject, got kind {}",
            other.kind()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(group: usize, addr: TransportAddr, max_dial_ms: u64) -> NodeOptions {
        NodeOptions {
            config: ClusterConfig::demo(2, 2, 2, 2),
            group,
            addr,
            max_dial_ms,
            dial_backoff_ms: 5,
            dial_backoff_max_ms: 20,
        }
    }

    #[test]
    fn out_of_range_group_is_rejected_before_dialing() {
        let addr = TransportAddr::Uds("/nonexistent/never-dialed.sock".into());
        let err = run_node(opts(99, addr, 10)).unwrap_err();
        assert!(matches!(err, Error::InvalidParams(_)), "got {err:?}");
    }

    #[test]
    fn unreachable_hub_exhausts_the_dial_window() {
        let addr = TransportAddr::Uds(std::env::temp_dir().join(format!(
            "hiercode-node-nohub-{}.sock",
            std::process::id()
        )));
        let err = run_node(opts(0, addr, 50)).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "got {err:?}");
    }
}
