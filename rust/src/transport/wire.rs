//! The hand-rolled wire format: versioned, length-prefixed, checksummed
//! binary frames carrying the coordinator protocol across a socket.
//!
//! # Frame layout
//!
//! ```text
//! offset  size  field
//!      0     4  magic      "hcw1" (little-endian u32)
//!      4     2  version    protocol version (little-endian u16)
//!      6     1  kind       message discriminant (see [`WireMsg`])
//!      7     1  reserved   must be zero
//!      8     4  len        payload length in bytes (little-endian u32)
//!     12     4  crc        CRC-32 (IEEE) of the payload
//!     16   len  payload    kind-specific fields
//! ```
//!
//! All integers are little-endian fixed-width; floats travel as their
//! IEEE-754 bit patterns (`f64::to_bits`), so a decoded matrix is
//! **bit-identical** to the encoded one — the loopback bit-identity
//! guarantee starts here. Strings are a `u32` length plus UTF-8 bytes.
//! Matrices are `rows: u64`, `cols: u64`, then `rows·cols` f64 bit
//! patterns in row-major order.
//!
//! Every malformed input surfaces a typed [`WireError`] — truncation,
//! bad magic, version skew, checksum mismatch, oversize, garbage — and
//! never a panic: the decode path is in the `no_panic` lint scope, and
//! the property tests below drive random corruption through it.

use crate::linalg::Matrix;
use crate::Error;

/// Frame magic: `"hcw1"` as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"hcw1");
/// Current protocol version. Bumped on any frame- or payload-layout
/// change; the handshake rejects mismatched peers explicitly.
pub const VERSION: u16 = 1;
/// Frame header size in bytes.
pub const HEADER_LEN: usize = 16;
/// Maximum accepted payload (64 MiB): a length field beyond this is a
/// corrupt or hostile frame, not a real message.
pub const MAX_PAYLOAD: usize = 64 << 20;

/// Sentinel for "no worker" in [`WireMsg::Heartbeat`] (the submaster's
/// own beacon).
pub const NO_WORKER: u32 = u32::MAX;

/// CRC-32 (IEEE) of `data` — the shared implementation in
/// [`crate::util::manifest`], re-exported so wire-format callers keep
/// their original path.
pub use crate::util::manifest::crc32;

/// Typed decode failure. Every variant is a distinct, observable way a
/// frame can be wrong — the rejection tests exercise each one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than the header or the declared payload length.
    Truncated,
    /// The first four bytes are not the frame magic.
    BadMagic,
    /// The peer speaks a different protocol version.
    BadVersion {
        /// Version in the received frame.
        got: u16,
        /// Version this build speaks.
        want: u16,
    },
    /// Unknown message discriminant.
    BadKind(u8),
    /// Payload checksum mismatch (bit rot or truncated write).
    BadChecksum,
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversize(usize),
    /// Structurally invalid payload (bad UTF-8, impossible matrix
    /// dimensions, trailing bytes, nonzero reserved byte).
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated => write!(f, "truncated frame"),
            Self::BadMagic => write!(f, "bad frame magic"),
            Self::BadVersion { got, want } => {
                write!(f, "protocol version {got} (this build speaks {want})")
            }
            Self::BadKind(k) => write!(f, "unknown message kind {k}"),
            Self::BadChecksum => write!(f, "payload checksum mismatch"),
            Self::Oversize(n) => {
                write!(f, "payload length {n} exceeds the {MAX_PAYLOAD}-byte cap")
            }
            Self::Malformed(why) => write!(f, "malformed payload: {why}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for Error {
    fn from(e: WireError) -> Self {
        Error::Coordinator(format!("wire protocol: {e}"))
    }
}

/// A frame-read failure on a blocking stream: either the transport
/// itself failed (EOF, reset, timeout — the connection is gone) or the
/// peer sent a protocol violation (the connection is garbage).
#[derive(Debug)]
pub enum FrameError {
    /// Transport-level failure.
    Io(std::io::Error),
    /// Protocol-level failure.
    Wire(WireError),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "transport: {e}"),
            Self::Wire(e) => write!(f, "{e}"),
        }
    }
}

/// Everything that crosses a master ↔ node link, one frame per message.
///
/// This mirrors `coordinator::messages::*` minus the fields that must
/// not cross a process boundary: `PartialResult::finished_at` is an
/// `Instant` (meaningless in another process) and is re-stamped at
/// receipt. Identifier newtypes travel as their raw integers.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// Node → master bootstrap: who am I, what do I speak, which
    /// cluster do I think I'm joining (`cluster_id` is the config
    /// seed — a cheap guard against cross-wiring two clusters).
    Hello {
        /// Protocol version the node speaks.
        protocol: u16,
        /// Group index the node serves.
        group: u32,
        /// Cluster identity (the config seed).
        cluster_id: u64,
    },
    /// Master → node: handshake accepted, Loads follow.
    Welcome,
    /// Master → node: handshake refused. `retryable` distinguishes a
    /// transient refusal (severed link mid-heal, duplicate in
    /// teardown) from a fatal one (wrong cluster, bad group).
    Reject {
        /// Human-readable refusal reason.
        reason: String,
        /// Whether the node should back off and re-dial.
        retryable: bool,
    },
    /// Master → node: install one worker's coded shard of a model
    /// (`worker` is the flat cluster-wide index).
    Load {
        /// Model being installed.
        model: u32,
        /// Flat worker index owning this shard.
        worker: u32,
        /// The shard (already f32-narrowed by the master, so a node-
        /// side re-narrow is the identity — bit-identical products).
        shard: Matrix,
    },
    /// Master → node: a batched job broadcast.
    Job {
        /// Job id.
        id: u64,
        /// Target model.
        model: u32,
        /// Output rows `m` (sizes the decode sessions).
        out_rows: u64,
        /// The batched request matrix, `d × b`.
        x: Matrix,
    },
    /// Master → node: stop feeding this job.
    Finish {
        /// Job id.
        id: u64,
    },
    /// Master → node: drain and exit.
    Shutdown,
    /// Node → master: one partial result for the master's decode
    /// session (the submaster's decoded group product or a relayed
    /// worker product).
    Partial {
        /// Job id.
        id: u64,
        /// Shard index in the master session's index space.
        shard: u64,
        /// Whether this is a group-decoded result (vs a relayed raw
        /// worker product). Carried explicitly: a trivial systematic
        /// decode can cost 0 flops, so the hub cannot infer it.
        decoded: bool,
        /// Flops the submaster spent decoding (0 for relays).
        decode_flops: u64,
        /// The partial product.
        data: Matrix,
    },
    /// Node → master: a liveness beacon ([`NO_WORKER`] = the
    /// submaster's own).
    Heartbeat {
        /// Reporting group.
        group: u32,
        /// In-group worker index, or [`NO_WORKER`].
        worker: u32,
    },
}

impl WireMsg {
    /// The frame discriminant.
    pub fn kind(&self) -> u8 {
        match self {
            Self::Hello { .. } => 0,
            Self::Welcome => 1,
            Self::Reject { .. } => 2,
            Self::Load { .. } => 3,
            Self::Job { .. } => 4,
            Self::Finish { .. } => 5,
            Self::Shutdown => 6,
            Self::Partial { .. } => 7,
            Self::Heartbeat { .. } => 8,
        }
    }

    /// Encode into a complete frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Self::Hello {
                protocol,
                group,
                cluster_id,
            } => {
                put_u16(&mut p, *protocol);
                put_u32(&mut p, *group);
                put_u64(&mut p, *cluster_id);
            }
            Self::Welcome | Self::Shutdown => {}
            Self::Reject { reason, retryable } => {
                put_str(&mut p, reason);
                p.push(u8::from(*retryable));
            }
            Self::Load {
                model,
                worker,
                shard,
            } => {
                put_u32(&mut p, *model);
                put_u32(&mut p, *worker);
                put_matrix(&mut p, shard);
            }
            Self::Job {
                id,
                model,
                out_rows,
                x,
            } => {
                put_u64(&mut p, *id);
                put_u32(&mut p, *model);
                put_u64(&mut p, *out_rows);
                put_matrix(&mut p, x);
            }
            Self::Finish { id } => put_u64(&mut p, *id),
            Self::Partial {
                id,
                shard,
                decoded,
                decode_flops,
                data,
            } => {
                put_u64(&mut p, *id);
                put_u64(&mut p, *shard);
                p.push(u8::from(*decoded));
                put_u64(&mut p, *decode_flops);
                put_matrix(&mut p, data);
            }
            Self::Heartbeat { group, worker } => {
                put_u32(&mut p, *group);
                put_u32(&mut p, *worker);
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + p.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind());
        out.push(0);
        out.extend_from_slice(&(p.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&p).to_le_bytes());
        out.extend_from_slice(&p);
        out
    }

    /// Decode one frame from the front of `buf`. Returns the message
    /// and the number of bytes consumed.
    pub fn decode(buf: &[u8]) -> Result<(Self, usize), WireError> {
        let header: &[u8; HEADER_LEN] = buf
            .get(..HEADER_LEN)
            .and_then(|h| h.try_into().ok())
            .ok_or(WireError::Truncated)?;
        let (kind, len) = parse_header(header)?;
        let payload = buf
            .get(HEADER_LEN..HEADER_LEN + len)
            .ok_or(WireError::Truncated)?;
        let crc = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
        if crc32(payload) != crc {
            return Err(WireError::BadChecksum);
        }
        Ok((decode_payload(kind, payload)?, HEADER_LEN + len))
    }

    /// Read one frame from a blocking stream. Returns the message and
    /// its total frame size (header + payload bytes read).
    pub fn read_from<R: std::io::Read>(r: &mut R) -> Result<(Self, usize), FrameError> {
        let mut header = [0u8; HEADER_LEN];
        r.read_exact(&mut header).map_err(FrameError::Io)?;
        let (kind, len) = parse_header(&header).map_err(FrameError::Wire)?;
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).map_err(FrameError::Io)?;
        let crc = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
        if crc32(&payload) != crc {
            return Err(FrameError::Wire(WireError::BadChecksum));
        }
        let msg = decode_payload(kind, &payload).map_err(FrameError::Wire)?;
        Ok((msg, HEADER_LEN + len))
    }
}

/// Validate a header; returns `(kind, payload_len)`.
fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(u8, usize), WireError> {
    let magic = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
    if magic != MAGIC {
        return Err(WireError::BadMagic);
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != VERSION {
        return Err(WireError::BadVersion {
            got: version,
            want: VERSION,
        });
    }
    let kind = h[6];
    if kind > 8 {
        return Err(WireError::BadKind(kind));
    }
    if h[7] != 0 {
        return Err(WireError::Malformed("nonzero reserved byte"));
    }
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversize(len));
    }
    Ok((kind, len))
}

/// Decode a validated (magic/version/checksum-checked) payload.
fn decode_payload(kind: u8, payload: &[u8]) -> Result<WireMsg, WireError> {
    let mut r = Reader {
        buf: payload,
        pos: 0,
    };
    let msg = match kind {
        0 => WireMsg::Hello {
            protocol: r.u16()?,
            group: r.u32()?,
            cluster_id: r.u64()?,
        },
        1 => WireMsg::Welcome,
        2 => WireMsg::Reject {
            reason: r.string()?,
            retryable: r.u8()? != 0,
        },
        3 => WireMsg::Load {
            model: r.u32()?,
            worker: r.u32()?,
            shard: r.matrix()?,
        },
        4 => WireMsg::Job {
            id: r.u64()?,
            model: r.u32()?,
            out_rows: r.u64()?,
            x: r.matrix()?,
        },
        5 => WireMsg::Finish { id: r.u64()? },
        6 => WireMsg::Shutdown,
        7 => WireMsg::Partial {
            id: r.u64()?,
            shard: r.u64()?,
            decoded: r.u8()? != 0,
            decode_flops: r.u64()?,
            data: r.matrix()?,
        },
        8 => WireMsg::Heartbeat {
            group: r.u32()?,
            worker: r.u32()?,
        },
        k => return Err(WireError::BadKind(k)),
    };
    if r.pos != payload.len() {
        return Err(WireError::Malformed("trailing bytes after payload"));
    }
    Ok(msg)
}

pub(crate) fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub(crate) fn put_matrix(out: &mut Vec<u8>, m: &Matrix) {
    put_u64(out, m.rows() as u64);
    put_u64(out, m.cols() as u64);
    for &v in m.data() {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Bounds-checked little-endian payload reader. Shared with the
/// control-plane artifact and admin codecs, which reuse the wire
/// conventions (little-endian fixed-width ints, length-prefixed
/// strings, `f64::to_bits` floats).
pub(crate) struct Reader<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) pos: usize,
}

impl Reader<'_> {
    pub(crate) fn take(&mut self, n: usize) -> Result<&[u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated)?;
        let s = self.buf.get(self.pos..end).ok_or(WireError::Truncated)?;
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub(crate) fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub(crate) fn string(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        std::str::from_utf8(bytes)
            .map(str::to_string)
            .map_err(|_| WireError::Malformed("string is not UTF-8"))
    }

    pub(crate) fn matrix(&mut self) -> Result<Matrix, WireError> {
        let rows = usize::try_from(self.u64()?)
            .map_err(|_| WireError::Malformed("matrix rows overflow"))?;
        let cols = usize::try_from(self.u64()?)
            .map_err(|_| WireError::Malformed("matrix cols overflow"))?;
        let n = rows
            .checked_mul(cols)
            .ok_or(WireError::Malformed("matrix size overflow"))?;
        // The element count must fit the remaining payload exactly-or-
        // less *before* allocating, so a corrupt dimension cannot ask
        // for gigabytes.
        if self.buf.len().saturating_sub(self.pos) < n.saturating_mul(8) {
            return Err(WireError::Truncated);
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(f64::from_bits(self.u64()?));
        }
        Matrix::from_vec(rows, cols, data)
            .map_err(|_| WireError::Malformed("inconsistent matrix dimensions"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn roundtrip(msg: &WireMsg) {
        let frame = msg.encode();
        let (back, used) = WireMsg::decode(&frame).expect("decode own encoding");
        assert_eq!(used, frame.len(), "whole frame consumed");
        assert_eq!(&back, msg);
        // The stream reader agrees with the buffer decoder.
        let mut cursor = frame.as_slice();
        let (streamed, n) = WireMsg::read_from(&mut cursor).expect("read_from");
        assert_eq!(n, frame.len());
        assert_eq!(&streamed, msg);
    }

    fn gen_matrix(g: &mut check::Gen) -> Matrix {
        let rows = g.usize_in(1..6);
        let cols = g.usize_in(1..6);
        let data = g.vec_f64(rows * cols, -1e6, 1e6);
        Matrix::from_vec(rows, cols, data).unwrap()
    }

    /// One random instance of every variant, driven by the shared
    /// seeded generator (`HIERCODE_CHECK_SEED` reproduces failures).
    fn gen_msg(g: &mut check::Gen, kind: u8) -> WireMsg {
        let mut r = |hi: u64| g.rng().next_u64() % hi;
        match kind {
            0 => WireMsg::Hello {
                protocol: r(u64::from(u16::MAX)) as u16,
                group: r(1 << 20) as u32,
                cluster_id: g.rng().next_u64(),
            },
            1 => WireMsg::Welcome,
            2 => WireMsg::Reject {
                reason: format!("refused-{}-π", r(1000)),
                retryable: g.bool_with(0.5),
            },
            3 => WireMsg::Load {
                model: r(1 << 16) as u32,
                worker: r(1 << 10) as u32,
                shard: gen_matrix(g),
            },
            4 => WireMsg::Job {
                id: g.rng().next_u64(),
                model: r(1 << 16) as u32,
                out_rows: r(1 << 30),
                x: gen_matrix(g),
            },
            5 => WireMsg::Finish {
                id: g.rng().next_u64(),
            },
            6 => WireMsg::Shutdown,
            7 => WireMsg::Partial {
                id: g.rng().next_u64(),
                shard: r(1 << 10),
                decoded: g.bool_with(0.5),
                decode_flops: g.rng().next_u64(),
                data: gen_matrix(g),
            },
            8 => WireMsg::Heartbeat {
                group: r(1 << 10) as u32,
                worker: if g.bool_with(0.3) {
                    NO_WORKER
                } else {
                    r(1 << 10) as u32
                },
            },
            _ => unreachable!("kinds are 0..=8"),
        }
    }

    #[test]
    fn every_variant_roundtrips() {
        check::check("wire_roundtrip_all_variants", 96, |g| {
            for kind in 0..=8u8 {
                roundtrip(&gen_msg(g, kind));
            }
        });
    }

    #[test]
    fn floats_roundtrip_bit_exactly_including_specials() {
        for v in [0.0, -0.0, f64::NAN, f64::INFINITY, f64::MIN_POSITIVE, 1e-308] {
            let m = Matrix::from_vec(1, 1, vec![v]).unwrap();
            let msg = WireMsg::Partial {
                id: 1,
                shard: 0,
                decoded: true,
                decode_flops: 0,
                data: m,
            };
            let (back, _) = WireMsg::decode(&msg.encode()).unwrap();
            let WireMsg::Partial { data, .. } = back else {
                panic!("kind changed in flight");
            };
            assert_eq!(data.data()[0].to_bits(), v.to_bits(), "bits of {v}");
        }
    }

    #[test]
    fn truncated_frames_reject_at_every_length() {
        check::check("wire_truncation_rejects", 48, |g| {
            let msg = gen_msg(g, g.usize_in(0..9) as u8);
            let frame = msg.encode();
            let cut = g.usize_in(0..frame.len());
            let err = WireMsg::decode(&frame[..cut]).unwrap_err();
            assert_eq!(err, WireError::Truncated, "prefix of {cut} bytes");
        });
    }

    #[test]
    fn corrupted_byte_rejects_never_panics() {
        check::check("wire_corruption_rejects", 96, |g| {
            let msg = gen_msg(g, g.usize_in(0..9) as u8);
            let mut frame = msg.encode();
            let at = g.usize_in(0..frame.len());
            let delta = 1 + (g.rng().next_u64() % 255) as u8;
            frame[at] = frame[at].wrapping_add(delta);
            match WireMsg::decode(&frame) {
                // A corrupt length field can make the buffer "too
                // short" or the payload mis-sized; everything else is
                // caught by an explicit field check or the checksum.
                Err(_) => {}
                Ok((back, _)) => panic!(
                    "byte {at} += {delta} went undetected (decoded {back:?})"
                ),
            }
        });
    }

    #[test]
    fn wrong_version_rejects_with_both_versions() {
        let mut frame = WireMsg::Welcome.encode();
        frame[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
        assert_eq!(
            WireMsg::decode(&frame).unwrap_err(),
            WireError::BadVersion {
                got: VERSION + 1,
                want: VERSION,
            }
        );
    }

    #[test]
    fn bad_magic_kind_checksum_and_reserved_reject() {
        let good = WireMsg::Finish { id: 7 }.encode();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert_eq!(WireMsg::decode(&bad).unwrap_err(), WireError::BadMagic);
        let mut bad = good.clone();
        bad[6] = 9;
        assert_eq!(WireMsg::decode(&bad).unwrap_err(), WireError::BadKind(9));
        let mut bad = good.clone();
        bad[7] = 1;
        assert!(matches!(
            WireMsg::decode(&bad).unwrap_err(),
            WireError::Malformed(_)
        ));
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert_eq!(WireMsg::decode(&bad).unwrap_err(), WireError::BadChecksum);
        // Oversize length field.
        let mut bad = good;
        bad[8..12].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert_eq!(
            WireMsg::decode(&bad).unwrap_err(),
            WireError::Oversize(MAX_PAYLOAD + 1)
        );
    }

    #[test]
    fn corrupt_matrix_dims_cannot_allocate_giant_buffers() {
        // A Load frame whose matrix claims 2^40 rows: the decoder must
        // reject on the *declared payload size* before allocating.
        let mut p = Vec::new();
        put_u32(&mut p, 1);
        put_u32(&mut p, 2);
        put_u64(&mut p, 1 << 40);
        put_u64(&mut p, 1 << 40);
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&VERSION.to_le_bytes());
        frame.push(3);
        frame.push(0);
        frame.extend_from_slice(&(p.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&p).to_le_bytes());
        frame.extend_from_slice(&p);
        assert_eq!(WireMsg::decode(&frame).unwrap_err(), WireError::Truncated);
    }

    #[test]
    fn trailing_payload_bytes_reject() {
        let mut p = Vec::new();
        put_u64(&mut p, 3);
        p.push(0xAB); // one byte too many for a Finish payload
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&VERSION.to_le_bytes());
        frame.push(5);
        frame.push(0);
        frame.extend_from_slice(&(p.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&p).to_le_bytes());
        frame.extend_from_slice(&p);
        assert!(matches!(
            WireMsg::decode(&frame).unwrap_err(),
            WireError::Malformed(_)
        ));
    }

    #[test]
    fn wire_error_maps_to_typed_crate_error() {
        let e: Error = WireError::BadChecksum.into();
        assert!(matches!(e, Error::Coordinator(_)));
        assert!(format!("{e}").contains("checksum"));
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
