//! Matrix/vector products: packed-microkernel GEMM, unrolled GEMV, and
//! the naive reference kernels they are validated against.
//!
//! The decode hot path multiplies an inverted `k×k` generator submatrix
//! by the stacked worker results (a `k × (m/k · b)` matrix for batched
//! requests), so GEMM throughput directly bounds decoding throughput —
//! exactly the cost the paper's §IV weighs against computing time.
//!
//! §Perf: the production [`matmul`] is a packed kernel around a 4×4
//! accumulator microtile, with the microtile core and the 4-row GEMV
//! routed through the runtime-dispatched SIMD tables of
//! [`crate::linalg::dispatch`] (AVX2/NEON when the host has them,
//! bit-identical to the scalar fallback by construction).
//! MR = NR = 4 keeps the 16 accumulators plus
//! one A broadcast and one B vector inside the 16 ymm registers of
//! baseline x86-64 (and comfortably inside aarch64's 32 v-registers);
//! the `B` panel is repacked into NR-wide strips so the inner loop
//! reads both operands unit-stride. Measured by `hiercode bench`
//! (BENCH_decode.json, `gemm_decode`): ≥ 2× the pre-PR i-k-j kernel
//! ([`matmul_ikj`]) at the k=64, n=4096 decode shape, because each A
//! and B element loaded from cache is now reused 4× from registers
//! instead of once. The previous `PANEL_THRESHOLD` heuristic (switch
//! to k-panelling only above 1 Mi elements) is gone: packing makes the
//! kernel cache-oblivious enough that one code path wins at every
//! bench size.

use crate::linalg::dispatch::{self, Kernels};
use crate::linalg::Matrix;
use crate::parallel::DecodePool;

/// Microtile rows (A-side register blocking).
pub const MR: usize = 4;
/// Microtile columns (B-side register blocking).
pub const NR: usize = 4;
/// K-panel depth: one packed `A` microtile panel is `MR·KC` f64
/// (8 KiB — L1-resident) and accumulation runs `KC` deep per microtile.
const KC: usize = 256;
/// Column-panel width: one packed `B` panel is at most `KC·NC` f64
/// (2 MiB worst case, typically far less at decode shapes).
const NC: usize = 1024;
/// Rows per parallel task when a [`DecodePool`] is attached: wide
/// enough that a task amortizes its share of the scoped-spawn cost,
/// narrow enough that `m = 64`-row decodes still split 4 ways.
const MC: usize = 16;

/// `y = A x` — dense GEMV, 4 rows per pass so the `x` stream is reused
/// from registers (the row-major layout makes per-row dot products the
/// natural unit; the 4-row core runs the dispatched
/// [`dispatch::Kernels::matvec4`] kernel, whose per-row accumulation
/// order matches [`matvec_naive`], so scalar, SIMD and naive all agree
/// bit-for-bit).
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "matvec shape mismatch");
    let kern = dispatch::active();
    let (m, k) = (a.rows(), a.cols());
    let mut y = vec![0.0; m];
    let data = a.data();
    let mut i = 0;
    while i + 4 <= m {
        let r0 = &data[i * k..(i + 1) * k];
        let r1 = &data[(i + 1) * k..(i + 2) * k];
        let r2 = &data[(i + 2) * k..(i + 3) * k];
        let r3 = &data[(i + 3) * k..(i + 4) * k];
        let [s0, s1, s2, s3] = (kern.matvec4)(r0, r1, r2, r3, x);
        y[i] = s0;
        y[i + 1] = s1;
        y[i + 2] = s2;
        y[i + 3] = s3;
        i += 4;
    }
    while i < m {
        let row = &data[i * k..(i + 1) * k];
        let mut acc = 0.0;
        for (aij, xj) in row.iter().zip(x.iter()) {
            acc += aij * xj;
        }
        y[i] = acc;
        i += 1;
    }
    y
}

/// Single-row reference GEMV — the oracle [`matvec`] is tested against.
pub fn matvec_naive(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "matvec shape mismatch");
    let mut y = vec![0.0; a.rows()];
    for i in 0..a.rows() {
        let mut acc = 0.0;
        for (aij, xj) in a.row(i).iter().zip(x.iter()) {
            acc += aij * xj;
        }
        y[i] = acc;
    }
    y
}

/// Naive triple-loop GEMM — the correctness oracle the packed kernel's
/// property tests compare against.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for l in 0..k {
            let ail = a[(i, l)];
            if ail == 0.0 {
                continue;
            }
            let brow = b.row(l);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += ail * brow[j];
            }
        }
    }
    c
}

/// The pre-packing i-k-j kernel — kept verbatim as the measured
/// baseline `hiercode bench` reports speedups against (and as a second
/// oracle in the property tests). Not used on any production path.
pub fn matmul_ikj(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, k, _n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, b.cols());
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for l in 0..k {
            let ail = arow[l];
            if ail == 0.0 {
                continue;
            }
            let brow = b.row(l);
            for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += ail * bj;
            }
        }
    }
    c
}

/// GEMM `C = A·B` with the packed 4×4 microkernel, serial.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_with(a, b, &DecodePool::serial())
}

/// GEMM `C = A·B`, row-parallel across `pool`.
///
/// The loop nest is jc → pc → (parallel) row chunks → MR×NR microtiles:
/// the packed `B` panel is built once per (jc, pc) tile and shared
/// read-only by every row task, each task owns a disjoint row range of
/// `C`, and each microtile accumulates in registers over the full
/// k-panel before touching `C`. Per-element accumulation order depends
/// only on the fixed panel sizes — never on the thread count (and the
/// dispatched SIMD microkernel preserves it lane-for-lane) — so the
/// result is bit-identical at any pool width and on any kernel table.
pub fn matmul_with(a: &Matrix, b: &Matrix, pool: &DecodePool) -> Matrix {
    matmul_with_kernels(a, b, pool, dispatch::active())
}

/// [`matmul_with`] on an explicit kernel table — how `hiercode bench`
/// times the SIMD path against the forced-scalar baseline, and how the
/// oracle tests prove `simd == scalar` bit-for-bit.
pub fn matmul_with_kernels(a: &Matrix, b: &Matrix, pool: &DecodePool, kern: &Kernels) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let mut bpack = vec![0.0f64; KC.min(k) * NC.min(n.next_multiple_of(NR))];
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        let strips = nc.div_ceil(NR);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(b, pc, kc, jc, nc, &mut bpack);
            let bpack = &bpack[..strips * kc * NR];
            if pool.size() > 1 && m > MC {
                let tasks: Vec<(usize, &mut [f64])> = c
                    .data_mut()
                    .chunks_mut(MC * n)
                    .enumerate()
                    .map(|(t, chunk)| (t * MC, chunk))
                    .collect();
                pool.map(tasks, |(i0, chunk)| {
                    gemm_rows(a, i0, chunk, n, jc, nc, pc, kc, bpack, strips, kern);
                });
            } else {
                gemm_rows(a, 0, c.data_mut(), n, jc, nc, pc, kc, bpack, strips, kern);
            }
        }
    }
    c
}

/// Multiply the row range `[i0, i0 + chunk.len()/n)` of `A` against the
/// packed `B` panel, accumulating into `chunk` (those rows of `C`).
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a: &Matrix,
    i0: usize,
    chunk: &mut [f64],
    n: usize,
    jc: usize,
    nc: usize,
    pc: usize,
    kc: usize,
    bpack: &[f64],
    strips: usize,
    kern: &Kernels,
) {
    let rows = chunk.len() / n;
    let mut apack = [0.0f64; MR * KC];
    for ir in (0..rows).step_by(MR) {
        let mr = MR.min(rows - ir);
        pack_a(a, i0 + ir, mr, pc, kc, &mut apack);
        for s in 0..strips {
            let j0 = s * NR;
            let nr = NR.min(nc - j0);
            let bstrip = &bpack[s * kc * NR..(s + 1) * kc * NR];
            let mut acc = [0.0f64; MR * NR];
            (kern.microkernel)(kc, &apack, bstrip, &mut acc);
            for r in 0..mr {
                let crow = &mut chunk[(ir + r) * n + jc + j0..][..nr];
                for (cj, &av) in crow.iter_mut().zip(&acc[r * NR..r * NR + nr]) {
                    *cj += av;
                }
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` into NR-wide strips, each strip laid
/// out p-major (`strip[p·NR + c]`), zero-padded to NR so the
/// microkernel never branches on width. Padding lanes are discarded at
/// the `C` writeback, so they cannot perturb real results.
fn pack_b(b: &Matrix, pc: usize, kc: usize, jc: usize, nc: usize, out: &mut [f64]) {
    let strips = nc.div_ceil(NR);
    for p in 0..kc {
        let brow = &b.row(pc + p)[jc..jc + nc];
        for s in 0..strips {
            let j0 = s * NR;
            let w = NR.min(nc - j0);
            let dst = &mut out[s * kc * NR + p * NR..][..NR];
            for (cidx, d) in dst.iter_mut().enumerate() {
                *d = if cidx < w { brow[j0 + cidx] } else { 0.0 };
            }
        }
    }
}

/// Pack `mr` rows of `A[i0.., pc..pc+kc]` p-major (`apack[p·MR + r]`),
/// zero-padding the `MR − mr` tail rows.
fn pack_a(a: &Matrix, i0: usize, mr: usize, pc: usize, kc: usize, out: &mut [f64; MR * KC]) {
    for r in 0..MR {
        if r < mr {
            let arow = &a.row(i0 + r)[pc..pc + kc];
            for (p, &v) in arow.iter().enumerate() {
                out[p * MR + r] = v;
            }
        } else {
            for p in 0..kc {
                out[p * MR + r] = 0.0;
            }
        }
    }
}

/// `y += alpha * x` over slices.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Dot product.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

/// Linear combination of equal-shaped matrices:
/// `sum_i coeffs[i] * mats[i]` — MDS encoding of a row of the generator.
pub fn lincomb(coeffs: &[f64], mats: &[&Matrix]) -> Matrix {
    assert_eq!(coeffs.len(), mats.len(), "lincomb length mismatch");
    assert!(!mats.is_empty(), "lincomb of nothing");
    let shape = mats[0].shape();
    let mut out = Matrix::zeros(shape.0, shape.1);
    for (&c, m) in coeffs.iter().zip(mats.iter()) {
        assert_eq!(m.shape(), shape, "lincomb shape mismatch");
        if c == 0.0 {
            continue;
        }
        axpy(c, m.data(), out.data_mut());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn matvec_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let y = matvec(&a, &[1.0, 1.0]);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn matvec_matches_naive_all_remainders() {
        // Exercise every i % 4 tail length.
        let mut r = Rng::new(9);
        for m in [1usize, 2, 3, 4, 5, 7, 8, 13] {
            let a = random_matrix(&mut r, m, 11);
            let x: Vec<f64> = (0..11).map(|_| r.uniform(-1.0, 1.0)).collect();
            assert_eq!(matvec(&a, &x), matvec_naive(&a, &x), "m={m}");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(1);
        let a = random_matrix(&mut r, 7, 7);
        let c = matmul(&a, &Matrix::identity(7));
        assert!(a.max_abs_diff(&c) < 1e-14);
    }

    #[test]
    fn packed_matches_naive_and_ikj() {
        let mut r = Rng::new(2);
        for (m, k, n) in [
            (1, 1, 1),
            (5, 7, 3),
            (64, 64, 64),
            (65, 130, 67),
            (200, 33, 90),
            // Awkward shapes: degenerate dims and non-multiples of
            // MR/NR/KC around the panel boundaries.
            (1, 300, 5),
            (5, 300, 1),
            (3, 257, 1030),
        ] {
            let a = random_matrix(&mut r, m, k);
            let b = random_matrix(&mut r, k, n);
            let c1 = matmul_naive(&a, &b);
            let c2 = matmul(&a, &b);
            let c3 = matmul_ikj(&a, &b);
            assert!(
                c1.max_abs_diff(&c2) < 1e-10,
                "packed mismatch at {m}x{k}x{n}: {}",
                c1.max_abs_diff(&c2)
            );
            assert!(c1.max_abs_diff(&c3) < 1e-10, "ikj mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn dispatched_matmul_is_bit_identical_to_forced_scalar() {
        // The simd == scalar oracle at the GEMM level: on SIMD hosts
        // this exercises the AVX2/NEON microkernel against the scalar
        // table; on scalar-only hosts both sides are the same kernel.
        let mut r = Rng::new(21);
        for (m, k, n) in [(1, 1, 1), (5, 7, 3), (64, 64, 64), (65, 130, 67), (3, 257, 41)] {
            let a = random_matrix(&mut r, m, k);
            let b = random_matrix(&mut r, k, n);
            let pool = DecodePool::serial();
            let active = matmul_with_kernels(&a, &b, &pool, dispatch::active());
            let scalar = matmul_with_kernels(&a, &b, &pool, dispatch::scalar());
            assert_eq!(active.data(), scalar.data(), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_matmul_is_bit_identical_to_serial() {
        let mut r = Rng::new(8);
        let a = random_matrix(&mut r, 61, 37);
        let b = random_matrix(&mut r, 37, 113);
        let serial = matmul(&a, &b);
        for threads in [2, 3, 8] {
            let pool = DecodePool::new(threads).unwrap();
            let par = matmul_with(&a, &b, &pool);
            assert_eq!(serial.data(), par.data(), "threads={threads}");
        }
    }

    #[test]
    fn matvec_matches_matmul_column() {
        let mut r = Rng::new(3);
        let a = random_matrix(&mut r, 20, 15);
        let x: Vec<f64> = (0..15).map(|_| r.uniform(-1.0, 1.0)).collect();
        let xm = Matrix::from_vec(15, 1, x.clone()).unwrap();
        let y1 = matvec(&a, &x);
        let y2 = matmul(&a, &xm);
        assert_allclose(&y1, y2.data(), 1e-12, 1e-12);
    }

    #[test]
    fn lincomb_is_linear() {
        let a = Matrix::from_rows(&[&[1.0, 0.0]]);
        let b = Matrix::from_rows(&[&[0.0, 1.0]]);
        let c = lincomb(&[2.0, -3.0], &[&a, &b]);
        assert_eq!(c.row(0), &[2.0, -3.0]);
    }

    #[test]
    fn axpy_and_dot() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &[1.0, 1.0, 1.0]), 6.0);
    }

    #[test]
    fn matmul_associativity_property() {
        check("matmul associativity", 20, |g| {
            let m = g.usize_in(1..12);
            let k = g.usize_in(1..12);
            let n = g.usize_in(1..12);
            let p = g.usize_in(1..12);
            let mut r = Rng::new(g.usize_in(0..1_000_000) as u64);
            let a = random_matrix(&mut r, m, k);
            let b = random_matrix(&mut r, k, n);
            let c = random_matrix(&mut r, n, p);
            let left = matmul(&matmul(&a, &b), &c);
            let right = matmul(&a, &matmul(&b, &c));
            assert!(left.max_abs_diff(&right) < 1e-9);
        });
    }

    #[test]
    fn packed_vs_naive_property_random_shapes() {
        check("packed GEMM == naive GEMM", 25, |g| {
            let m = g.usize_in(1..40);
            let k = g.usize_in(1..300);
            let n = g.usize_in(1..40);
            let mut r = Rng::new(g.usize_in(0..1 << 30) as u64);
            let a = random_matrix(&mut r, m, k);
            let b = random_matrix(&mut r, k, n);
            let diff = matmul_naive(&a, &b).max_abs_diff(&matmul(&a, &b));
            assert!(diff < 1e-10, "{m}x{k}x{n}: {diff}");
        });
    }
}
