//! Matrix/vector products: naive and cache-blocked GEMM, GEMV.
//!
//! The decode hot path multiplies an inverted `k×k` generator submatrix
//! by the stacked worker results (a `k × (m/k · b)` matrix for batched
//! requests), so GEMM throughput directly bounds decoding throughput —
//! exactly the cost the paper's §IV weighs against computing time.

use crate::linalg::Matrix;

/// `y = A x` — dense GEMV with row-major accumulation.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "matvec shape mismatch");
    let mut y = vec![0.0; a.rows()];
    for i in 0..a.rows() {
        let row = a.row(i);
        let mut acc = 0.0;
        for (aij, xj) in row.iter().zip(x.iter()) {
            acc += aij * xj;
        }
        y[i] = acc;
    }
    y
}

/// Naive triple-loop GEMM (reference implementation, used by tests to
/// validate the blocked kernel).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for l in 0..k {
            let ail = a[(i, l)];
            if ail == 0.0 {
                continue;
            }
            let brow = b.row(l);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += ail * brow[j];
            }
        }
    }
    c
}

/// Cache-block size for the tiled path of [`matmul`]: the `B` panel
/// (`BLOCK × n` f64) stays resident across one `A`-row sweep.
pub const BLOCK: usize = 64;

/// Threshold (elements of `B`) above which [`matmul`] switches to the
/// k-panelled path. §Perf: at bench sizes (≤ 256³) the straight i-k-j
/// loop beat the 3-D tiled kernel by 1.4× on this machine (row-stream
/// prefetch does the work; tiling only added loop overhead), so tiling
/// is reserved for operands that genuinely exceed cache.
pub const PANEL_THRESHOLD: usize = 1 << 20;

/// GEMM `C = A·B`. i-k-j loop order: the inner loop runs contiguously
/// over a `B` row and a `C` row (auto-vectorized); for large `B` the
/// k-dimension is panelled so each `B` panel is reused across all `A`
/// rows while cache-resident.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let k_step = if k * n > PANEL_THRESHOLD { BLOCK } else { k };
    for kk in (0..k).step_by(k_step.max(1)) {
        let k_end = (kk + k_step).min(k);
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for l in kk..k_end {
                let ail = arow[l];
                if ail == 0.0 {
                    continue;
                }
                let brow = b.row(l);
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += ail * bj;
                }
            }
        }
    }
    c
}

/// `y += alpha * x` over slices.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Dot product.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

/// Linear combination of equal-shaped matrices:
/// `sum_i coeffs[i] * mats[i]` — MDS encoding of a row of the generator.
pub fn lincomb(coeffs: &[f64], mats: &[&Matrix]) -> Matrix {
    assert_eq!(coeffs.len(), mats.len(), "lincomb length mismatch");
    assert!(!mats.is_empty(), "lincomb of nothing");
    let shape = mats[0].shape();
    let mut out = Matrix::zeros(shape.0, shape.1);
    for (&c, m) in coeffs.iter().zip(mats.iter()) {
        assert_eq!(m.shape(), shape, "lincomb shape mismatch");
        if c == 0.0 {
            continue;
        }
        axpy(c, m.data(), out.data_mut());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn matvec_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let y = matvec(&a, &[1.0, 1.0]);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(1);
        let a = random_matrix(&mut r, 7, 7);
        let c = matmul(&a, &Matrix::identity(7));
        assert!(a.max_abs_diff(&c) < 1e-14);
    }

    #[test]
    fn blocked_matches_naive() {
        let mut r = Rng::new(2);
        for (m, k, n) in [(1, 1, 1), (5, 7, 3), (64, 64, 64), (65, 130, 67), (200, 33, 90)] {
            let a = random_matrix(&mut r, m, k);
            let b = random_matrix(&mut r, k, n);
            let c1 = matmul_naive(&a, &b);
            let c2 = matmul(&a, &b);
            assert!(
                c1.max_abs_diff(&c2) < 1e-10,
                "mismatch at {m}x{k}x{n}: {}",
                c1.max_abs_diff(&c2)
            );
        }
    }

    #[test]
    fn matvec_matches_matmul_column() {
        let mut r = Rng::new(3);
        let a = random_matrix(&mut r, 20, 15);
        let x: Vec<f64> = (0..15).map(|_| r.uniform(-1.0, 1.0)).collect();
        let xm = Matrix::from_vec(15, 1, x.clone()).unwrap();
        let y1 = matvec(&a, &x);
        let y2 = matmul(&a, &xm);
        assert_allclose(&y1, y2.data(), 1e-12, 1e-12);
    }

    #[test]
    fn lincomb_is_linear() {
        let a = Matrix::from_rows(&[&[1.0, 0.0]]);
        let b = Matrix::from_rows(&[&[0.0, 1.0]]);
        let c = lincomb(&[2.0, -3.0], &[&a, &b]);
        assert_eq!(c.row(0), &[2.0, -3.0]);
    }

    #[test]
    fn axpy_and_dot() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &[1.0, 1.0, 1.0]), 6.0);
    }

    #[test]
    fn matmul_associativity_property() {
        check("matmul associativity", 20, |g| {
            let m = g.usize_in(1..12);
            let k = g.usize_in(1..12);
            let n = g.usize_in(1..12);
            let p = g.usize_in(1..12);
            let mut r = Rng::new(g.usize_in(0..1_000_000) as u64);
            let a = random_matrix(&mut r, m, k);
            let b = random_matrix(&mut r, k, n);
            let c = random_matrix(&mut r, n, p);
            let left = matmul(&matmul(&a, &b), &c);
            let right = matmul(&a, &matmul(&b, &c));
            assert!(left.max_abs_diff(&right) < 1e-9);
        });
    }
}
