//! Runtime-dispatched SIMD kernels for the decode hot path.
//!
//! The three register-resident inner loops everything else is built on
//! — the packed GEMM microkernel ([`Kernels::microkernel`]), the
//! 4-source panel update of the blocked triangular solve
//! ([`Kernels::update4`]) and the 4-row GEMV core
//! ([`Kernels::matvec4`]) — exist in one scalar and (per-arch) one SIMD
//! implementation, packaged as a [`Kernels`] table of function
//! pointers. [`active`] selects the table **once** per process: AVX2 on
//! x86-64 hosts that report it (AVX-512 hosts take the same AVX2 table
//! — the AVX-512 f64 intrinsics stabilized in Rust 1.89, above this
//! crate's 1.75 MSRV, so the wider path is detected but not yet
//! emitted), NEON on aarch64, and the scalar table everywhere else
//! (including under Miri, where feature detection reports nothing).
//!
//! # Bit-identity contract
//!
//! The SIMD kernels are drop-in replacements, not approximations: for
//! every input they produce **bit-for-bit** the scalar results, so the
//! crate-wide `parallel == serial` determinism suites extend to
//! `simd == scalar` with no tolerance. Two rules make that possible:
//!
//! * **No FMA contraction.** Every multiply-add is an explicit
//!   `mul` + `add` intrinsic pair, never a fused `fmadd` — a fused op
//!   rounds once where the scalar code rounds twice, which would change
//!   low bits.
//! * **Same per-accumulator order.** SIMD lanes map to *independent*
//!   scalar accumulators (the NR columns of a GEMM microtile, the
//!   panel columns of a solve sweep, the 4 rows of a GEMV block), and
//!   each lane receives its terms in exactly the scalar loop's order.
//!   Where the scalar code evaluates `l0*y0 + l1*y1 + l2*y2 + l3*y3`
//!   left-associatively, the vector code uses the same association.
//!
//! Feature checks happen **only** in [`select`]; the `unsafe`
//! target-feature functions are reachable solely through a table that
//! the selector refused to hand out unless the feature is present.
//! The scalar table stays compiled on every target as the fallback and
//! as the oracle the unit tests compare against.

use crate::linalg::ops::{MR, NR};
use std::sync::OnceLock;

/// The dispatchable kernel table. All three entries share the
/// bit-identity contract described in the module docs; `name` is
/// surfaced in benches and metrics so a run records which path it
/// measured.
#[derive(Clone, Copy, Debug)]
pub struct Kernels {
    /// Selected implementation: `"scalar"`, `"avx2"`,
    /// `"avx2 (avx512f host)"` or `"neon"`.
    pub name: &'static str,
    /// GEMM microtile core:
    /// `acc[r·NR + c] += Σ_p apack[p·MR + r] · bstrip[p·NR + c]`,
    /// accumulating over `p` in ascending order per accumulator.
    /// `kc` is clamped to the packed panels' lengths, so the call is
    /// total (no panic, no out-of-bounds) on any input.
    pub microkernel: fn(kc: usize, apack: &[f64], bstrip: &[f64], acc: &mut [f64; MR * NR]),
    /// Panel sweep core of the blocked triangular solve:
    /// `yi[c] -= l[0]·y0[c] + l[1]·y1[c] + l[2]·y2[c] + l[3]·y3[c]`
    /// (left-associative, matching the unrolled scalar sweep) for every
    /// column `c` up to the shortest slice.
    pub update4: fn(yi: &mut [f64], l: [f64; 4], y0: &[f64], y1: &[f64], y2: &[f64], y3: &[f64]),
    /// GEMV core: four row·x dot products, each accumulated in
    /// ascending-`j` order (one product added per step, matching the
    /// scalar 4-row loop), over the shortest of the five slices.
    pub matvec4: fn(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> [f64; 4],
}

/// The always-available scalar table — fallback and bit-identity
/// oracle.
pub const SCALAR: Kernels = Kernels {
    name: "scalar",
    microkernel: scalar::microkernel,
    update4: scalar::update4,
    matvec4: scalar::matvec4,
};

/// The table selected for this host, chosen once per process (see the
/// module docs for the selection order).
pub fn active() -> &'static Kernels {
    static ACTIVE: OnceLock<Kernels> = OnceLock::new();
    ACTIVE.get_or_init(select)
}

/// The scalar table, by reference — what benches and oracle tests force
/// to measure/verify the SIMD path against.
pub fn scalar() -> &'static Kernels {
    &SCALAR
}

/// Name of the active table (`"scalar"`, `"avx2"`, …).
pub fn active_name() -> &'static str {
    active().name
}

/// One-time selection. The only place feature detection runs: every
/// SIMD entry point below is reached exclusively through the table this
/// function returns, which is what makes their `unsafe` sound.
fn select() -> Kernels {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            if std::arch::is_x86_feature_detected!("avx512f") {
                // Detected but routed to AVX2: the AVX-512 f64
                // intrinsics need rustc ≥ 1.89 (MSRV here is 1.75).
                return Kernels {
                    name: "avx2 (avx512f host)",
                    ..avx2::KERNELS
                };
            }
            return avx2::KERNELS;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return neon::KERNELS;
        }
    }
    SCALAR
}

/// Scalar reference implementations — the exact loops the pre-dispatch
/// code ran, kept as total functions (they clamp to the shortest slice
/// instead of indexing past it).
mod scalar {
    use super::{MR, NR};

    pub(super) fn microkernel(
        kc: usize,
        apack: &[f64],
        bstrip: &[f64],
        acc: &mut [f64; MR * NR],
    ) {
        let kc = kc.min(apack.len() / MR).min(bstrip.len() / NR);
        for p in 0..kc {
            let av = &apack[p * MR..p * MR + MR];
            let bv = &bstrip[p * NR..p * NR + NR];
            for r in 0..MR {
                let ar = av[r];
                for cidx in 0..NR {
                    acc[r * NR + cidx] += ar * bv[cidx];
                }
            }
        }
    }

    pub(super) fn update4(
        yi: &mut [f64],
        l: [f64; 4],
        y0: &[f64],
        y1: &[f64],
        y2: &[f64],
        y3: &[f64],
    ) {
        let w = yi
            .len()
            .min(y0.len())
            .min(y1.len())
            .min(y2.len())
            .min(y3.len());
        for c in 0..w {
            yi[c] -= l[0] * y0[c] + l[1] * y1[c] + l[2] * y2[c] + l[3] * y3[c];
        }
    }

    pub(super) fn matvec4(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> [f64; 4] {
        let n = x
            .len()
            .min(r0.len())
            .min(r1.len())
            .min(r2.len())
            .min(r3.len());
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for j in 0..n {
            let xj = x[j];
            s0 += r0[j] * xj;
            s1 += r1[j] * xj;
            s2 += r2[j] * xj;
            s3 += r3[j] * xj;
        }
        [s0, s1, s2, s3]
    }
}

/// AVX2 implementations (256-bit, 4 × f64 lanes). Reached only through
/// [`select`] after `is_x86_feature_detected!("avx2")` succeeded.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{Kernels, MR, NR};
    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_permute2f128_pd,
        _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, _mm256_sub_pd, _mm256_unpackhi_pd,
        _mm256_unpacklo_pd,
    };

    pub(super) const KERNELS: Kernels = Kernels {
        name: "avx2",
        microkernel,
        update4,
        matvec4,
    };

    fn microkernel(kc: usize, apack: &[f64], bstrip: &[f64], acc: &mut [f64; MR * NR]) {
        // SAFETY: this table is only handed out by select() after the
        // avx2 runtime check passed, so the target-feature fn is
        // callable; it clamps kc to both slice lengths before any load.
        unsafe { microkernel_avx2(kc, apack, bstrip, acc) }
    }

    fn update4(yi: &mut [f64], l: [f64; 4], y0: &[f64], y1: &[f64], y2: &[f64], y3: &[f64]) {
        // SAFETY: avx2 verified by select() (see microkernel above);
        // the callee loads only below the clamped common width.
        unsafe { update4_avx2(yi, l, y0, y1, y2, y3) }
    }

    fn matvec4(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> [f64; 4] {
        // SAFETY: avx2 verified by select(); loads stay below the
        // clamped common length.
        unsafe { matvec4_avx2(r0, r1, r2, r3, x) }
    }

    /// `acc_r` lane `c` accumulates `apack[p·MR+r]·bstrip[p·NR+c]` in
    /// ascending `p`, exactly the scalar per-accumulator chain. Mul and
    /// add stay separate intrinsics: no FMA contraction.
    #[target_feature(enable = "avx2")]
    unsafe fn microkernel_avx2(kc: usize, apack: &[f64], bstrip: &[f64], acc: &mut [f64; MR * NR]) {
        let kc = kc.min(apack.len() / MR).min(bstrip.len() / NR);
        // SAFETY: acc is exactly MR·NR = 16 f64, so the four 4-lane
        // loads/stores at offsets 0/4/8/12 are in bounds; per-p loads
        // are bounded by the kc clamp above (p·NR + 4 ≤ bstrip.len(),
        // p·MR + 4 ≤ apack.len()).
        unsafe {
            let mut acc0 = _mm256_loadu_pd(acc.as_ptr());
            let mut acc1 = _mm256_loadu_pd(acc.as_ptr().add(NR));
            let mut acc2 = _mm256_loadu_pd(acc.as_ptr().add(2 * NR));
            let mut acc3 = _mm256_loadu_pd(acc.as_ptr().add(3 * NR));
            for p in 0..kc {
                let bv = _mm256_loadu_pd(bstrip.as_ptr().add(p * NR));
                let ap = apack.as_ptr().add(p * MR);
                acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(_mm256_set1_pd(*ap), bv));
                acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(_mm256_set1_pd(*ap.add(1)), bv));
                acc2 = _mm256_add_pd(acc2, _mm256_mul_pd(_mm256_set1_pd(*ap.add(2)), bv));
                acc3 = _mm256_add_pd(acc3, _mm256_mul_pd(_mm256_set1_pd(*ap.add(3)), bv));
            }
            _mm256_storeu_pd(acc.as_mut_ptr(), acc0);
            _mm256_storeu_pd(acc.as_mut_ptr().add(NR), acc1);
            _mm256_storeu_pd(acc.as_mut_ptr().add(2 * NR), acc2);
            _mm256_storeu_pd(acc.as_mut_ptr().add(3 * NR), acc3);
        }
    }

    /// Vector lanes are panel columns; the summand keeps the scalar
    /// sweep's left association `((l0·y0 + l1·y1) + l2·y2) + l3·y3`.
    #[target_feature(enable = "avx2")]
    unsafe fn update4_avx2(
        yi: &mut [f64],
        l: [f64; 4],
        y0: &[f64],
        y1: &[f64],
        y2: &[f64],
        y3: &[f64],
    ) {
        let w = yi
            .len()
            .min(y0.len())
            .min(y1.len())
            .min(y2.len())
            .min(y3.len());
        // SAFETY: every pointer load/store below is at offset c with
        // c + 4 ≤ w ≤ the length of each slice involved.
        unsafe {
            let l0 = _mm256_set1_pd(l[0]);
            let l1 = _mm256_set1_pd(l[1]);
            let l2 = _mm256_set1_pd(l[2]);
            let l3 = _mm256_set1_pd(l[3]);
            let mut c = 0;
            while c + 4 <= w {
                let t01 = _mm256_add_pd(
                    _mm256_mul_pd(l0, _mm256_loadu_pd(y0.as_ptr().add(c))),
                    _mm256_mul_pd(l1, _mm256_loadu_pd(y1.as_ptr().add(c))),
                );
                let t012 = _mm256_add_pd(t01, _mm256_mul_pd(l2, _mm256_loadu_pd(y2.as_ptr().add(c))));
                let t = _mm256_add_pd(t012, _mm256_mul_pd(l3, _mm256_loadu_pd(y3.as_ptr().add(c))));
                let v = _mm256_sub_pd(_mm256_loadu_pd(yi.as_ptr().add(c)), t);
                _mm256_storeu_pd(yi.as_mut_ptr().add(c), v);
                c += 4;
            }
            while c < w {
                yi[c] -= l[0] * y0[c] + l[1] * y1[c] + l[2] * y2[c] + l[3] * y3[c];
                c += 1;
            }
        }
    }

    /// Vector lanes are the four rows: a 4×4 transpose turns row loads
    /// into per-`j` columns, then each `j` adds one product per lane in
    /// ascending order — the scalar 4-accumulator chain, four lanes at
    /// a time.
    #[target_feature(enable = "avx2")]
    unsafe fn matvec4_avx2(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> [f64; 4] {
        let n = x
            .len()
            .min(r0.len())
            .min(r1.len())
            .min(r2.len())
            .min(r3.len());
        let mut out = [0.0f64; 4];
        // SAFETY: all vector loads read 4 lanes at offset j with
        // j + 4 ≤ n ≤ every slice's length; the final store writes the
        // local 4-element array.
        unsafe {
            let mut acc = _mm256_setzero_pd();
            let mut j = 0;
            while j + 4 <= n {
                let v0 = _mm256_loadu_pd(r0.as_ptr().add(j));
                let v1 = _mm256_loadu_pd(r1.as_ptr().add(j));
                let v2 = _mm256_loadu_pd(r2.as_ptr().add(j));
                let v3 = _mm256_loadu_pd(r3.as_ptr().add(j));
                // 4×4 transpose: c_t = (r0[j+t], r1[j+t], r2[j+t], r3[j+t]).
                let t0 = _mm256_unpacklo_pd(v0, v1);
                let t1 = _mm256_unpackhi_pd(v0, v1);
                let t2 = _mm256_unpacklo_pd(v2, v3);
                let t3 = _mm256_unpackhi_pd(v2, v3);
                let c0: __m256d = _mm256_permute2f128_pd(t0, t2, 0x20);
                let c1: __m256d = _mm256_permute2f128_pd(t1, t3, 0x20);
                let c2: __m256d = _mm256_permute2f128_pd(t0, t2, 0x31);
                let c3: __m256d = _mm256_permute2f128_pd(t1, t3, 0x31);
                acc = _mm256_add_pd(acc, _mm256_mul_pd(c0, _mm256_set1_pd(x[j])));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(c1, _mm256_set1_pd(x[j + 1])));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(c2, _mm256_set1_pd(x[j + 2])));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(c3, _mm256_set1_pd(x[j + 3])));
                j += 4;
            }
            _mm256_storeu_pd(out.as_mut_ptr(), acc);
            while j < n {
                let xj = x[j];
                out[0] += r0[j] * xj;
                out[1] += r1[j] * xj;
                out[2] += r2[j] * xj;
                out[3] += r3[j] * xj;
                j += 1;
            }
        }
        out
    }
}

/// NEON implementations (128-bit, 2 × f64 lanes). Reached only through
/// [`select`] after `is_aarch64_feature_detected!("neon")` succeeded.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{Kernels, MR, NR};
    use std::arch::aarch64::{
        vaddq_f64, vdupq_n_f64, vld1q_f64, vmulq_f64, vst1q_f64, vsubq_f64, vtrn1q_f64, vtrn2q_f64,
    };

    pub(super) const KERNELS: Kernels = Kernels {
        name: "neon",
        microkernel,
        update4,
        matvec4,
    };

    fn microkernel(kc: usize, apack: &[f64], bstrip: &[f64], acc: &mut [f64; MR * NR]) {
        // SAFETY: this table is only handed out by select() after the
        // neon runtime check passed; the callee clamps kc before any
        // load.
        unsafe { microkernel_neon(kc, apack, bstrip, acc) }
    }

    fn update4(yi: &mut [f64], l: [f64; 4], y0: &[f64], y1: &[f64], y2: &[f64], y3: &[f64]) {
        // SAFETY: neon verified by select(); loads stay below the
        // clamped common width.
        unsafe { update4_neon(yi, l, y0, y1, y2, y3) }
    }

    fn matvec4(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> [f64; 4] {
        // SAFETY: neon verified by select(); loads stay below the
        // clamped common length.
        unsafe { matvec4_neon(r0, r1, r2, r3, x) }
    }

    /// Two 2-lane accumulators per microtile row (columns 0–1 / 2–3),
    /// chained over `p` in ascending order, mul and add unfused.
    #[target_feature(enable = "neon")]
    unsafe fn microkernel_neon(kc: usize, apack: &[f64], bstrip: &[f64], acc: &mut [f64; MR * NR]) {
        let kc = kc.min(apack.len() / MR).min(bstrip.len() / NR);
        // SAFETY: acc is MR·NR = 16 f64 so the eight 2-lane loads and
        // stores are in bounds; per-p loads are bounded by the clamp.
        unsafe {
            let mut lo = [vld1q_f64(acc.as_ptr()); MR];
            let mut hi = [vld1q_f64(acc.as_ptr()); MR];
            for r in 0..MR {
                lo[r] = vld1q_f64(acc.as_ptr().add(r * NR));
                hi[r] = vld1q_f64(acc.as_ptr().add(r * NR + 2));
            }
            for p in 0..kc {
                let blo = vld1q_f64(bstrip.as_ptr().add(p * NR));
                let bhi = vld1q_f64(bstrip.as_ptr().add(p * NR + 2));
                let ap = apack.as_ptr().add(p * MR);
                for r in 0..MR {
                    let ar = vdupq_n_f64(*ap.add(r));
                    lo[r] = vaddq_f64(lo[r], vmulq_f64(ar, blo));
                    hi[r] = vaddq_f64(hi[r], vmulq_f64(ar, bhi));
                }
            }
            for r in 0..MR {
                vst1q_f64(acc.as_mut_ptr().add(r * NR), lo[r]);
                vst1q_f64(acc.as_mut_ptr().add(r * NR + 2), hi[r]);
            }
        }
    }

    /// Lanes are panel columns (two at a time); the summand keeps the
    /// scalar left association.
    #[target_feature(enable = "neon")]
    unsafe fn update4_neon(
        yi: &mut [f64],
        l: [f64; 4],
        y0: &[f64],
        y1: &[f64],
        y2: &[f64],
        y3: &[f64],
    ) {
        let w = yi
            .len()
            .min(y0.len())
            .min(y1.len())
            .min(y2.len())
            .min(y3.len());
        // SAFETY: every load/store is at offset c with c + 2 ≤ w ≤ the
        // length of each slice involved.
        unsafe {
            let l0 = vdupq_n_f64(l[0]);
            let l1 = vdupq_n_f64(l[1]);
            let l2 = vdupq_n_f64(l[2]);
            let l3 = vdupq_n_f64(l[3]);
            let mut c = 0;
            while c + 2 <= w {
                let t01 = vaddq_f64(
                    vmulq_f64(l0, vld1q_f64(y0.as_ptr().add(c))),
                    vmulq_f64(l1, vld1q_f64(y1.as_ptr().add(c))),
                );
                let t012 = vaddq_f64(t01, vmulq_f64(l2, vld1q_f64(y2.as_ptr().add(c))));
                let t = vaddq_f64(t012, vmulq_f64(l3, vld1q_f64(y3.as_ptr().add(c))));
                let v = vsubq_f64(vld1q_f64(yi.as_ptr().add(c)), t);
                vst1q_f64(yi.as_mut_ptr().add(c), v);
                c += 2;
            }
            while c < w {
                yi[c] -= l[0] * y0[c] + l[1] * y1[c] + l[2] * y2[c] + l[3] * y3[c];
                c += 1;
            }
        }
    }

    /// Lanes are row pairs (0–1 / 2–3); a 2×2 transpose per `j` pair
    /// feeds one product per lane per `j` in ascending order.
    #[target_feature(enable = "neon")]
    unsafe fn matvec4_neon(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> [f64; 4] {
        let n = x
            .len()
            .min(r0.len())
            .min(r1.len())
            .min(r2.len())
            .min(r3.len());
        let mut out = [0.0f64; 4];
        // SAFETY: all 2-lane loads are at offset j with j + 2 ≤ n ≤
        // every slice's length; the stores write the local array.
        unsafe {
            let mut acc01 = vdupq_n_f64(0.0);
            let mut acc23 = vdupq_n_f64(0.0);
            let mut j = 0;
            while j + 2 <= n {
                let v0 = vld1q_f64(r0.as_ptr().add(j));
                let v1 = vld1q_f64(r1.as_ptr().add(j));
                let v2 = vld1q_f64(r2.as_ptr().add(j));
                let v3 = vld1q_f64(r3.as_ptr().add(j));
                // 2×2 transpose: columns (r0[j], r1[j]) and (r0[j+1], r1[j+1]).
                let c01_j = vtrn1q_f64(v0, v1);
                let c01_j1 = vtrn2q_f64(v0, v1);
                let c23_j = vtrn1q_f64(v2, v3);
                let c23_j1 = vtrn2q_f64(v2, v3);
                let xj = vdupq_n_f64(x[j]);
                let xj1 = vdupq_n_f64(x[j + 1]);
                acc01 = vaddq_f64(acc01, vmulq_f64(c01_j, xj));
                acc23 = vaddq_f64(acc23, vmulq_f64(c23_j, xj));
                acc01 = vaddq_f64(acc01, vmulq_f64(c01_j1, xj1));
                acc23 = vaddq_f64(acc23, vmulq_f64(c23_j1, xj1));
                j += 2;
            }
            vst1q_f64(out.as_mut_ptr(), acc01);
            vst1q_f64(out.as_mut_ptr().add(2), acc23);
            while j < n {
                let xj = x[j];
                out[0] += r0[j] * xj;
                out[1] += r1[j] * xj;
                out[2] += r2[j] * xj;
                out[3] += r3[j] * xj;
                j += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vec_rand(r: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| r.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn selection_is_stable_and_named() {
        // active() must return the same table every call (OnceLock),
        // and its name must be one of the known implementations.
        let a = active();
        let b = active();
        assert_eq!(a.name, b.name);
        assert!(
            ["scalar", "avx2", "avx2 (avx512f host)", "neon"].contains(&a.name),
            "unknown kernel table {:?}",
            a.name
        );
        assert_eq!(scalar().name, "scalar");
    }

    #[test]
    fn microkernel_simd_bit_identical_to_scalar() {
        // On SIMD hosts this is the real oracle check; on scalar-only
        // hosts (and under Miri, where detection reports nothing) both
        // sides are the scalar kernel and the test is a tautology.
        let mut r = Rng::new(0x51D);
        for kc in [0usize, 1, 2, 3, 4, 7, 8, 33, 256] {
            let apack = vec_rand(&mut r, kc * MR);
            let bstrip = vec_rand(&mut r, kc * NR);
            let seed = vec_rand(&mut r, MR * NR);
            let mut want = [0.0f64; MR * NR];
            let mut got = [0.0f64; MR * NR];
            want.copy_from_slice(&seed);
            got.copy_from_slice(&seed);
            (SCALAR.microkernel)(kc, &apack, &bstrip, &mut want);
            (active().microkernel)(kc, &apack, &bstrip, &mut got);
            assert_eq!(want, got, "kc={kc}");
        }
    }

    #[test]
    fn update4_simd_bit_identical_to_scalar() {
        let mut r = Rng::new(0x51E);
        for w in [0usize, 1, 2, 3, 4, 5, 7, 8, 127, 128, 131] {
            let l = [
                r.uniform(-2.0, 2.0),
                r.uniform(-2.0, 2.0),
                0.0, // a zero coefficient must not change the path
                r.uniform(-2.0, 2.0),
            ];
            let y0 = vec_rand(&mut r, w);
            let y1 = vec_rand(&mut r, w);
            let y2 = vec_rand(&mut r, w);
            let y3 = vec_rand(&mut r, w);
            let seed = vec_rand(&mut r, w);
            let mut want = seed.clone();
            let mut got = seed.clone();
            (SCALAR.update4)(&mut want, l, &y0, &y1, &y2, &y3);
            (active().update4)(&mut got, l, &y0, &y1, &y2, &y3);
            assert_eq!(want, got, "w={w}");
        }
    }

    #[test]
    fn matvec4_simd_bit_identical_to_scalar() {
        let mut r = Rng::new(0x51F);
        for n in [0usize, 1, 2, 3, 4, 5, 8, 63, 64, 65] {
            let r0 = vec_rand(&mut r, n);
            let r1 = vec_rand(&mut r, n);
            let r2 = vec_rand(&mut r, n);
            let r3 = vec_rand(&mut r, n);
            let x = vec_rand(&mut r, n);
            let want = (SCALAR.matvec4)(&r0, &r1, &r2, &r3, &x);
            let got = (active().matvec4)(&r0, &r1, &r2, &r3, &x);
            assert_eq!(want, got, "n={n}");
        }
    }

    #[test]
    fn kernels_are_total_on_short_slices() {
        // The clamp contract: mismatched slice lengths truncate instead
        // of panicking or reading out of bounds.
        let mut acc = [0.0f64; MR * NR];
        (SCALAR.microkernel)(100, &[1.0; 8], &[1.0; 8], &mut acc);
        (active().microkernel)(100, &[1.0; 8], &[1.0; 8], &mut acc);
        let mut yi = vec![1.0; 10];
        (active().update4)(&mut yi, [1.0; 4], &[1.0; 3], &[1.0; 10], &[1.0; 10], &[1.0; 10]);
        assert_eq!(&yi[3..], &[1.0; 7][..], "columns past the clamp untouched");
        let s = (active().matvec4)(&[1.0; 5], &[1.0; 5], &[1.0; 5], &[1.0; 5], &[2.0; 3]);
        assert_eq!(s, [6.0; 4]);
    }
}
