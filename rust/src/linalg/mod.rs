//! Dense linear-algebra substrate.
//!
//! MDS decoding over the reals reduces to solving `k × k` linear systems
//! whose coefficient matrices are submatrices of the code's generator
//! (§II-A). No external BLAS/LAPACK is available offline, so this module
//! provides the needed kernels: a row-major [`Matrix`], blocked
//! GEMM/GEMV ([`ops`]), partial-pivot LU with solve/inverse and the
//! erasure-pattern factor cache ([`lu`]), runtime-dispatched SIMD inner
//! kernels ([`dispatch`]) and the Vandermonde / Cauchy generator
//! builders ([`vandermonde`]).

pub mod dispatch;
pub mod lu;
pub mod matrix;
pub mod ops;
pub mod vandermonde;

pub use lu::{LuCache, LuFactors};
pub use matrix::Matrix;
