//! Generator-matrix builders for real-field MDS codes.
//!
//! An `(n, k)` code is MDS iff every `k×k` submatrix of its generator is
//! nonsingular. Over the reals a Vandermonde matrix on distinct
//! evaluation points has this property, but raw Vandermonde systems are
//! catastrophically ill-conditioned as `k` grows; we therefore build
//! **systematic Cauchy** generators (`[I; C]` with `C` a Cauchy block)
//! whose decode systems stay usable at the paper's scales (k up to ~400)
//! and keep plain Vandermonde around for the polynomial-code baseline,
//! which is *defined* by evaluation (Yu et al., 2017).

use crate::linalg::{lu::LuFactors, Matrix};
use crate::{Error, Result};

/// `n×k` Vandermonde matrix `V[i][j] = x_i^j` on distinct points `x`.
pub fn vandermonde(points: &[f64], k: usize) -> Matrix {
    Matrix::from_fn(points.len(), k, |i, j| points[i].powi(j as i32))
}

/// Default evaluation points for an `n`-row Vandermonde: `1..=n` scaled
/// into `(0, 2]` to limit dynamic range of the powers.
pub fn default_points(n: usize) -> Vec<f64> {
    (1..=n).map(|i| 2.0 * i as f64 / n as f64).collect()
}

/// Systematic `(n, k)` MDS generator `[I_k; P]`: the first `k` rows are
/// identity (systematic shards are the data itself — decode is free when
/// the `k` fastest are systematic), and `P` is an `(n−k)×k` block of
/// seeded Gaussian entries scaled by `1/√k`.
///
/// A random parity block is MDS with probability 1 and — unlike Cauchy
/// or Vandermonde blocks, whose condition numbers grow *exponentially*
/// in `k` — its square submatrices stay numerically invertible at the
/// paper's scales (`k1 = 400`): random-matrix condition numbers grow
/// only polynomially. The block is derived deterministically from
/// `(n, k)`, so encoder and decoder agree without sharing state, and
/// [`verify_mds`] checks the property exhaustively for small `n` /
/// by sampling for large `n`.
pub fn systematic_mds(n: usize, k: usize) -> Result<Matrix> {
    if k == 0 || k > n {
        return Err(Error::InvalidParams(format!(
            "systematic_mds: need 1 <= k <= n, got ({n}, {k})"
        )));
    }
    let mut g = Matrix::zeros(n, k);
    for i in 0..k {
        g[(i, i)] = 1.0;
    }
    // Deterministic seed from (n, k): encoder and decoder independently
    // reconstruct the identical generator.
    let seed = 0x48434F44u64 // "HCOD"
        .wrapping_mul(2654435761)
        .wrapping_add((n as u64) << 32)
        .wrapping_add(k as u64);
    let mut rng = crate::util::rng::Rng::new(seed);
    let scale = 1.0 / (k as f64).sqrt();
    for i in k..n {
        for j in 0..k {
            g[(i, j)] = rng.normal() * scale;
        }
    }
    Ok(g)
}

/// Verify the MDS property on a set of row-subsets: each `k×k` submatrix
/// must factorize. Exhaustive over all subsets for small `n`, sampled
/// otherwise (`trials` random subsets).
pub fn verify_mds(g: &Matrix, trials: usize, rng: &mut crate::util::rng::Rng) -> Result<()> {
    let (n, k) = g.shape();
    let exhaustive_limit = 16;
    if n <= exhaustive_limit {
        let mut idx = vec![0usize; k];
        verify_subsets_rec(g, &mut idx, 0, 0, n, k)?;
    } else {
        for _ in 0..trials {
            let subset = rng.subset(n, k);
            let sub = g.select_rows(&subset);
            LuFactors::factorize(&sub).map_err(|_| {
                Error::Numerical(format!("singular {k}x{k} submatrix at rows {subset:?}"))
            })?;
        }
    }
    Ok(())
}

fn verify_subsets_rec(
    g: &Matrix,
    idx: &mut Vec<usize>,
    pos: usize,
    start: usize,
    n: usize,
    k: usize,
) -> Result<()> {
    if pos == k {
        let sub = g.select_rows(idx);
        LuFactors::factorize(&sub).map_err(|_| {
            Error::Numerical(format!("singular submatrix at rows {idx:?}"))
        })?;
        return Ok(());
    }
    for i in start..n {
        idx[pos] = i;
        verify_subsets_rec(g, idx, pos + 1, i + 1, n, k)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn vandermonde_shape_and_values() {
        let v = vandermonde(&[2.0, 3.0], 3);
        assert_eq!(v.shape(), (2, 3));
        assert_eq!(v.row(0), &[1.0, 2.0, 4.0]);
        assert_eq!(v.row(1), &[1.0, 3.0, 9.0]);
    }

    #[test]
    fn systematic_prefix_is_identity() {
        let g = systematic_mds(6, 3).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(g[(i, j)], if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(systematic_mds(3, 0).is_err());
        assert!(systematic_mds(3, 4).is_err());
        assert!(systematic_mds(3, 3).is_ok()); // n == k degenerates to identity
    }

    #[test]
    fn mds_property_exhaustive_small() {
        let mut r = Rng::new(1);
        for (n, k) in [(3, 2), (5, 3), (6, 4), (10, 7), (14, 10)] {
            let g = systematic_mds(n, k).unwrap();
            verify_mds(&g, 0, &mut r).unwrap_or_else(|e| panic!("({n},{k}): {e}"));
        }
    }

    #[test]
    fn mds_property_sampled_large() {
        let mut r = Rng::new(2);
        let g = systematic_mds(800, 400).unwrap();
        verify_mds(&g, 20, &mut r).unwrap();
    }

    #[test]
    fn vandermonde_is_mds_small() {
        let mut r = Rng::new(3);
        let g = vandermonde(&default_points(8), 5);
        verify_mds(&g, 0, &mut r).unwrap();
    }
}
