//! Partial-pivot LU factorization, solve, inverse and the
//! erasure-pattern factor cache.
//!
//! MDS decoding solves `G_S · A = Y` where `G_S` is the `k×k` submatrix
//! of the generator for the responding workers and `Y` stacks their
//! results. Decoding cost is `O(k^β)` with `β ≈ 2` once the `O(k³)`
//! factorization is amortized across the `m/k`-row right-hand sides —
//! which is exactly the cost model the paper assumes (§IV, footnote 2).
//! [`LuCache`] pushes the amortization across *requests*: `G_S` depends
//! only on which workers responded, so under steady serving traffic —
//! where the same few erasure patterns recur — the factors are memoized
//! keyed by the **sorted** surviving-index set (the decoders gather
//! rows in sorted index order precisely so arrival order cannot fork
//! the key or the arithmetic). The cache is bounded (LRU eviction),
//! per code instance (factors derive from the generator, never from
//! model data), and invalidated wholesale by the coordinator whenever
//! the ground truth could shift — model re-registration and supervisor
//! shard re-shipping after a worker restart — so a stale pattern can
//! never decode against rewired shards.

use crate::linalg::dispatch;
use crate::linalg::Matrix;
use crate::parallel::DecodePool;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Columns per solve panel: the triangular working set is
/// `n × SOLVE_PANEL` f64 (128 KiB at n = 128 — L2-resident), and panel
/// count bounds the useful decode-thread fan-out of one solve. Fixed —
/// never derived from the thread count — so panel boundaries (and thus
/// bit-exact results) are independent of parallelism.
const SOLVE_PANEL: usize = 128;

/// LU factors of a square matrix with row pivoting: `P·A = L·U`.
#[derive(Clone, Debug)]
pub struct LuFactors {
    /// Packed L (unit lower, below diagonal) and U (upper incl. diag).
    lu: Matrix,
    /// Row permutation: `perm[i]` is the original row in position `i`.
    perm: Vec<usize>,
    /// Number of flops spent factorizing (for §IV cost accounting).
    factor_flops: u64,
}

impl LuFactors {
    /// Factorize `a` (square). Fails on structural singularity.
    pub fn factorize(a: &Matrix) -> Result<Self> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::InvalidParams(format!(
                "LU of non-square {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut flops: u64 = 0;
        for col in 0..n {
            // Pivot: largest |entry| in this column at or below diagonal.
            let mut p = col;
            let mut best = lu[(col, col)].abs();
            for r in (col + 1)..n {
                let v = lu[(r, col)].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-12 {
                return Err(Error::Numerical(format!(
                    "singular system at column {col} (pivot {best:.3e})"
                )));
            }
            if p != col {
                perm.swap(p, col);
                // Swap full rows of the packed storage.
                for j in 0..n {
                    let tmp = lu[(col, j)];
                    lu[(col, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
            }
            let pivot = lu[(col, col)];
            for r in (col + 1)..n {
                let factor = lu[(r, col)] / pivot;
                lu[(r, col)] = factor;
                if factor == 0.0 {
                    continue;
                }
                for j in (col + 1)..n {
                    let v = lu[(col, j)];
                    lu[(r, j)] -= factor * v;
                }
                flops += 2 * (n - col) as u64;
            }
        }
        Ok(Self {
            lu,
            perm,
            factor_flops: flops,
        })
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.lu.rows()
    }

    /// Flops spent in factorization.
    pub fn factor_flops(&self) -> u64 {
        self.factor_flops
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.dim();
        if b.len() != n {
            return Err(Error::InvalidParams(format!(
                "rhs length {} != {n}",
                b.len()
            )));
        }
        // Forward substitution on permuted b: L y = P b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = b[self.perm[i]];
            for j in 0..i {
                acc -= self.lu[(i, j)] * y[j];
            }
            y[i] = acc;
        }
        // Back substitution: U x = y.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in (i + 1)..n {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
        Ok(x)
    }

    /// Solve `A X = B` for a matrix of right-hand sides — the blocked
    /// multi-RHS solve on the decode hot path: `B` has `m/k2/k1 · batch`
    /// columns and the per-column cost is `O(k²)` (the `β = 2` regime).
    pub fn solve_matrix(&self, b: &Matrix) -> Result<Matrix> {
        self.solve_matrix_with(b, &DecodePool::serial(), &mut Vec::new())
    }

    /// Blocked multi-RHS solve with per-panel parallelism and caller
    /// scratch.
    ///
    /// The RHS columns are gathered (permuted) into contiguous panels of
    /// [`SOLVE_PANEL`] columns inside `scratch` (reused across calls —
    /// the decoders' zero-alloc steady state), each panel runs its own
    /// forward + back substitution sweep — fanned across `pool`, since
    /// panels are fully independent — and the solved panels scatter
    /// into the row-major result. §Perf: relative to the old per-(i,j)
    /// axpy sweep this (a) touches each `y_j` row once per `y_i` with a
    /// 4-way unrolled source accumulation instead of i separate
    /// read-modify-write passes, and (b) keeps the working set at
    /// `n × SOLVE_PANEL` f64 (128 KiB at k = 128) instead of `n × cols`
    /// (`hiercode bench`'s `lu_solve` entry measures the combination).
    /// Per-column arithmetic order is fixed by the panel algorithm
    /// alone, so results are bit-identical at any pool width.
    pub fn solve_matrix_with(
        &self,
        b: &Matrix,
        pool: &DecodePool,
        scratch: &mut Vec<f64>,
    ) -> Result<Matrix> {
        let n = self.dim();
        if b.rows() != n {
            return Err(Error::InvalidParams(format!(
                "rhs rows {} != {n}",
                b.rows()
            )));
        }
        let cols = b.cols();
        let mut y = Matrix::zeros(n, cols);
        if n == 0 || cols == 0 {
            return Ok(y);
        }
        // Gather the permuted RHS into contiguous column panels. Grow
        // the scratch without re-zeroing: the gather below overwrites
        // the full n·cols working region every call.
        if scratch.len() < n * cols {
            scratch.resize(n * cols, 0.0);
        }
        let panels: Vec<(usize, usize)> = (0..cols)
            .step_by(SOLVE_PANEL)
            .map(|c0| (c0, SOLVE_PANEL.min(cols - c0)))
            .collect();
        {
            let mut off = 0;
            let mut chunks = Vec::with_capacity(panels.len());
            let mut rest: &mut [f64] = scratch;
            for &(_, w) in &panels {
                // mem::take moves the reference itself, so `head` keeps
                // the full scratch lifetime while `rest` advances.
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(n * w);
                chunks.push(head);
                rest = tail;
                off += n * w;
            }
            debug_assert_eq!(off, n * cols);
            for (chunk, &(c0, w)) in chunks.iter_mut().zip(&panels) {
                for i in 0..n {
                    chunk[i * w..(i + 1) * w]
                        .copy_from_slice(&b.row(self.perm[i])[c0..c0 + w]);
                }
            }
            // Solve every panel, in parallel when it pays.
            if pool.size() > 1 && chunks.len() > 1 {
                let tasks: Vec<(&mut [f64], usize)> = chunks
                    .into_iter()
                    .zip(panels.iter().map(|&(_, w)| w))
                    .collect();
                pool.map(tasks, |(chunk, w)| self.solve_panel(chunk, w));
            } else {
                for (chunk, &(_, w)) in chunks.into_iter().zip(&panels) {
                    self.solve_panel(chunk, w);
                }
            }
        }
        // Scatter the solved panels back to row-major.
        let mut off = 0;
        for &(c0, w) in &panels {
            for i in 0..n {
                y.row_mut(i)[c0..c0 + w]
                    .copy_from_slice(&scratch[off + i * w..off + (i + 1) * w]);
            }
            off += n * w;
        }
        Ok(y)
    }

    /// Forward + back substitution on one contiguous `n × w` panel.
    /// The 4-source sweeps run the dispatched
    /// [`dispatch::Kernels::update4`] kernel (SIMD where the host has
    /// it, bit-identical to the scalar fallback by construction).
    fn solve_panel(&self, sl: &mut [f64], w: usize) {
        let kern = dispatch::active();
        let n = self.dim();
        // Forward: L y = P b (unit lower triangle).
        for i in 1..n {
            let (head, tail) = sl.split_at_mut(i * w);
            let yi = &mut tail[..w];
            let lrow = self.lu.row(i);
            let mut j = 0;
            while j + 4 <= i {
                let l = [lrow[j], lrow[j + 1], lrow[j + 2], lrow[j + 3]];
                let y0 = &head[j * w..(j + 1) * w];
                let y1 = &head[(j + 1) * w..(j + 2) * w];
                let y2 = &head[(j + 2) * w..(j + 3) * w];
                let y3 = &head[(j + 3) * w..(j + 4) * w];
                (kern.update4)(yi, l, y0, y1, y2, y3);
                j += 4;
            }
            while j < i {
                let lij = lrow[j];
                if lij != 0.0 {
                    let yj = &head[j * w..(j + 1) * w];
                    for c in 0..w {
                        yi[c] -= lij * yj[c];
                    }
                }
                j += 1;
            }
        }
        // Back: U x = y.
        for i in (0..n).rev() {
            let (head, tail) = sl.split_at_mut((i + 1) * w);
            let yi = &mut head[i * w..];
            let urow = self.lu.row(i);
            let mut j = i + 1;
            while j + 4 <= n {
                let u = [urow[j], urow[j + 1], urow[j + 2], urow[j + 3]];
                let base = (j - i - 1) * w;
                let x0 = &tail[base..base + w];
                let x1 = &tail[base + w..base + 2 * w];
                let x2 = &tail[base + 2 * w..base + 3 * w];
                let x3 = &tail[base + 3 * w..base + 4 * w];
                (kern.update4)(yi, u, x0, x1, x2, x3);
                j += 4;
            }
            while j < n {
                let uij = urow[j];
                if uij != 0.0 {
                    let base = (j - i - 1) * w;
                    let xj = &tail[base..base + w];
                    for c in 0..w {
                        yi[c] -= uij * xj[c];
                    }
                }
                j += 1;
            }
            let d = urow[i];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
    }

    /// Flops for solving `cols` right-hand sides (2n² each, plus the
    /// one-off factorization) — used by the §IV decode-cost accounting.
    pub fn solve_flops(&self, cols: usize) -> u64 {
        let n = self.dim() as u64;
        2 * n * n * cols as u64
    }

    /// Matrix inverse via `n` unit-vector solves.
    pub fn inverse(&self) -> Result<Matrix> {
        let n = self.dim();
        self.solve_matrix(&Matrix::identity(n))
    }
}

/// Convenience: solve `A x = b` in one call.
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    LuFactors::factorize(a)?.solve_vec(b)
}

/// Default [`LuCache`] capacity: generously above the handful of
/// erasure patterns steady traffic produces per group, small enough
/// that a worst-case full cache of `k×k` factors stays a few MiB.
pub const LU_CACHE_PATTERNS: usize = 32;

/// Point-in-time counters of one [`LuCache`] (hits and misses count
/// lookups, so `hits + misses` is the total lookup count; `evictions`
/// counts entries dropped, by LRU pressure or invalidation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LuCacheStats {
    /// Lookups that returned memoized factors (factorization skipped).
    pub hits: u64,
    /// Lookups that found no entry (the caller factorizes and inserts).
    pub misses: u64,
    /// Entries dropped — LRU pressure or `invalidate_all`.
    pub evictions: u64,
}

impl LuCacheStats {
    /// Sum component-wise — aggregation across a scheme's caches.
    pub fn merge(self, other: LuCacheStats) -> LuCacheStats {
        LuCacheStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            evictions: self.evictions + other.evictions,
        }
    }

    /// Hit rate in `[0, 1]`, or NaN before the first lookup (the same
    /// "no data yet" sentinel the latency histograms use).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            f64::NAN
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One memoized erasure pattern.
#[derive(Debug)]
struct LuCacheEntry {
    /// Sorted surviving-index set (the canonical decode order).
    key: Box<[usize]>,
    /// LRU clock value of the last touch.
    stamp: u64,
    /// The memoized factors, shared with in-flight solves.
    factors: Arc<LuFactors>,
}

/// Bounded memo of LU factors keyed by the **sorted** surviving-index
/// set of a decode — the erasure-pattern cache of the serving hot path.
///
/// Contract (the one the module docs describe):
/// * **Keying.** The key is the sorted list of shard indices whose
///   results the decoder consumed. Decoders canonicalize to sorted
///   order before building `G_S`, so equal index *sets* produce equal
///   keys *and* equal arithmetic — a hit returns bit-identical factors
///   to what refactorizing would produce.
/// * **Eviction.** Capacity is fixed at construction; inserting into a
///   full cache evicts the least-recently-used entry.
/// * **Invalidation.** [`LuCache::invalidate_all`] empties the cache
///   (counting the drops as evictions). The coordinator calls it on
///   model re-registration and on supervisor shard re-shipping; the
///   factors themselves are generator-derived, so this is conservative
///   — but conservative is what keeps a rewired cluster provably
///   consistent.
///
/// Lookups take a short internal mutex (linear scan over at most
/// `cap` entries — no hashing, so nothing about iteration order can
/// leak into results); counters are lock-free atomics.
#[derive(Debug)]
pub struct LuCache {
    /// Entries, unordered; `stamp` carries recency.
    entries: crate::sync::Mutex<Vec<LuCacheEntry>>,
    /// Maximum entry count.
    cap: usize,
    /// Monotonic LRU clock.
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for LuCache {
    fn default() -> Self {
        Self::new(LU_CACHE_PATTERNS)
    }
}

impl LuCache {
    /// Cache holding at most `cap` patterns (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Self {
            entries: crate::sync::Mutex::new(Vec::new()),
            cap: cap.max(1),
            clock: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Memoized factors for the sorted index set `key`, bumping the
    /// hit counter and the entry's recency — or `None` (a miss) when
    /// the pattern has not been seen since the last invalidation.
    pub fn lookup(&self, key: &[usize]) -> Option<Arc<LuFactors>> {
        let mut entries = self.entries.lock();
        if let Some(e) = entries.iter_mut().find(|e| e.key.as_ref() == key) {
            e.stamp = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(Arc::clone(&e.factors));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Memoize `factors` under the sorted index set `key`, evicting the
    /// least-recently-used entry when full. Re-inserting an existing
    /// key replaces its factors (a racing double-factorize is benign:
    /// both computed identical bits from identical inputs).
    pub fn insert(&self, key: Vec<usize>, factors: Arc<LuFactors>) {
        let stamp = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        let mut entries = self.entries.lock();
        if let Some(e) = entries.iter_mut().find(|e| e.key.as_ref() == key.as_slice()) {
            e.stamp = stamp;
            e.factors = factors;
            return;
        }
        if entries.len() >= self.cap {
            if let Some(lru) = entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
            {
                entries.swap_remove(lru);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        entries.push(LuCacheEntry {
            key: key.into_boxed_slice(),
            stamp,
            factors,
        });
    }

    /// Drop every entry (counted as evictions). The coordinator's
    /// invalidation hook for model re-registration and shard
    /// re-shipping.
    pub fn invalidate_all(&self) {
        let mut entries = self.entries.lock();
        let dropped = entries.len() as u64;
        entries.clear();
        if dropped > 0 {
            self.evictions.fetch_add(dropped, Ordering::Relaxed);
        }
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.entries.lock().len()
    }

    /// True when no pattern is memoized.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot.
    pub fn stats(&self) -> LuCacheStats {
        LuCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::util::check::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn random_well_conditioned(r: &mut Rng, n: usize) -> Matrix {
        // Diagonally dominant → well conditioned and nonsingular.
        let mut m = Matrix::from_fn(n, n, |_, _| r.uniform(-1.0, 1.0));
        for i in 0..n {
            m[(i, i)] += n as f64;
        }
        m
    }

    #[test]
    fn solve_known_system() {
        // [2 1; 1 3] x = [3; 5] → x = [0.8, 1.4]
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = solve(&a, &[3.0, 5.0]).unwrap();
        assert_allclose(&x, &[0.8, 1.4], 1e-12, 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(matches!(
            LuFactors::factorize(&a),
            Err(Error::Numerical(_))
        ));
    }

    #[test]
    fn non_square_rejected() {
        let a = Matrix::zeros(2, 3);
        assert!(LuFactors::factorize(&a).is_err());
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = solve(&a, &[2.0, 3.0]).unwrap();
        assert_allclose(&x, &[3.0, 2.0], 1e-12, 1e-12);
    }

    #[test]
    fn solve_matrix_matches_vector_solves() {
        let mut r = Rng::new(10);
        let a = random_well_conditioned(&mut r, 8);
        let b = Matrix::from_fn(8, 5, |_, _| r.uniform(-2.0, 2.0));
        let f = LuFactors::factorize(&a).unwrap();
        let x = f.solve_matrix(&b).unwrap();
        for j in 0..5 {
            let bj: Vec<f64> = (0..8).map(|i| b[(i, j)]).collect();
            let xj = f.solve_vec(&bj).unwrap();
            let got: Vec<f64> = (0..8).map(|i| x[(i, j)]).collect();
            assert_allclose(&got, &xj, 1e-10, 1e-12);
        }
    }

    #[test]
    fn inverse_times_original_is_identity() {
        let mut r = Rng::new(11);
        for n in [1, 2, 5, 16] {
            let a = random_well_conditioned(&mut r, n);
            let inv = LuFactors::factorize(&a).unwrap().inverse().unwrap();
            let prod = ops::matmul(&inv, &a);
            assert!(
                prod.max_abs_diff(&Matrix::identity(n)) < 1e-9,
                "n={n}: {}",
                prod.max_abs_diff(&Matrix::identity(n))
            );
        }
    }

    #[test]
    fn residual_property_random_systems() {
        check("LU solve residual", 40, |g| {
            let n = g.usize_in(1..20);
            let mut r = Rng::new(g.usize_in(0..1_000_000) as u64);
            let a = random_well_conditioned(&mut r, n);
            let b: Vec<f64> = (0..n).map(|_| r.uniform(-5.0, 5.0)).collect();
            let x = solve(&a, &b).unwrap();
            let ax = ops::matvec(&a, &x);
            assert_allclose(&ax, &b, 1e-8, 1e-8);
        });
    }

    #[test]
    fn solve_matrix_spans_panel_boundaries() {
        // cols > SOLVE_PANEL exercises the gather/scatter multi-panel
        // path; correctness is checked against per-column solves.
        let mut r = Rng::new(14);
        let a = random_well_conditioned(&mut r, 6);
        let b = Matrix::from_fn(6, SOLVE_PANEL + 37, |_, _| r.uniform(-1.0, 1.0));
        let f = LuFactors::factorize(&a).unwrap();
        let x = f.solve_matrix(&b).unwrap();
        for j in [0, 1, SOLVE_PANEL - 1, SOLVE_PANEL, SOLVE_PANEL + 36] {
            let bj: Vec<f64> = (0..6).map(|i| b[(i, j)]).collect();
            let xj = f.solve_vec(&bj).unwrap();
            let got: Vec<f64> = (0..6).map(|i| x[(i, j)]).collect();
            assert_allclose(&got, &xj, 1e-10, 1e-12);
        }
    }

    #[test]
    fn pooled_solve_is_bit_identical_to_serial() {
        let mut r = Rng::new(15);
        let a = random_well_conditioned(&mut r, 12);
        let b = Matrix::from_fn(12, 3 * SOLVE_PANEL + 5, |_, _| r.uniform(-1.0, 1.0));
        let f = LuFactors::factorize(&a).unwrap();
        let serial = f.solve_matrix(&b).unwrap();
        for threads in [2, 8] {
            let pool = DecodePool::new(threads).unwrap();
            let mut scratch = Vec::new();
            let par = f.solve_matrix_with(&b, &pool, &mut scratch).unwrap();
            assert_eq!(serial.data(), par.data(), "threads={threads}");
            // Scratch is reused: a second call must not change results.
            let again = f.solve_matrix_with(&b, &pool, &mut scratch).unwrap();
            assert_eq!(serial.data(), again.data());
        }
    }

    #[test]
    fn flop_accounting_positive() {
        let mut r = Rng::new(12);
        let a = random_well_conditioned(&mut r, 10);
        let f = LuFactors::factorize(&a).unwrap();
        assert!(f.factor_flops() > 0);
        // 2 n² per rhs column.
        assert_eq!(f.solve_flops(3), 2 * 100 * 3);
    }

    fn dummy_factors(r: &mut Rng, n: usize) -> Arc<LuFactors> {
        Arc::new(LuFactors::factorize(&random_well_conditioned(r, n)).unwrap())
    }

    #[test]
    fn cache_hits_misses_and_bit_identical_factors() {
        let mut r = Rng::new(16);
        let cache = LuCache::new(4);
        assert!(cache.is_empty());
        assert!(cache.lookup(&[0, 2, 3]).is_none());
        let f = dummy_factors(&mut r, 5);
        cache.insert(vec![0, 2, 3], Arc::clone(&f));
        let hit = cache.lookup(&[0, 2, 3]).expect("pattern memoized");
        // A hit returns the same factors object: trivially bit-identical.
        assert!(Arc::ptr_eq(&hit, &f));
        // A different pattern is a distinct key.
        assert!(cache.lookup(&[0, 2, 4]).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn cache_evicts_least_recently_used_at_capacity() {
        let mut r = Rng::new(17);
        let cache = LuCache::new(2);
        cache.insert(vec![0], dummy_factors(&mut r, 2));
        cache.insert(vec![1], dummy_factors(&mut r, 2));
        // Touch [0] so [1] is the LRU entry.
        assert!(cache.lookup(&[0]).is_some());
        cache.insert(vec![2], dummy_factors(&mut r, 2));
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup(&[0]).is_some(), "recently used survives");
        assert!(cache.lookup(&[1]).is_none(), "LRU entry evicted");
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn cache_invalidate_all_empties_and_counts_evictions() {
        let mut r = Rng::new(18);
        let cache = LuCache::new(8);
        cache.insert(vec![0, 1], dummy_factors(&mut r, 3));
        cache.insert(vec![1, 2], dummy_factors(&mut r, 3));
        cache.invalidate_all();
        assert!(cache.is_empty());
        assert_eq!(cache.stats().evictions, 2);
        assert!(cache.lookup(&[0, 1]).is_none(), "stale pattern gone");
        // Fresh stats: hit rate NaN sentinel before any lookup.
        assert!(LuCache::new(1).stats().hit_rate().is_nan());
    }

    #[test]
    fn cache_reinsert_replaces_without_growth() {
        let mut r = Rng::new(19);
        let cache = LuCache::new(4);
        let f1 = dummy_factors(&mut r, 3);
        let f2 = dummy_factors(&mut r, 3);
        cache.insert(vec![5, 6, 7], f1);
        cache.insert(vec![5, 6, 7], Arc::clone(&f2));
        assert_eq!(cache.len(), 1);
        let got = cache.lookup(&[5, 6, 7]).unwrap();
        assert!(Arc::ptr_eq(&got, &f2), "reinsert replaced the factors");
    }
}
