//! Partial-pivot LU factorization, solve and inverse.
//!
//! MDS decoding solves `G_S · A = Y` where `G_S` is the `k×k` submatrix
//! of the generator for the responding workers and `Y` stacks their
//! results. Decoding cost is `O(k^β)` with `β ≈ 2` once the `O(k³)`
//! factorization is amortized across the `m/k`-row right-hand sides —
//! which is exactly the cost model the paper assumes (§IV, footnote 2).
//! The factorization cache in the coordinator exploits the same split.

use crate::linalg::Matrix;
use crate::parallel::DecodePool;
use crate::{Error, Result};

/// Columns per solve panel: the triangular working set is
/// `n × SOLVE_PANEL` f64 (128 KiB at n = 128 — L2-resident), and panel
/// count bounds the useful decode-thread fan-out of one solve. Fixed —
/// never derived from the thread count — so panel boundaries (and thus
/// bit-exact results) are independent of parallelism.
const SOLVE_PANEL: usize = 128;

/// LU factors of a square matrix with row pivoting: `P·A = L·U`.
#[derive(Clone, Debug)]
pub struct LuFactors {
    /// Packed L (unit lower, below diagonal) and U (upper incl. diag).
    lu: Matrix,
    /// Row permutation: `perm[i]` is the original row in position `i`.
    perm: Vec<usize>,
    /// Number of flops spent factorizing (for §IV cost accounting).
    factor_flops: u64,
}

impl LuFactors {
    /// Factorize `a` (square). Fails on structural singularity.
    pub fn factorize(a: &Matrix) -> Result<Self> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::InvalidParams(format!(
                "LU of non-square {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut flops: u64 = 0;
        for col in 0..n {
            // Pivot: largest |entry| in this column at or below diagonal.
            let mut p = col;
            let mut best = lu[(col, col)].abs();
            for r in (col + 1)..n {
                let v = lu[(r, col)].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-12 {
                return Err(Error::Numerical(format!(
                    "singular system at column {col} (pivot {best:.3e})"
                )));
            }
            if p != col {
                perm.swap(p, col);
                // Swap full rows of the packed storage.
                for j in 0..n {
                    let tmp = lu[(col, j)];
                    lu[(col, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
            }
            let pivot = lu[(col, col)];
            for r in (col + 1)..n {
                let factor = lu[(r, col)] / pivot;
                lu[(r, col)] = factor;
                if factor == 0.0 {
                    continue;
                }
                for j in (col + 1)..n {
                    let v = lu[(col, j)];
                    lu[(r, j)] -= factor * v;
                }
                flops += 2 * (n - col) as u64;
            }
        }
        Ok(Self {
            lu,
            perm,
            factor_flops: flops,
        })
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.lu.rows()
    }

    /// Flops spent in factorization.
    pub fn factor_flops(&self) -> u64 {
        self.factor_flops
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.dim();
        if b.len() != n {
            return Err(Error::InvalidParams(format!(
                "rhs length {} != {n}",
                b.len()
            )));
        }
        // Forward substitution on permuted b: L y = P b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = b[self.perm[i]];
            for j in 0..i {
                acc -= self.lu[(i, j)] * y[j];
            }
            y[i] = acc;
        }
        // Back substitution: U x = y.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in (i + 1)..n {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
        Ok(x)
    }

    /// Solve `A X = B` for a matrix of right-hand sides — the blocked
    /// multi-RHS solve on the decode hot path: `B` has `m/k2/k1 · batch`
    /// columns and the per-column cost is `O(k²)` (the `β = 2` regime).
    pub fn solve_matrix(&self, b: &Matrix) -> Result<Matrix> {
        self.solve_matrix_with(b, &DecodePool::serial(), &mut Vec::new())
    }

    /// Blocked multi-RHS solve with per-panel parallelism and caller
    /// scratch.
    ///
    /// The RHS columns are gathered (permuted) into contiguous panels of
    /// [`SOLVE_PANEL`] columns inside `scratch` (reused across calls —
    /// the decoders' zero-alloc steady state), each panel runs its own
    /// forward + back substitution sweep — fanned across `pool`, since
    /// panels are fully independent — and the solved panels scatter
    /// into the row-major result. §Perf: relative to the old per-(i,j)
    /// axpy sweep this (a) touches each `y_j` row once per `y_i` with a
    /// 4-way unrolled source accumulation instead of i separate
    /// read-modify-write passes, and (b) keeps the working set at
    /// `n × SOLVE_PANEL` f64 (128 KiB at k = 128) instead of `n × cols`
    /// (`hiercode bench`'s `lu_solve` entry measures the combination).
    /// Per-column arithmetic order is fixed by the panel algorithm
    /// alone, so results are bit-identical at any pool width.
    pub fn solve_matrix_with(
        &self,
        b: &Matrix,
        pool: &DecodePool,
        scratch: &mut Vec<f64>,
    ) -> Result<Matrix> {
        let n = self.dim();
        if b.rows() != n {
            return Err(Error::InvalidParams(format!(
                "rhs rows {} != {n}",
                b.rows()
            )));
        }
        let cols = b.cols();
        let mut y = Matrix::zeros(n, cols);
        if n == 0 || cols == 0 {
            return Ok(y);
        }
        // Gather the permuted RHS into contiguous column panels. Grow
        // the scratch without re-zeroing: the gather below overwrites
        // the full n·cols working region every call.
        if scratch.len() < n * cols {
            scratch.resize(n * cols, 0.0);
        }
        let panels: Vec<(usize, usize)> = (0..cols)
            .step_by(SOLVE_PANEL)
            .map(|c0| (c0, SOLVE_PANEL.min(cols - c0)))
            .collect();
        {
            let mut off = 0;
            let mut chunks = Vec::with_capacity(panels.len());
            let mut rest: &mut [f64] = scratch;
            for &(_, w) in &panels {
                // mem::take moves the reference itself, so `head` keeps
                // the full scratch lifetime while `rest` advances.
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(n * w);
                chunks.push(head);
                rest = tail;
                off += n * w;
            }
            debug_assert_eq!(off, n * cols);
            for (chunk, &(c0, w)) in chunks.iter_mut().zip(&panels) {
                for i in 0..n {
                    chunk[i * w..(i + 1) * w]
                        .copy_from_slice(&b.row(self.perm[i])[c0..c0 + w]);
                }
            }
            // Solve every panel, in parallel when it pays.
            if pool.size() > 1 && chunks.len() > 1 {
                let tasks: Vec<(&mut [f64], usize)> = chunks
                    .into_iter()
                    .zip(panels.iter().map(|&(_, w)| w))
                    .collect();
                pool.map(tasks, |(chunk, w)| self.solve_panel(chunk, w));
            } else {
                for (chunk, &(_, w)) in chunks.into_iter().zip(&panels) {
                    self.solve_panel(chunk, w);
                }
            }
        }
        // Scatter the solved panels back to row-major.
        let mut off = 0;
        for &(c0, w) in &panels {
            for i in 0..n {
                y.row_mut(i)[c0..c0 + w]
                    .copy_from_slice(&scratch[off + i * w..off + (i + 1) * w]);
            }
            off += n * w;
        }
        Ok(y)
    }

    /// Forward + back substitution on one contiguous `n × w` panel.
    fn solve_panel(&self, sl: &mut [f64], w: usize) {
        let n = self.dim();
        // Forward: L y = P b (unit lower triangle).
        for i in 1..n {
            let (head, tail) = sl.split_at_mut(i * w);
            let yi = &mut tail[..w];
            let lrow = self.lu.row(i);
            let mut j = 0;
            while j + 4 <= i {
                let (l0, l1, l2, l3) = (lrow[j], lrow[j + 1], lrow[j + 2], lrow[j + 3]);
                let y0 = &head[j * w..(j + 1) * w];
                let y1 = &head[(j + 1) * w..(j + 2) * w];
                let y2 = &head[(j + 2) * w..(j + 3) * w];
                let y3 = &head[(j + 3) * w..(j + 4) * w];
                for c in 0..w {
                    yi[c] -= l0 * y0[c] + l1 * y1[c] + l2 * y2[c] + l3 * y3[c];
                }
                j += 4;
            }
            while j < i {
                let lij = lrow[j];
                if lij != 0.0 {
                    let yj = &head[j * w..(j + 1) * w];
                    for c in 0..w {
                        yi[c] -= lij * yj[c];
                    }
                }
                j += 1;
            }
        }
        // Back: U x = y.
        for i in (0..n).rev() {
            let (head, tail) = sl.split_at_mut((i + 1) * w);
            let yi = &mut head[i * w..];
            let urow = self.lu.row(i);
            let mut j = i + 1;
            while j + 4 <= n {
                let (u0, u1, u2, u3) = (urow[j], urow[j + 1], urow[j + 2], urow[j + 3]);
                let base = (j - i - 1) * w;
                let x0 = &tail[base..base + w];
                let x1 = &tail[base + w..base + 2 * w];
                let x2 = &tail[base + 2 * w..base + 3 * w];
                let x3 = &tail[base + 3 * w..base + 4 * w];
                for c in 0..w {
                    yi[c] -= u0 * x0[c] + u1 * x1[c] + u2 * x2[c] + u3 * x3[c];
                }
                j += 4;
            }
            while j < n {
                let uij = urow[j];
                if uij != 0.0 {
                    let base = (j - i - 1) * w;
                    let xj = &tail[base..base + w];
                    for c in 0..w {
                        yi[c] -= uij * xj[c];
                    }
                }
                j += 1;
            }
            let d = urow[i];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
    }

    /// Flops for solving `cols` right-hand sides (2n² each, plus the
    /// one-off factorization) — used by the §IV decode-cost accounting.
    pub fn solve_flops(&self, cols: usize) -> u64 {
        let n = self.dim() as u64;
        2 * n * n * cols as u64
    }

    /// Matrix inverse via `n` unit-vector solves.
    pub fn inverse(&self) -> Result<Matrix> {
        let n = self.dim();
        self.solve_matrix(&Matrix::identity(n))
    }
}

/// Convenience: solve `A x = b` in one call.
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    LuFactors::factorize(a)?.solve_vec(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::util::check::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn random_well_conditioned(r: &mut Rng, n: usize) -> Matrix {
        // Diagonally dominant → well conditioned and nonsingular.
        let mut m = Matrix::from_fn(n, n, |_, _| r.uniform(-1.0, 1.0));
        for i in 0..n {
            m[(i, i)] += n as f64;
        }
        m
    }

    #[test]
    fn solve_known_system() {
        // [2 1; 1 3] x = [3; 5] → x = [0.8, 1.4]
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = solve(&a, &[3.0, 5.0]).unwrap();
        assert_allclose(&x, &[0.8, 1.4], 1e-12, 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(matches!(
            LuFactors::factorize(&a),
            Err(Error::Numerical(_))
        ));
    }

    #[test]
    fn non_square_rejected() {
        let a = Matrix::zeros(2, 3);
        assert!(LuFactors::factorize(&a).is_err());
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = solve(&a, &[2.0, 3.0]).unwrap();
        assert_allclose(&x, &[3.0, 2.0], 1e-12, 1e-12);
    }

    #[test]
    fn solve_matrix_matches_vector_solves() {
        let mut r = Rng::new(10);
        let a = random_well_conditioned(&mut r, 8);
        let b = Matrix::from_fn(8, 5, |_, _| r.uniform(-2.0, 2.0));
        let f = LuFactors::factorize(&a).unwrap();
        let x = f.solve_matrix(&b).unwrap();
        for j in 0..5 {
            let bj: Vec<f64> = (0..8).map(|i| b[(i, j)]).collect();
            let xj = f.solve_vec(&bj).unwrap();
            let got: Vec<f64> = (0..8).map(|i| x[(i, j)]).collect();
            assert_allclose(&got, &xj, 1e-10, 1e-12);
        }
    }

    #[test]
    fn inverse_times_original_is_identity() {
        let mut r = Rng::new(11);
        for n in [1, 2, 5, 16] {
            let a = random_well_conditioned(&mut r, n);
            let inv = LuFactors::factorize(&a).unwrap().inverse().unwrap();
            let prod = ops::matmul(&inv, &a);
            assert!(
                prod.max_abs_diff(&Matrix::identity(n)) < 1e-9,
                "n={n}: {}",
                prod.max_abs_diff(&Matrix::identity(n))
            );
        }
    }

    #[test]
    fn residual_property_random_systems() {
        check("LU solve residual", 40, |g| {
            let n = g.usize_in(1..20);
            let mut r = Rng::new(g.usize_in(0..1_000_000) as u64);
            let a = random_well_conditioned(&mut r, n);
            let b: Vec<f64> = (0..n).map(|_| r.uniform(-5.0, 5.0)).collect();
            let x = solve(&a, &b).unwrap();
            let ax = ops::matvec(&a, &x);
            assert_allclose(&ax, &b, 1e-8, 1e-8);
        });
    }

    #[test]
    fn solve_matrix_spans_panel_boundaries() {
        // cols > SOLVE_PANEL exercises the gather/scatter multi-panel
        // path; correctness is checked against per-column solves.
        let mut r = Rng::new(14);
        let a = random_well_conditioned(&mut r, 6);
        let b = Matrix::from_fn(6, SOLVE_PANEL + 37, |_, _| r.uniform(-1.0, 1.0));
        let f = LuFactors::factorize(&a).unwrap();
        let x = f.solve_matrix(&b).unwrap();
        for j in [0, 1, SOLVE_PANEL - 1, SOLVE_PANEL, SOLVE_PANEL + 36] {
            let bj: Vec<f64> = (0..6).map(|i| b[(i, j)]).collect();
            let xj = f.solve_vec(&bj).unwrap();
            let got: Vec<f64> = (0..6).map(|i| x[(i, j)]).collect();
            assert_allclose(&got, &xj, 1e-10, 1e-12);
        }
    }

    #[test]
    fn pooled_solve_is_bit_identical_to_serial() {
        let mut r = Rng::new(15);
        let a = random_well_conditioned(&mut r, 12);
        let b = Matrix::from_fn(12, 3 * SOLVE_PANEL + 5, |_, _| r.uniform(-1.0, 1.0));
        let f = LuFactors::factorize(&a).unwrap();
        let serial = f.solve_matrix(&b).unwrap();
        for threads in [2, 8] {
            let pool = DecodePool::new(threads).unwrap();
            let mut scratch = Vec::new();
            let par = f.solve_matrix_with(&b, &pool, &mut scratch).unwrap();
            assert_eq!(serial.data(), par.data(), "threads={threads}");
            // Scratch is reused: a second call must not change results.
            let again = f.solve_matrix_with(&b, &pool, &mut scratch).unwrap();
            assert_eq!(serial.data(), again.data());
        }
    }

    #[test]
    fn flop_accounting_positive() {
        let mut r = Rng::new(12);
        let a = random_well_conditioned(&mut r, 10);
        let f = LuFactors::factorize(&a).unwrap();
        assert!(f.factor_flops() > 0);
        // 2 n² per rhs column.
        assert_eq!(f.solve_flops(3), 2 * 100 * 3);
    }
}
