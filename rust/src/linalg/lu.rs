//! Partial-pivot LU factorization, solve and inverse.
//!
//! MDS decoding solves `G_S · A = Y` where `G_S` is the `k×k` submatrix
//! of the generator for the responding workers and `Y` stacks their
//! results. Decoding cost is `O(k^β)` with `β ≈ 2` once the `O(k³)`
//! factorization is amortized across the `m/k`-row right-hand sides —
//! which is exactly the cost model the paper assumes (§IV, footnote 2).
//! The factorization cache in the coordinator exploits the same split.

use crate::linalg::{ops, Matrix};
use crate::{Error, Result};

/// LU factors of a square matrix with row pivoting: `P·A = L·U`.
#[derive(Clone, Debug)]
pub struct LuFactors {
    /// Packed L (unit lower, below diagonal) and U (upper incl. diag).
    lu: Matrix,
    /// Row permutation: `perm[i]` is the original row in position `i`.
    perm: Vec<usize>,
    /// Number of flops spent factorizing (for §IV cost accounting).
    factor_flops: u64,
}

impl LuFactors {
    /// Factorize `a` (square). Fails on structural singularity.
    pub fn factorize(a: &Matrix) -> Result<Self> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::InvalidParams(format!(
                "LU of non-square {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut flops: u64 = 0;
        for col in 0..n {
            // Pivot: largest |entry| in this column at or below diagonal.
            let mut p = col;
            let mut best = lu[(col, col)].abs();
            for r in (col + 1)..n {
                let v = lu[(r, col)].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-12 {
                return Err(Error::Numerical(format!(
                    "singular system at column {col} (pivot {best:.3e})"
                )));
            }
            if p != col {
                perm.swap(p, col);
                // Swap full rows of the packed storage.
                for j in 0..n {
                    let tmp = lu[(col, j)];
                    lu[(col, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
            }
            let pivot = lu[(col, col)];
            for r in (col + 1)..n {
                let factor = lu[(r, col)] / pivot;
                lu[(r, col)] = factor;
                if factor == 0.0 {
                    continue;
                }
                for j in (col + 1)..n {
                    let v = lu[(col, j)];
                    lu[(r, j)] -= factor * v;
                }
                flops += 2 * (n - col) as u64;
            }
        }
        Ok(Self {
            lu,
            perm,
            factor_flops: flops,
        })
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.lu.rows()
    }

    /// Flops spent in factorization.
    pub fn factor_flops(&self) -> u64 {
        self.factor_flops
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.dim();
        if b.len() != n {
            return Err(Error::InvalidParams(format!(
                "rhs length {} != {n}",
                b.len()
            )));
        }
        // Forward substitution on permuted b: L y = P b.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = b[self.perm[i]];
            for j in 0..i {
                acc -= self.lu[(i, j)] * y[j];
            }
            y[i] = acc;
        }
        // Back substitution: U x = y.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in (i + 1)..n {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
        Ok(x)
    }

    /// Solve `A X = B` for a matrix of right-hand sides, column-blocked
    /// so the triangular sweeps stream contiguously over `B`'s rows.
    ///
    /// This is the decoder's hot call: `B` has `m/k2/k1 · batch` columns
    /// and the per-column cost is `O(k²)` — the `β = 2` regime.
    pub fn solve_matrix(&self, b: &Matrix) -> Result<Matrix> {
        let n = self.dim();
        if b.rows() != n {
            return Err(Error::InvalidParams(format!(
                "rhs rows {} != {n}",
                b.rows()
            )));
        }
        let cols = b.cols();
        // Apply permutation once.
        let mut y = Matrix::zeros(n, cols);
        for i in 0..n {
            y.row_mut(i).copy_from_slice(b.row(self.perm[i]));
        }
        // Forward substitution across all columns: row i minus L(i,j)·row j.
        for i in 0..n {
            // Split borrow: rows j < i are finalized.
            for j in 0..i {
                let lij = self.lu[(i, j)];
                if lij == 0.0 {
                    continue;
                }
                let (head, tail) = y.data_mut().split_at_mut(i * cols);
                let yj = &head[j * cols..(j + 1) * cols];
                let yi = &mut tail[..cols];
                ops::axpy(-lij, yj, yi);
            }
        }
        // Back substitution.
        for i in (0..n).rev() {
            for j in (i + 1)..n {
                let uij = self.lu[(i, j)];
                if uij == 0.0 {
                    continue;
                }
                let (head, tail) = y.data_mut().split_at_mut(j * cols);
                let yi = &mut head[i * cols..(i + 1) * cols];
                let yj = &tail[..cols];
                ops::axpy(-uij, yj, yi);
            }
            let d = self.lu[(i, i)];
            for v in y.row_mut(i) {
                *v /= d;
            }
        }
        Ok(y)
    }

    /// Flops for solving `cols` right-hand sides (2n² each, plus the
    /// one-off factorization) — used by the §IV decode-cost accounting.
    pub fn solve_flops(&self, cols: usize) -> u64 {
        let n = self.dim() as u64;
        2 * n * n * cols as u64
    }

    /// Matrix inverse via `n` unit-vector solves.
    pub fn inverse(&self) -> Result<Matrix> {
        let n = self.dim();
        self.solve_matrix(&Matrix::identity(n))
    }
}

/// Convenience: solve `A x = b` in one call.
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    LuFactors::factorize(a)?.solve_vec(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn random_well_conditioned(r: &mut Rng, n: usize) -> Matrix {
        // Diagonally dominant → well conditioned and nonsingular.
        let mut m = Matrix::from_fn(n, n, |_, _| r.uniform(-1.0, 1.0));
        for i in 0..n {
            m[(i, i)] += n as f64;
        }
        m
    }

    #[test]
    fn solve_known_system() {
        // [2 1; 1 3] x = [3; 5] → x = [0.8, 1.4]
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = solve(&a, &[3.0, 5.0]).unwrap();
        assert_allclose(&x, &[0.8, 1.4], 1e-12, 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(matches!(
            LuFactors::factorize(&a),
            Err(Error::Numerical(_))
        ));
    }

    #[test]
    fn non_square_rejected() {
        let a = Matrix::zeros(2, 3);
        assert!(LuFactors::factorize(&a).is_err());
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = solve(&a, &[2.0, 3.0]).unwrap();
        assert_allclose(&x, &[3.0, 2.0], 1e-12, 1e-12);
    }

    #[test]
    fn solve_matrix_matches_vector_solves() {
        let mut r = Rng::new(10);
        let a = random_well_conditioned(&mut r, 8);
        let b = Matrix::from_fn(8, 5, |_, _| r.uniform(-2.0, 2.0));
        let f = LuFactors::factorize(&a).unwrap();
        let x = f.solve_matrix(&b).unwrap();
        for j in 0..5 {
            let bj: Vec<f64> = (0..8).map(|i| b[(i, j)]).collect();
            let xj = f.solve_vec(&bj).unwrap();
            let got: Vec<f64> = (0..8).map(|i| x[(i, j)]).collect();
            assert_allclose(&got, &xj, 1e-10, 1e-12);
        }
    }

    #[test]
    fn inverse_times_original_is_identity() {
        let mut r = Rng::new(11);
        for n in [1, 2, 5, 16] {
            let a = random_well_conditioned(&mut r, n);
            let inv = LuFactors::factorize(&a).unwrap().inverse().unwrap();
            let prod = ops::matmul(&inv, &a);
            assert!(
                prod.max_abs_diff(&Matrix::identity(n)) < 1e-9,
                "n={n}: {}",
                prod.max_abs_diff(&Matrix::identity(n))
            );
        }
    }

    #[test]
    fn residual_property_random_systems() {
        check("LU solve residual", 40, |g| {
            let n = g.usize_in(1..20);
            let mut r = Rng::new(g.usize_in(0..1_000_000) as u64);
            let a = random_well_conditioned(&mut r, n);
            let b: Vec<f64> = (0..n).map(|_| r.uniform(-5.0, 5.0)).collect();
            let x = solve(&a, &b).unwrap();
            let ax = ops::matvec(&a, &x);
            assert_allclose(&ax, &b, 1e-8, 1e-8);
        });
    }

    #[test]
    fn flop_accounting_positive() {
        let mut r = Rng::new(12);
        let a = random_well_conditioned(&mut r, 10);
        let f = LuFactors::factorize(&a).unwrap();
        assert!(f.factor_flops() > 0);
        // 2 n² per rhs column.
        assert_eq!(f.solve_flops(3), 2 * 100 * 3);
    }
}
