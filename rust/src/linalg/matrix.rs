//! Row-major dense `f64` matrix.

use crate::{Error, Result};

/// Row-major dense matrix of `f64`.
///
/// The coded shards `Â_{i,j}`, the generator matrices `G`, and all
/// decode systems are instances of this type. Kept deliberately simple:
/// contiguous `Vec<f64>`, explicit shape, panics only on programmer
/// errors (index out of bounds), `Result` on user-facing shape errors.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::InvalidParams(format!(
                "matrix data length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Self { rows, cols, data })
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Build with a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw data vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// A view of row `i`.
    pub fn row(&self, i: usize) -> &[f64] {
        assert!(i < self.rows, "row {i} out of {}", self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        assert!(i < self.rows, "row {i} out of {}", self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Reshape in place to `rows × cols`, reusing the existing
    /// allocation whenever its capacity suffices. **Contents are
    /// unspecified** — the caller must overwrite every element (no
    /// re-zeroing pass, which is the point: this is the primitive
    /// behind the decoders' reusable scratch, where a session that sees
    /// the same shapes every job allocates and zeroes nothing).
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        let need = rows * cols;
        if self.data.len() < need {
            self.data.resize(need, 0.0);
        } else {
            self.data.truncate(need);
        }
        self.rows = rows;
        self.cols = cols;
    }

    /// Stack matrices vertically: `[A1; A2; ...]` (the paper's block
    /// notation for splitting the input `A`).
    pub fn vstack(blocks: &[Matrix]) -> Result<Matrix> {
        let first = blocks
            .first()
            .ok_or_else(|| Error::InvalidParams("vstack of zero blocks".into()))?;
        let cols = first.cols;
        let rows: usize = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            if b.cols != cols {
                return Err(Error::InvalidParams(format!(
                    "vstack column mismatch: {} vs {cols}",
                    b.cols
                )));
            }
            data.extend_from_slice(&b.data);
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Split into `parts` equal row-blocks: inverse of [`Matrix::vstack`].
    pub fn split_rows(&self, parts: usize) -> Result<Vec<Matrix>> {
        if parts == 0 || self.rows % parts != 0 {
            return Err(Error::InvalidParams(format!(
                "cannot split {} rows into {parts} equal blocks",
                self.rows
            )));
        }
        let block_rows = self.rows / parts;
        Ok((0..parts)
            .map(|p| {
                let start = p * block_rows * self.cols;
                let end = start + block_rows * self.cols;
                Matrix {
                    rows: block_rows,
                    cols: self.cols,
                    data: self.data[start..end].to_vec(),
                }
            })
            .collect())
    }

    /// Extract the submatrix of the given rows (decode systems pick the
    /// generator rows of the workers that responded).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(idx.len(), self.cols);
        for (out, &i) in idx.iter().enumerate() {
            m.row_mut(out).copy_from_slice(self.row(i));
        }
        m
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Max absolute element difference (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// True if all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let m = Matrix::zeros(3, 4);
        assert_eq!(m.shape(), (3, 4));
        assert!(m.data().iter().all(|&x| x == 0.0));
        let id = Matrix::identity(3);
        assert_eq!(id[(0, 0)], 1.0);
        assert_eq!(id[(0, 1)], 0.0);
    }

    #[test]
    fn from_vec_validates_length() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(Matrix::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn vstack_then_split_roundtrip() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let s = Matrix::vstack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape(), (4, 2));
        let parts = s.split_rows(2).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn vstack_rejects_mismatched_cols() {
        let a = Matrix::zeros(1, 2);
        let b = Matrix::zeros(1, 3);
        assert!(Matrix::vstack(&[a, b]).is_err());
    }

    #[test]
    fn split_rejects_uneven() {
        let m = Matrix::zeros(5, 2);
        assert!(m.split_rows(2).is_err());
        assert!(m.split_rows(0).is_err());
    }

    #[test]
    fn select_rows_picks() {
        let m = Matrix::from_rows(&[&[0.0], &[1.0], &[2.0], &[3.0]]);
        let s = m.select_rows(&[3, 1]);
        assert_eq!(s.row(0), &[3.0]);
        assert_eq!(s.row(1), &[1.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t[(2, 1)], 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_rows(&[&[3.0, 4.0]]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert!(m.is_finite());
        let mut bad = m.clone();
        bad[(0, 0)] = f64::NAN;
        assert!(!bad.is_finite());
    }
}
