fn main() { hiercode::cli::main() }
