//! Minimal recursive-descent JSON parser and serializer.
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! user-supplied cluster config files. Supports the full JSON grammar
//! with `f64` numbers and `\uXXXX` escapes (BMP only).

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (JSON numbers are all f64 here).
    Number(f64),
    /// String.
    String(String),
    /// Array.
    Array(Vec<Json>),
    /// Object (ordered for deterministic serialization).
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer value).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req<'a>(&'a self, key: &str, ctx: &str) -> Result<&'a Json> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("{ctx}: missing field '{key}'")))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str, ctx: &str) -> Result<String> {
        self.req(key, ctx)?
            .as_str()
            .map(|s| s.to_string())
            .ok_or_else(|| Error::Config(format!("{ctx}: field '{key}' must be a string")))
    }

    /// Required usize field.
    pub fn req_usize(&self, key: &str, ctx: &str) -> Result<usize> {
        self.req(key, ctx)?
            .as_usize()
            .ok_or_else(|| Error::Config(format!("{ctx}: field '{key}' must be a non-negative integer")))
    }

    /// Required f64 field.
    pub fn req_f64(&self, key: &str, ctx: &str) -> Result<f64> {
        self.req(key, ctx)?
            .as_f64()
            .ok_or_else(|| Error::Config(format!("{ctx}: field '{key}' must be a number")))
    }

    /// Serialize to a compact JSON string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::String(s) => write_escaped(s, out),
            Json::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("invalid unicode escape"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err(&format!("invalid number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Number(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Number(-350.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::String("hi".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"artifacts":[{"file":"x.hlo.txt","inputs":[[16,32],[32,1]],"name":"w"}],"version":1}"#;
        let v = Json::parse(doc).unwrap();
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
        assert_eq!(s, doc);
    }

    #[test]
    fn accessor_helpers() {
        let v = Json::parse(r#"{"n": 5, "x": 1.5, "s": "y"}"#).unwrap();
        assert_eq!(v.req_usize("n", "t").unwrap(), 5);
        assert!(v.req_usize("x", "t").is_err()); // fractional
        assert_eq!(v.req_f64("x", "t").unwrap(), 1.5);
        assert_eq!(v.req_str("s", "t").unwrap(), "y");
        assert!(v.req("missing", "t").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "version": 1,
          "artifacts": [
            {"name": "worker_matvec_r16_d32_b1", "file": "worker_matvec_r16_d32_b1.hlo.txt",
             "sha256_16": "abc", "entry": "worker_task",
             "inputs": [[16, 32], [32, 1]], "output": [16, 1], "dtype": "f32"}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        let arts = v.get("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(
            arts[0].get("output").unwrap().as_array().unwrap()[0].as_usize(),
            Some(16)
        );
    }
}
