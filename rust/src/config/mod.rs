//! Configuration system: a self-contained JSON parser plus the typed,
//! validated config schema for clusters, codes and simulations.
//!
//! (`serde`/`serde_json` are unavailable in the offline build — see
//! DESIGN.md; [`json`] implements the subset of JSON the project needs:
//! full syntax, f64 numbers, no surrogate-pair escapes.)

pub mod json;
pub mod schema;

pub use json::Json;
pub use schema::{
    ClusterConfig, CodeConfig, RuntimeConfig, ServingConfig, StragglerConfig,
};
